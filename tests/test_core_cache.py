"""Unit tests: MemoryPool + Chameleon Adapter Cache (paper §4.1)."""
import pytest

from repro.core import (AdapterCache, AdapterInfo, CostAwareEviction,
                        FairShareEviction, LRUEviction, MemoryPool,
                        PoolError)


def make_catalog(sizes):
    """sizes: {adapter_id: size_tokens} (bytes = tokens for simplicity)."""
    return {aid: AdapterInfo(adapter_id=aid, rank=8, size_bytes=s,
                             size_tokens=s) for aid, s in sizes.items()}


def make_cache(capacity=100, sizes=None, policy=None, enabled=True):
    pool = MemoryPool(capacity_tokens=capacity)
    catalog = make_catalog(sizes or {0: 10, 1: 10, 2: 20, 3: 40})
    return pool, AdapterCache(pool, catalog, policy=policy, enabled=enabled)


class TestMemoryPool:
    def test_reserve_release(self):
        pool = MemoryPool(capacity_tokens=100)
        pool.reserve_request(1, 30)
        assert pool.free_tokens == 70
        pool.grow_request(1, 10)
        assert pool.free_tokens == 60
        assert pool.release_request(1) == 40
        assert pool.free_tokens == 100
        pool.check_invariants()

    def test_overflow_raises(self):
        pool = MemoryPool(capacity_tokens=10)
        with pytest.raises(PoolError):
            pool.reserve_request(1, 11)

    def test_adapter_holds(self):
        pool = MemoryPool(capacity_tokens=50)
        pool.hold_adapter(7, 20)
        assert pool.used_adapters == 20
        pool.hold_adapter(7, 20)  # idempotent
        assert pool.used_adapters == 20
        assert pool.drop_adapter(7) == 20
        assert pool.free_tokens == 50

    def test_cache_tokens_is_idle_memory(self):
        pool = MemoryPool(capacity_tokens=100)
        pool.reserve_request(1, 60)
        assert pool.cache_tokens == 40  # adapters may use all idle memory


class TestAcquireRelease:
    def test_miss_then_hit(self):
        _, cache = make_cache()
        assert cache.acquire(0, now=1.0) is False   # cold: miss
        cache.release(0, now=2.0)
        assert cache.acquire(0, now=3.0) is True    # cached: hit
        assert cache.stats.hits == 1 and cache.stats.misses == 1

    def test_refcount_protects_running(self):
        pool, cache = make_cache(capacity=30, sizes={0: 20, 1: 20})
        cache.acquire(0, now=1.0)
        # Adapter 0 pinned (RC=1); adapter 1 cannot fit and nothing is
        # evictable -> PoolError.
        with pytest.raises(PoolError):
            cache.acquire(1, now=2.0)
        cache.release(0, now=3.0)
        cache.acquire(1, now=4.0)   # now 0 is evictable
        assert cache.resident(1) and not cache.resident(0)

    def test_slora_mode_discards_on_release(self):
        _, cache = make_cache(enabled=False)
        cache.acquire(0, now=1.0)
        cache.release(0, now=2.0)
        assert not cache.resident(0)   # S-LoRA semantics

    def test_chameleon_mode_retains_on_release(self):
        _, cache = make_cache(enabled=True)
        cache.acquire(0, now=1.0)
        cache.release(0, now=2.0)
        assert cache.resident(0)       # the whole point of the paper


class TestEvictionPolicies:
    def test_lru_evicts_oldest(self):
        pool, cache = make_cache(capacity=45, sizes={0: 20, 1: 20, 2: 20},
                                 policy=LRUEviction())
        cache.acquire(0, now=1.0); cache.release(0, now=1.0)
        cache.acquire(1, now=2.0); cache.release(1, now=2.0)
        cache.acquire(2, now=3.0)   # must evict 0 (oldest)
        assert not cache.resident(0) and cache.resident(1)

    def test_cost_aware_protects_large_adapter(self):
        # Equal recency+frequency; size weight 0.45 must keep the big one.
        pool, cache = make_cache(capacity=60, sizes={0: 40, 1: 10, 2: 20},
                                 policy=CostAwareEviction())
        cache.acquire(0, now=1.0); cache.release(0, now=1.0)
        cache.acquire(1, now=1.0); cache.release(1, now=1.0)
        cache.acquire(2, now=2.0)   # need 20, free 10 -> evict one
        assert cache.resident(0), "large (costly-to-reload) adapter kept"
        assert not cache.resident(1)

    def test_cost_aware_protects_frequent_adapter(self):
        pool, cache = make_cache(capacity=45, sizes={0: 20, 1: 20, 2: 20})
        for t in range(5):   # adapter 0 is hot
            cache.acquire(0, now=float(t)); cache.release(0, now=float(t))
        cache.acquire(1, now=6.0); cache.release(1, now=6.0)
        cache.acquire(2, now=7.0)
        assert cache.resident(0), "frequent adapter kept despite older"
        assert not cache.resident(1)

    def test_fairshare_weights_sum_to_one(self):
        p = FairShareEviction()
        assert abs(p.w.frequency + p.w.recency + p.w.size - 1.0) < 1e-9

    def test_paper_weights(self):
        p = CostAwareEviction()
        assert (p.w.frequency, p.w.recency, p.w.size) == (0.45, 0.10, 0.45)


class TestDynamicSizing:
    def test_shrink_for_requests(self):
        pool, cache = make_cache(capacity=100, sizes={0: 30, 1: 30, 2: 30})
        for aid in (0, 1, 2):
            cache.acquire(aid, now=1.0); cache.release(aid, now=1.0)
        assert pool.used_adapters == 90
        # A batch needs 50 tokens -> cache must shrink (evict 2 adapters).
        assert cache.shrink_for_requests(50, now=2.0)
        assert pool.free_tokens >= 50
        assert cache.stats.shrink_events == 1

    def test_shrink_fails_when_pinned(self):
        pool, cache = make_cache(capacity=100, sizes={0: 90, 1: 30})
        cache.acquire(0, now=1.0)   # pinned, RC=1
        assert not cache.shrink_for_requests(50, now=2.0)

    def test_queued_protection_is_second_tier(self):
        pool, cache = make_cache(capacity=100, sizes={0: 40, 1: 40, 2: 40})
        cache.acquire(0, now=1.0); cache.release(0, now=1.0)
        cache.acquire(1, now=2.0); cache.release(1, now=2.0)
        # Protect 1 (queued request needs it): eviction should hit 0 first.
        cache.make_room(30, now=3.0, queued_protect=[1])
        assert cache.resident(1) and not cache.resident(0)
        # But under pressure the queued adapter *can* go (second tier).
        cache.make_room(80, now=4.0, queued_protect=[1])
        assert not cache.resident(1)

    def test_prefetch_never_evicts(self):
        pool, cache = make_cache(capacity=50, sizes={0: 40, 1: 40})
        cache.acquire(0, now=1.0); cache.release(0, now=1.0)
        assert cache.prefetch(1, now=2.0) in (True, False)
        # Adapter 0 must still be resident if prefetch succeeded by eviction
        # -- prefetch() uses make_room; guard: pool free was 10 < 40, so the
        # cache may evict 0 -- the QueuedRequestPrefetcher wrapper is the
        # no-evict layer. Here we just require pool invariants hold.
        pool.check_invariants()
