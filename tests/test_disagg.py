"""Disaggregated prefill/decode serving (DESIGN §3.4): KV handoff
correctness, MIGRATING lifecycle, role-aware routing, chunked prefill.

The hard invariants under test:

- **handoff parity** — a request whose KV migrated engine->engine
  (paged or dense, COW-shared prefix pages included) streams exactly
  the tokens a single engine would have produced;
- **pool safety** — ``MemoryPool.check_invariants`` holds on *both*
  ends mid-handoff (source pages pinned, destination pages reserved);
- **MIGRATING lifecycle** — cancel and deadline expiry inside the
  handoff window finalize cleanly with the streamed-token records
  intact and both ends released;
- **routing** — prefill-tier saturation spills back to decode
  replicas; the disagg tier serves the ServingSystem protocol.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Request
from repro.core.request import RequestState
from repro.models import api
from repro.serving import ServingSystem, build_system
from repro.serving.cluster import EngineCluster, EngineClusterConfig
from repro.serving.disagg import (DisaggCluster, DisaggConfig,
                                  RoleAutoscaler)
from repro.serving.engine import ChameleonEngine, EngineConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def make_engine(small_model, **kw):
    cfg, params = small_model
    defaults = dict(max_slots=4, max_len=128, n_lora_slots=4,
                    n_adapters=8, seed=0)
    defaults.update(kw)
    return ChameleonEngine(cfg, params, EngineConfig(**defaults))


def make_disagg(small_model, ecfg_kw=None, **dkw):
    cfg, params = small_model
    defaults = dict(max_slots=4, max_len=128, n_lora_slots=4,
                    n_adapters=8, seed=0)
    defaults.update(ecfg_kw or {})
    dcfg = dict(n_prefill=1, n_decode=2, link_gbps=8.0, seed=0)
    dcfg.update(dkw)
    return DisaggCluster(cfg, params, EngineConfig(**defaults),
                         DisaggConfig(**dcfg))


def _prompt(rng, n):
    return [int(x) for x in rng.integers(1, 200, n)]


def _run_to_generated(eng, handle, n):
    """Step until the request has streamed ``n`` tokens (horizon-1
    source engines expose each token at the step boundary)."""
    for _ in range(10_000):
        if len(handle.tokens) >= n or handle.done:
            return
        eng.step()
    raise AssertionError("request never reached the target progress")


def _check_pools(*engines):
    for e in engines:
        if e.paged:
            e.pool.check_invariants(free_page_ids=e.free_pages)


class TestKVHandoffParity:
    """Round-trip page serialization: export mid-decode on A, import
    into B, finish there — tokens must match the single-engine run."""

    @pytest.mark.parametrize("paged", [True, False])
    def test_migrated_tokens_match_baseline(self, small_model, paged):
        rng = np.random.default_rng(5)
        prompt = _prompt(rng, 25)

        base = make_engine(small_model, paged=paged)
        hb = base.submit(Request(input_len=25, output_len=10,
                                 adapter_id=1, prompt=list(prompt)))
        base.drain()
        want = hb.tokens
        assert len(want) == 10

        src = make_engine(small_model, paged=paged, max_horizon=1,
                          pipeline_readback=False)
        dst = make_engine(small_model, paged=paged)
        req = Request(input_len=25, output_len=10, adapter_id=1,
                      prompt=list(prompt))
        h = src.submit(req)
        _run_to_generated(src, h, 3)
        ship = src.begin_migration(req)
        assert ship is not None
        assert req.state is RequestState.MIGRATING
        assert not req.terminal          # MIGRATING is not terminal
        _check_pools(src, dst)           # source pages pinned, not freed
        assert dst.import_request_kv(ship)
        src.complete_migration(req)
        _check_pools(src, dst)
        assert req.state is RequestState.RUNNING
        # The handle keeps streaming from the destination.
        h._system = dst
        dst.drain()
        assert h.done and req.state is RequestState.FINISHED
        assert h.tokens == want
        assert src.n_kv_exports == 1 and dst.n_kv_imports == 1
        assert dst.kv_handoff_bytes == ship["nbytes"] > 0
        res = h.result()
        assert len(res.tbts) == 9        # every inter-token gap recorded

    def test_cow_shared_pages_survive_migration(self, small_model):
        """A request whose slot maps radix-tree shared pages (prefix
        hit) migrates correctly: the exported payload contains the
        shared pages' bits and both pools stay consistent."""
        rng = np.random.default_rng(7)
        pre = _prompt(rng, 32)           # two full pages of preamble

        base = make_engine(small_model, prefix_cache=True)
        warm = base.submit(Request(input_len=32, output_len=4,
                                   adapter_id=2, prompt=list(pre)))
        base.drain()
        hb = base.submit(Request(input_len=32, output_len=8,
                                 adapter_id=2, prompt=list(pre)))
        base.drain()
        want = hb.tokens

        src = make_engine(small_model, prefix_cache=True,
                          max_horizon=1, pipeline_readback=False)
        dst = make_engine(small_model)
        w = src.submit(Request(input_len=32, output_len=4,
                               adapter_id=2, prompt=list(pre)))
        src.drain()
        assert w.done
        req = Request(input_len=32, output_len=8, adapter_id=2,
                      prompt=list(pre))
        h = src.submit(req)
        _run_to_generated(src, h, 2)
        assert src.n_prefix_hits >= 1    # the slot really shares pages
        ship = src.begin_migration(req)
        assert ship is not None
        _check_pools(src, dst)
        assert dst.import_request_kv(ship)
        src.complete_migration(req)
        _check_pools(src, dst)
        h._system = dst
        dst.drain()
        assert h.tokens == want


class TestMigratingLifecycle:
    def _export(self, small_model, ttl=None):
        src = make_engine(small_model, max_horizon=1,
                          pipeline_readback=False)
        req = Request(input_len=20, output_len=12, adapter_id=0)
        h = src.submit(req, ttl=ttl)
        _run_to_generated(src, h, 3)
        ship = src.begin_migration(req)
        assert ship is not None
        return src, req, h, ship

    def test_cancel_mid_handoff(self, small_model):
        src, req, h, ship = self._export(small_model)
        assert src.abort_migration(req, RequestState.CANCELLED,
                                   shipment=ship)
        assert req.state is RequestState.CANCELLED
        _check_pools(src)
        assert not src._migrating and src.busy() is False
        # Streamed records survived the export/abort round trip.
        res = h.result()
        assert res.state is RequestState.CANCELLED
        assert len(res.tokens) == 3 and len(res.tbts) == 2

    def test_expiry_mid_handoff(self, small_model):
        src, req, h, ship = self._export(small_model, ttl=30.0)
        assert src.abort_migration(req, RequestState.EXPIRED,
                                   shipment=ship)
        assert req.state is RequestState.EXPIRED
        _check_pools(src)
        # The slot is reusable afterwards.
        h2 = src.submit(Request(input_len=8, output_len=3, adapter_id=1))
        src.drain()
        assert h2.done and len(h2.tokens) == 3

    def test_abort_after_import_refusal_leaves_dst_clean(self,
                                                         small_model):
        """A destination with zero free slots refuses the import
        without holding anything; the source can still abort."""
        src, req, h, ship = self._export(small_model)
        dst = make_engine(small_model, max_slots=2)
        blockers = [dst.submit(Request(input_len=8, output_len=40,
                                       adapter_id=i)) for i in range(2)]
        while not dst.active.all():
            dst.step()
        assert dst.import_request_kv(ship) is False
        _check_pools(dst)
        assert src.abort_migration(req, RequestState.CANCELLED,
                                   shipment=ship)
        dst.drain()
        assert all(b.done for b in blockers)

    def test_cluster_cancel_while_on_link(self, small_model):
        """handle.cancel() during the modeled link transfer: the
        cluster aborts on the source and the handle resolves."""
        dis = make_disagg(small_model, link_gbps=1e-6)   # ~never lands
        req = Request(input_len=20, output_len=12, adapter_id=0)
        h = dis.submit(req)
        for _ in range(10_000):
            if req.state is RequestState.MIGRATING:
                break
            dis.step()
        assert req.state is RequestState.MIGRATING
        assert h.cancel()
        dis.step()
        assert req.state is RequestState.CANCELLED
        assert dis.handoff.n_dropped == 1
        assert not dis.busy()
        _check_pools(*dis.engines)

    def test_cluster_expiry_while_on_link(self, small_model):
        dis = make_disagg(small_model, link_gbps=1e-6)
        dis.warmup()          # jit compiles must not eat the TTL
        req = Request(input_len=20, output_len=12, adapter_id=0)
        dis.submit(req, ttl=1.5)
        for _ in range(10_000):
            if req.state is RequestState.MIGRATING:
                break
            dis.step()
        assert req.state is RequestState.MIGRATING
        import time
        deadline = time.monotonic() + 30.0
        while req.state is RequestState.MIGRATING \
                and time.monotonic() < deadline:
            dis.step()
        assert req.state is RequestState.EXPIRED
        assert not dis.busy()
        _check_pools(*dis.engines)


class TestDisaggCluster:
    def test_tokens_match_monolithic_cluster(self, small_model):
        cfg, params = small_model
        spec = [(25, 8, 0), (6, 5, 1), (40, 6, 2), (10, 4, 0),
                (33, 7, 3), (12, 3, 1), (50, 5, 4)]

        def mk():
            rng = np.random.default_rng(3)
            return [Request(input_len=L, output_len=O, adapter_id=a,
                            prompt=_prompt(rng, L))
                    for L, O, a in spec]

        ecfg = EngineConfig(max_slots=4, max_len=128, n_lora_slots=4,
                            n_adapters=8, seed=0)
        mono = EngineCluster(cfg, params, ecfg,
                             EngineClusterConfig(n_engines=3, seed=0))
        mono.warmup()
        want = [mono.submit(r) for r in mk()]
        mono.drain()

        dis = make_disagg(small_model)
        dis.warmup()
        got = [dis.submit(r) for r in mk()]
        dis.drain()
        assert all(h.done and h.state is RequestState.FINISHED
                   for h in got)
        for a, b in zip(want, got):
            assert a.tokens == b.tokens
        s = dis.stats()
        assert s["handoff"]["handoffs"] + s["spilled_prefills"] \
            == len(spec)
        assert s["handoff"]["handoffs"] >= 1
        _check_pools(*dis.engines)

    def test_spillback_when_prefill_saturated(self, small_model):
        """spill_factor below any realizable pressure ratio forces
        every submit after the first onto the decode tier (an *idle*
        prefill tier, pressure 0, never counts as saturated) — spilled
        requests run monolithically there with no handoff."""
        dis = make_disagg(small_model, spill_factor=1e-9)
        hs = [dis.submit(Request(input_len=10, output_len=4,
                                 adapter_id=i % 4)) for i in range(5)]
        assert dis.n_spilled == 4        # only the idle-tier submit stayed
        dis.drain()
        assert all(h.done for h in hs)
        assert dis.handoff.n_begun == 1
        # Spilled requests landed on decode replicas.
        assert sum(len(e.records) for e in dis.decode) >= 4

    def test_rank_aware_decode_homes_spread(self, small_model):
        """Fresh adapters home by cumulative resident-rank load, so
        the first two distinct adapters land on different replicas."""
        dis = make_disagg(small_model)
        r1 = Request(input_len=8, output_len=2, adapter_id=0)
        r2 = Request(input_len=8, output_len=2, adapter_id=1)
        h1 = dis._decode_home(r1)
        h2 = dis._decode_home(r2)
        assert h1 is not h2
        # Sticky: the same adapter keeps its home.
        assert dis._decode_home(r1) is h1

    def test_protocol_conformance_and_factory(self, small_model):
        cfg, params = small_model
        sys_ = build_system(
            tier="disagg", model_cfg=cfg, params=params,
            ecfg=EngineConfig(max_slots=4, max_len=128, n_lora_slots=4,
                              n_adapters=8, seed=0),
            n_nodes=3)
        assert isinstance(sys_, DisaggCluster)
        assert isinstance(sys_, ServingSystem)
        assert len(sys_.prefill) == 1 and len(sys_.decode) == 2
        h = sys_.submit(Request(input_len=12, output_len=4,
                                adapter_id=0))
        got = list(h.stream())
        assert len(got) == 4 and h.done

    def test_gauges_registered(self, small_model):
        from repro.serving.metrics import GAUGES
        dis = make_disagg(small_model)
        hs = [dis.submit(Request(input_len=20, output_len=4,
                                 adapter_id=i % 3)) for i in range(3)]
        dis.drain()
        assert all(h.done for h in hs)
        merged, per = dis.metrics()
        live = set(merged.cache_stats) | set(merged.sched_stats)
        for m in per:
            live |= set(m.cache_stats) | set(m.sched_stats)
        missing = live - set(GAUGES)
        assert not missing, f"unregistered gauges: {sorted(missing)}"


class TestRoleAutoscaler:
    def test_plan_follows_demand(self):
        asc = RoleAutoscaler()
        for _ in range(8):
            asc.observe(prefill_tokens=4000.0, decode_tokens=100.0)
        plan = asc.plan(1, 3)
        assert plan["want_prefill"] > 1
        assert plan["want_prefill"] + plan["want_decode"] == 4
        assert plan["prefill_plan"].n_devices == plan["want_prefill"]
        for _ in range(16):
            asc.observe(prefill_tokens=10.0, decode_tokens=5000.0)
        plan = asc.plan(2, 2)
        assert plan["want_prefill"] == 1 and plan["want_decode"] == 3

    def test_apply_moves_idle_replica(self, small_model):
        dis = make_disagg(small_model, n_prefill=1, n_decode=2,
                          autoscale_apply=True)
        # Decode-heavy forever: the planner wants prefill at the
        # 1-replica floor, so no move happens from (1, 2)...
        dis.autoscaler.observe(10.0, 5000.0)
        dis.last_role_plan = dis.autoscaler.plan(1, 2)
        dis._maybe_rebalance()
        assert len(dis.prefill) == 1 and dis.n_rebalances == 0
        # ...while a prefill-heavy plan pulls an idle decode replica
        # over and rebuilds the prefill router.
        for _ in range(8):
            dis.autoscaler.observe(5000.0, 10.0)
        dis.last_role_plan = dis.autoscaler.plan(1, 2)
        assert dis.last_role_plan["want_prefill"] == 2
        dis._maybe_rebalance()
        assert len(dis.prefill) == 2 and len(dis.decode) == 1
        assert dis.router.n == 2
        assert dis.n_rebalances == 1
        # The shrunk decode tier still serves correctly.
        h = dis.submit(Request(input_len=10, output_len=3, adapter_id=0))
        dis.drain()
        assert h.done and len(h.tokens) == 3


class TestChunkedPrefill:
    """Chunked prefill on a monolithic engine (the disagg benchmark's
    other arm): token parity with chunk=0 and clean cancellation
    mid-chunk."""

    def test_token_parity_with_monolithic_prefill(self, small_model):
        spec = [(25, 8, 0), (6, 5, 1), (40, 6, 2), (10, 4, 0),
                (33, 7, 3)]

        def run(chunk):
            eng = make_engine(small_model, prefill_chunk_tokens=chunk)
            rng = np.random.default_rng(11)
            hs = [eng.submit(Request(input_len=L, output_len=O,
                                     adapter_id=a,
                                     prompt=_prompt(rng, L)))
                  for L, O, a in spec]
            eng.drain()
            assert all(h.done for h in hs)
            return [h.tokens for h in hs], eng

        want, _ = run(0)
        got, eng = run(8)
        assert got == want
        assert eng.n_chunked_prefills > 0
        _check_pools(eng)

    def test_cancel_mid_chunk(self, small_model):
        eng = make_engine(small_model, prefill_chunk_tokens=4)
        req = Request(input_len=60, output_len=8, adapter_id=0)
        h = eng.submit(req)
        for _ in range(200):
            eng.step()
            if eng._chunked:
                break
        assert eng._chunked
        assert h.cancel()
        eng.drain()
        assert req.state is RequestState.CANCELLED
        assert not eng._chunked
        _check_pools(eng)
        # The freed slot serves the next request.
        h2 = eng.submit(Request(input_len=8, output_len=3, adapter_id=1))
        eng.drain()
        assert h2.done and len(h2.tokens) == 3
