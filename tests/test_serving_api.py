"""The unified serving surface (DESIGN §3): ServingSystem conformance
across all three tiers, handle lifecycle, cancellation, deadlines,
sampling determinism, and squash continuity.

The conformance section runs the *same* assertions against the DES
node, the real JAX engine and the real-engine cluster: submit returns a
RequestHandle, the streamed tokens equal the system's internal output
record, cancellation is clean, and the latency breakdown is coherent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Request, RequestState, SamplingParams
from repro.models import api
from repro.serving import build_system
from repro.serving.engine import ChameleonEngine, EngineConfig
from repro.serving.handles import (RequestHandle, RequestResult,
                                   ServingSystem)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


ECFG = dict(max_slots=4, max_len=128, n_lora_slots=4, n_adapters=8)

TIERS = ("sim", "engine", "cluster")


def make_system(tier, small_model, **ekw):
    cfg, params = small_model
    if tier == "sim":
        return build_system("chameleon", tier="sim")
    e = EngineConfig(**{**ECFG, **ekw})
    return build_system("chameleon", tier=tier, model_cfg=cfg,
                        params=params, ecfg=e)


def output_record(system, req_id):
    """The system's internal token record for one request."""
    if hasattr(system, "engines"):            # EngineCluster
        for e in system.engines:
            if req_id in e.outputs:
                return e.outputs[req_id]
        return None
    return system.outputs.get(req_id)


# ------------------------------------------------------------------
# Conformance: identical assertions against every tier
# ------------------------------------------------------------------
@pytest.mark.parametrize("tier", TIERS)
class TestServingSystemConformance:
    def test_protocol_and_handle(self, tier, small_model):
        sys_ = make_system(tier, small_model)
        assert isinstance(sys_, ServingSystem)
        h = sys_.submit(Request(input_len=8, output_len=4, adapter_id=0))
        assert isinstance(h, RequestHandle)
        assert h.state in (RequestState.QUEUED, RequestState.LOADING)
        assert sys_.busy()
        sys_.drain()
        assert not sys_.busy()
        assert h.state is RequestState.FINISHED

    def test_stream_equals_internal_record(self, tier, small_model):
        sys_ = make_system(tier, small_model)
        seen = []
        h = sys_.submit(Request(input_len=10, output_len=6, adapter_id=1),
                        on_token=seen.append)
        streamed = list(h.stream())
        assert len(streamed) == 6
        assert streamed == seen == h.tokens
        assert streamed == output_record(sys_, h.req_id)

    def test_cancel_queued_is_clean(self, tier, small_model):
        sys_ = make_system(tier, small_model)
        keep = sys_.submit(Request(input_len=8, output_len=4,
                                   adapter_id=2))
        doomed = sys_.submit(Request(input_len=8, output_len=50,
                                     adapter_id=3))
        assert doomed.cancel()
        assert not doomed.cancel()      # already terminal
        sys_.drain()
        assert doomed.state is RequestState.CANCELLED
        assert doomed.tokens == [] or doomed.state is RequestState.CANCELLED
        assert keep.state is RequestState.FINISHED

    def test_result_latency_breakdown(self, tier, small_model):
        sys_ = make_system(tier, small_model)
        h = sys_.submit(Request(input_len=8, output_len=5, adapter_id=4))
        res = h.result()
        assert isinstance(res, RequestResult)
        assert res.finished and res.n_tokens == 5
        assert res.queue_wait is not None and res.queue_wait >= 0
        assert res.adapter_load_wait >= 0
        assert res.ttft is not None and res.ttft >= res.queue_wait
        assert res.e2e is not None and res.e2e >= res.ttft

    def test_queue_pressure_and_stats(self, tier, small_model):
        sys_ = make_system(tier, small_model)
        assert sys_.queue_pressure() == 0.0
        sys_.submit(Request(input_len=8, output_len=4, adapter_id=5))
        assert sys_.queue_pressure() > 0.0
        assert isinstance(sys_.stats(), dict)
        sys_.drain()
        assert sys_.metrics() is not None


# ------------------------------------------------------------------
# Engine-tier lifecycle details
# ------------------------------------------------------------------
class TestHandleLifecycle:
    def test_states_move_forward_only(self, small_model):
        eng = make_system("engine", small_model)
        h = eng.submit(Request(input_len=8, output_len=6, adapter_id=0))
        order = [RequestState.QUEUED, RequestState.LOADING,
                 RequestState.RUNNING, RequestState.FINISHED]
        seen = [h.state]
        while eng.busy():
            eng.step()
            if h.state is not seen[-1]:
                seen.append(h.state)
        assert seen[-1] is RequestState.FINISHED
        ranks = [order.index(s) for s in seen]
        assert ranks == sorted(ranks), seen

    def test_cancel_running(self, small_model):
        eng = make_system("engine", small_model)
        h = eng.submit(Request(input_len=8, output_len=60, adapter_id=0))
        first = next(h.stream())        # pump until it streams
        assert h.state is RequestState.RUNNING
        assert h.cancel()
        eng.drain()
        assert h.state is RequestState.CANCELLED
        assert h.tokens[0] == first and len(h.tokens) < 60
        eng.pool.check_invariants()
        assert eng.pool.used_requests == 0
        assert eng.stats()["cancelled"] == 1

    def test_cancel_on_final_token_honours_contract(self, small_model):
        """cancel() returning True promises CANCELLED — even when the
        cancel is issued by the on_token callback that delivered the
        request's final token."""
        eng = make_system("engine", small_model)
        holder = {}

        def cancel_self(_tok):
            if len(holder["h"].tokens) + 1 >= 4:   # the final token
                assert holder["h"].cancel()
        holder["h"] = eng.submit(
            Request(input_len=8, output_len=4, adapter_id=0),
            on_token=cancel_self)
        eng.drain()
        assert holder["h"].state is RequestState.CANCELLED
        assert eng.stats()["cancelled"] == 1
        eng.pool.check_invariants()
        assert eng.pool.used_requests == 0

    def test_sim_cancel_from_callback_mid_batch(self, small_model):
        """A cancel issued from inside an on_token callback against a
        co-batched request must not corrupt the DES iteration."""
        sim = make_system("sim", small_model)
        handles = []

        def chain_cancel(_tok):
            for h in handles[1:]:
                h.cancel()
        handles.append(sim.submit(
            Request(input_len=50, output_len=8, adapter_id=0),
            on_token=chain_cancel))
        handles.extend(sim.submit(
            Request(input_len=50, output_len=8, adapter_id=i))
            for i in range(1, 4))
        sim.drain()
        assert handles[0].state is RequestState.FINISHED
        assert all(h.state is RequestState.CANCELLED
                   for h in handles[1:])
        sim.pool.check_invariants()
        assert sim.pool.used_requests == 0

    def test_cancel_loading_deferred(self, small_model):
        """Cancel while the adapter's H2D transfer is in flight: the
        pin is released, the entry stays consistent, and the engine
        keeps serving other requests."""
        eng = make_system("engine", small_model, h2d_gbps=1e-4)
        h = eng.submit(Request(input_len=8, output_len=6, adapter_id=7))
        for _ in range(200):
            eng.step()
            if h.state is RequestState.LOADING:
                break
        assert h.state is RequestState.LOADING
        assert h.cancel()
        assert h.state is RequestState.CANCELLED
        entry = eng.cache.entries.get(7)
        assert entry is not None and entry.ref_count == 0
        other = eng.submit(Request(input_len=8, output_len=4,
                                   adapter_id=0))
        eng.flush_loads()
        eng.drain()
        assert other.state is RequestState.FINISHED
        eng.pool.check_invariants()

    def test_deadline_expiry_under_load(self, small_model):
        """With the batch saturated, queued requests whose TTL lapses
        are reaped by the scheduler; running ones finish normally."""
        eng = make_system("engine", small_model, max_slots=2)
        heads = [eng.submit(Request(input_len=8, output_len=30,
                                    adapter_id=i)) for i in range(2)]
        tails = [eng.submit(Request(input_len=8, output_len=4,
                                    adapter_id=2 + i), ttl=1e-3)
                 for i in range(3)]
        eng.drain()
        assert all(h.state is RequestState.FINISHED for h in heads)
        assert all(h.state is RequestState.EXPIRED for h in tails)
        assert eng.stats()["expired"] == 3
        eng.pool.check_invariants()
        assert eng.pool.used_requests == 0

    def test_running_deadline_enforced_in_step_loop(self, small_model):
        eng = make_system("engine", small_model)
        h = eng.submit(Request(input_len=8, output_len=500, adapter_id=0),
                       ttl=0.3)
        eng.drain()
        assert h.state is RequestState.EXPIRED
        assert 0 < len(h.tokens) < 500
        eng.pool.check_invariants()

    def test_stop_tokens_finish_early(self, small_model):
        eng = make_system("engine", small_model)
        ref = eng.submit(Request(input_len=8, output_len=20,
                                 adapter_id=1)).result().tokens
        stop = ref[4]
        r = Request(input_len=8, output_len=20, adapter_id=1)
        res = eng.submit(r, sampling=SamplingParams(
            stop_token_ids=(stop,))).result()
        assert res.finished
        assert res.tokens == ref[:5]    # stop token kept, then done

    def test_max_new_tokens_caps_decode(self, small_model):
        eng = make_system("engine", small_model)
        res = eng.submit(Request(input_len=8, output_len=30, adapter_id=2),
                         sampling=SamplingParams(max_new_tokens=7)
                         ).result()
        assert res.finished and res.n_tokens == 7

    def test_real_prompt_tokens_change_output(self, small_model):
        """The engine consumes real prompt ids: different prompts of
        the same length through the same adapter decode differently."""
        eng = make_system("engine", small_model)
        a = eng.submit(Request(input_len=8, output_len=6, adapter_id=0,
                               prompt=[1, 2, 3, 4, 5, 6, 7, 8])).result()
        b = eng.submit(Request(input_len=8, output_len=6, adapter_id=0,
                               prompt=[9, 10, 11, 12, 13, 14, 15, 16])
                       ).result()
        assert a.tokens != b.tokens


# ------------------------------------------------------------------
# Sampling: seeded determinism across data planes and backends
# ------------------------------------------------------------------
class TestSamplingDeterminism:
    SP = SamplingParams(temperature=0.8, top_k=20, top_p=0.95, seed=123)

    def _decode(self, small_model, **ekw):
        eng = make_system("engine", small_model, **ekw)
        reqs = [Request(input_len=8 + i, output_len=6, adapter_id=i,
                        sampling=self.SP) for i in range(3)]
        outs = [eng.submit(r).result().tokens for r in reqs]
        return outs

    def test_same_seed_same_tokens_across_runs(self, small_model):
        assert self._decode(small_model) == self._decode(small_model)

    def test_seed_determinism_across_paged_and_dense(self, small_model):
        paged = self._decode(small_model, paged=True)
        dense = self._decode(small_model, paged=False)
        assert paged == dense

    def test_seed_determinism_across_lora_backends(self, small_model):
        einsum = self._decode(small_model, lora_backend="einsum")
        kernel = self._decode(small_model, lora_backend="kernel")
        assert einsum == kernel

    def test_different_seeds_differ(self, small_model):
        eng = make_system("engine", small_model)
        t1 = eng.submit(Request(input_len=8, output_len=8, adapter_id=0),
                        sampling=SamplingParams(temperature=1.0, seed=1)
                        ).result().tokens
        t2 = eng.submit(Request(input_len=8, output_len=8, adapter_id=0),
                        sampling=SamplingParams(temperature=1.0, seed=2)
                        ).result().tokens
        assert t1 != t2

    def test_greedy_default_matches_explicit_greedy(self, small_model):
        """SamplingParams() is greedy argmax — the pre-redesign engine
        behaviour, token for token."""
        eng = make_system("engine", small_model)
        a = eng.submit(Request(input_len=10, output_len=8, adapter_id=3)
                       ).result().tokens
        b = eng.submit(Request(input_len=10, output_len=8, adapter_id=3),
                       sampling=SamplingParams()).result().tokens
        assert a == b

    def test_invalid_params_rejected(self, small_model):
        with pytest.raises(ValueError):
            SamplingParams(temperature=-1.0)
        with pytest.raises(ValueError):
            SamplingParams(top_p=0.0)
        with pytest.raises(ValueError):
            SamplingParams(top_k=-2)


# ------------------------------------------------------------------
# Squash continuity: streamed prefix survives preemption/requeue
# ------------------------------------------------------------------
class TestSquashContinuity:
    def test_preemption_preserves_stream(self, small_model):
        """Force an out-of-pages preemption mid-decode: the handle's
        stream must keep its prefix (no rewind, no duplicates) and the
        final tokens must equal an unpreempted run."""
        cfg, params = small_model
        ref_eng = ChameleonEngine(cfg, params, EngineConfig(**ECFG))
        # Sized so a third KV page is still unallocated when the pool
        # is drained below: the fused loop decodes in multi-token
        # horizons, so by the time the caller has consumed 4 tokens
        # the engine may already hold every page a 2-page request
        # needs (output 24 + input 8 = exactly 2 pages of 16).
        spec = dict(input_len=8, output_len=40, adapter_id=0)
        ref = ref_eng.submit(Request(**spec)).result().tokens

        eng = ChameleonEngine(cfg, params, EngineConfig(**ECFG))
        seen = []
        h = eng.submit(Request(**spec), on_token=seen.append)
        it = h.stream()
        for _ in range(4):              # stream a prefix...
            next(it)
        prefix = list(h.tokens)
        stolen, eng.free_pages = eng.free_pages, []   # ...then preempt
        for _ in range(20):
            eng.step()
            if eng.n_preempted:
                break
        assert eng.n_preempted >= 1
        at_squash = list(h.tokens)
        assert at_squash[:len(prefix)] == prefix, \
            "stream must not rewind on squash"
        eng.free_pages = stolen
        eng.drain()
        assert h.state is RequestState.FINISHED
        assert h.tokens[:len(at_squash)] == at_squash
        assert h.tokens == seen == ref
        assert h.req.squash_count >= 1
        res = h.result()
        assert res.ttft is not None     # TTFT kept from the first pass

    def test_requeue_keeps_first_token_time(self, small_model):
        cfg, params = small_model
        eng = ChameleonEngine(cfg, params, EngineConfig(**ECFG))
        h = eng.submit(Request(input_len=8, output_len=30, adapter_id=0))
        next(h.stream())
        t_first = h.req.first_token_time
        stolen, eng.free_pages = eng.free_pages, []
        for _ in range(20):
            eng.step()
            if eng.n_preempted:
                break
        eng.free_pages = stolen
        eng.drain()
        assert h.req.first_token_time == t_first
