"""shard_map all-to-all MoE == einsum MoE (uses 1 host device mesh;
the 4-shard variant is covered in the dry-run at 256 devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_block, moe_block_gather
from repro.models.moe_shard_map import moe_block_a2a


@pytest.fixture(scope="module")
def operands():
    key = jax.random.PRNGKey(0)
    B, S, D, E, F = 4, 64, 32, 8, 16
    ks = jax.random.split(key, 5)
    return (jax.random.normal(ks[0], (B, S, D), jnp.float32),
            jax.random.normal(ks[1], (D, E)) * 0.1,
            jax.random.normal(ks[2], (E, D, F)) * 0.1,
            jax.random.normal(ks[3], (E, D, F)) * 0.1,
            jax.random.normal(ks[4], (E, F, D)) * 0.1)


def test_a2a_matches_einsum_dispatch(operands):
    x, rw, wg, wu, wd = operands
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    with mesh:
        y_ref, _ = moe_block(x, rw, wg, wu, wd, top_k=2,
                             capacity_factor=8.0, group_size=64)
        y, _ = jax.jit(lambda *a: moe_block_a2a(
            *a, top_k=2, capacity_factor=8.0, mesh=mesh,
            group_size=64))(x, rw, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_a2a_dropless_decode_matches_gather(operands):
    x, rw, wg, wu, wd = operands
    x1 = x[:, :1]                      # decode: S == 1
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    E, K = 8, 2
    with mesh:
        y_ref, _ = moe_block_gather(x1, rw, wg, wu, wd, top_k=K)
        y, _ = jax.jit(lambda *a: moe_block_a2a(
            *a, top_k=K, capacity_factor=E / K, mesh=mesh,
            group_size=64))(x1, rw, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
