"""Unit tests: WRS, K-means queue selection, M/M/1 quotas, predictor."""
import numpy as np
import pytest

from repro.core import (HistogramPredictor, NoisyOraclePredictor,
                        OutputOnlyCalculator, QueueStats, WRSCalculator,
                        assign_quotas, bucket_of, choose_queues, kmeans_1d,
                        measure_accuracy, queue_index, tok_min)


class TestWRS:
    def test_paper_weights(self):
        c = WRSCalculator()
        assert (c.w.a_input, c.w.b_output, c.w.c_adapter) == (0.3, 0.5, 0.2)

    def test_monotone_in_each_factor(self):
        c = WRSCalculator(max_input=1000, max_output=1000, max_adapter=1000)
        base = c.wrs(100, 100, 100)
        assert c.wrs(200, 100, 100) > base
        assert c.wrs(100, 200, 100) > base
        assert c.wrs(100, 100, 200) > base

    def test_bounded_01(self):
        c = WRSCalculator(max_input=10, max_output=10, max_adapter=10)
        for i, o, a in [(1, 1, 1), (10, 10, 10), (100, 100, 100)]:
            assert 0.0 <= c.wrs(i, o, a) <= 1.0 + 1e-9

    def test_output_only_ignores_input_and_adapter(self):
        def fresh():
            return OutputOnlyCalculator(max_input=100, max_output=100,
                                        max_adapter=100)
        assert fresh().wrs(1, 50, 1) == fresh().wrs(99, 50, 99)


class TestKMeans:
    def test_two_clear_clusters(self):
        v = np.concatenate([np.random.default_rng(0).normal(0.1, 0.01, 100),
                            np.random.default_rng(1).normal(0.9, 0.01, 100)])
        k, cents, cuts = choose_queues(v, k_max=4)
        assert k >= 2
        assert len(cuts) == k - 1
        assert 0.1 < cuts[0] < 0.9

    def test_homogeneous_collapses_to_one_queue(self):
        v = np.full(100, 0.5) + np.random.default_rng(0).normal(0, 1e-4, 100)
        k, _, cuts = choose_queues(v, k_max=4)
        assert k == 1 and len(cuts) == 0

    def test_k_max_respected(self):
        v = np.random.default_rng(0).uniform(0, 1, 500)
        k, _, _ = choose_queues(v, k_max=4)
        assert 1 <= k <= 4

    def test_queue_index_binning(self):
        cuts = np.array([0.3, 0.6])
        assert queue_index(0.1, cuts) == 0
        assert queue_index(0.4, cuts) == 1
        assert queue_index(0.9, cuts) == 2

    def test_wcss_decreases_with_k(self):
        v = np.random.default_rng(0).uniform(0, 1, 300)
        w = [kmeans_1d(v, k)[1] for k in (1, 2, 3, 4)]
        assert all(w[i] >= w[i + 1] - 1e-9 for i in range(3))


class TestQuotas:
    def test_tok_min_formula(self):
        q = QueueStats(max_size=100, duration=2.0, arrival_rate=3.0, slo=5.0)
        assert tok_min(q) == pytest.approx(100 * 2.0 * (1 / 5.0 + 3.0))

    def test_quotas_sum_to_total(self):
        queues = [QueueStats(50, 1.0, 2.0, 5.0),
                  QueueStats(500, 4.0, 0.5, 5.0)]
        quotas = assign_quotas(queues, total_tokens=10000)
        assert sum(quotas) == 10000

    def test_busier_queue_gets_more(self):
        queues = [QueueStats(100, 1.0, 10.0, 5.0),
                  QueueStats(100, 1.0, 0.1, 5.0)]
        q = assign_quotas(queues, total_tokens=10000)
        assert q[0] > q[1]

    def test_overload_scales_down(self):
        queues = [QueueStats(10000, 10.0, 100.0, 1.0),
                  QueueStats(10000, 10.0, 100.0, 1.0)]
        q = assign_quotas(queues, total_tokens=1000)
        assert sum(q) <= 1000 and min(q) >= 1


class TestPredictor:
    def test_perfect_oracle(self):
        p = NoisyOraclePredictor(accuracy=1.0, seed=0)
        assert p.predict(10, 0, 123) == 123

    def test_accuracy_is_calibrated(self):
        for target in (0.6, 0.8):
            p = NoisyOraclePredictor(accuracy=target, seed=1)
            rng = np.random.default_rng(2)
            pairs = [(10, 0, int(rng.integers(1, 512))) for _ in range(3000)]
            acc = measure_accuracy(p, pairs)
            assert abs(acc - target) < 0.05, (target, acc)

    def test_histogram_learns_adapter_length(self):
        p = HistogramPredictor()
        for _ in range(50):
            p.observe(adapter_id=1, true_output=100)
            p.observe(adapter_id=2, true_output=4)
        assert bucket_of(p.predict(10, 1)) == bucket_of(100)
        assert bucket_of(p.predict(10, 2)) == bucket_of(4)

    def test_histogram_cold_start_uses_global(self):
        p = HistogramPredictor()
        for _ in range(10):
            p.observe(adapter_id=1, true_output=64)
        assert bucket_of(p.predict(10, 999)) == bucket_of(64)
