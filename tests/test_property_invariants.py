"""Property-based tests (hypothesis) for system invariants.

The control plane (pool/cache/scheduler) must maintain its invariants
under *any* interleaving of request arrivals, batch formation steps,
token generation, and completions — these are the invariants the
serving engine and simulator rely on.
"""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # no network in CI containers: shim it
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import (AdapterCache, AdapterInfo, ChameleonScheduler,
                        MemoryPool, NoisyOraclePredictor, Request,
                        RequestState)
from repro.core.kmeans import choose_queues, queue_index
from repro.core.quotas import QueueStats, assign_quotas
from repro.serving.cost_model import CostModel


def make_world(capacity, adapter_sizes, seed=0):
    pool = MemoryPool(capacity_tokens=capacity)
    catalog = {i: AdapterInfo(adapter_id=i, rank=8, size_bytes=s * 100,
                              size_tokens=s)
               for i, s in enumerate(adapter_sizes)}
    cache = AdapterCache(pool, catalog)
    pred = NoisyOraclePredictor(accuracy=0.8, seed=seed)
    sched = ChameleonScheduler(pool, cache, catalog, pred,
                               t_refresh=5.0, refresh_min_samples=8)
    return pool, cache, sched


req_strategy = st.tuples(
    st.integers(1, 60),      # input_len
    st.integers(1, 40),      # output_len
    st.integers(0, 5),       # adapter_id
)


class TestSchedulerInvariants:
    @given(reqs=st.lists(req_strategy, min_size=1, max_size=60),
           capacity=st.integers(300, 3000))
    @settings(max_examples=40, deadline=None)
    def test_full_lifecycle_conserves_everything(self, reqs, capacity):
        """Submit all → run scheduling/decode rounds to completion.

        Invariants checked every round:
          - pool accounting (non-negative, bounded, exact);
          - per-queue quota usage == sum of outstanding charges;
          - a request is never in two places;
          - at drain: zero request holds, zero quota used, all requests
            FINISHED exactly once.
        """
        pool, cache, sched = make_world(capacity, [10, 10, 20, 20, 40, 40])
        requests = [Request(input_len=i, output_len=o, adapter_id=a,
                            arrival_time=0.0) for i, o, a in reqs]
        for r in requests:
            sched.submit(r, 0.0)
        running: list[Request] = []
        finished: list[Request] = []
        now = 0.0
        for _ in range(3000):
            now += 0.1
            admitted = sched.schedule(now, running)
            for r in admitted:
                assert r not in running
                running.append(r)
            pool.check_invariants()
            charged = sum(t for r in running for _, t in r.charges)
            used = sum(q.used for q in sched.queues)
            assert used == charged, (used, charged)
            # one decode round
            done = []
            for r in running:
                r.generated += 1
                if r.generated >= r.output_len:
                    done.append(r)
                elif r.bypassed and r.exceeded_prediction():
                    done.append(r)   # squash path
            for r in done:
                running.remove(r)
                if r.generated >= r.output_len:
                    r.state = RequestState.FINISHED
                    sched.on_finish(r, now)
                    finished.append(r)
                else:
                    sched.on_squash(r, now)
            if not running and sched.pending_count() == 0:
                break
        assert len(finished) == len(requests)
        assert pool.used_requests == 0
        assert sum(q.used for q in sched.queues) == 0
        pool.check_invariants()

    @given(reqs=st.lists(req_strategy, min_size=4, max_size=40))
    @settings(max_examples=25, deadline=None)
    def test_quota_never_negative_and_bounded(self, reqs):
        pool, cache, sched = make_world(2000, [10] * 6)
        now = 0.0
        for i, (inp, out, a) in enumerate(reqs):
            sched.submit(Request(input_len=inp, output_len=out,
                                 adapter_id=a), now)
            if i % 3 == 2:
                sched.maybe_refresh(now)
                sched.schedule(now, [])
            now += 1.0
        for q in sched.queues:
            assert q.used >= 0


class TestCacheInvariants:
    @given(ops=st.lists(st.tuples(st.sampled_from(["acq", "rel", "pre"]),
                                  st.integers(0, 4)),
                        min_size=1, max_size=200))
    @settings(max_examples=40, deadline=None)
    def test_random_ops_never_corrupt_pool(self, ops):
        pool = MemoryPool(capacity_tokens=100)
        catalog = {i: AdapterInfo(adapter_id=i, rank=8, size_bytes=2000,
                                  size_tokens=20) for i in range(5)}
        cache = AdapterCache(pool, catalog)
        pinned: dict[int, int] = {}
        now = 0.0
        for op, aid in ops:
            now += 1.0
            try:
                if op == "acq":
                    cache.acquire(aid, now)
                    pinned[aid] = pinned.get(aid, 0) + 1
                elif op == "rel" and pinned.get(aid, 0) > 0:
                    cache.release(aid, now)
                    pinned[aid] -= 1
                elif op == "pre":
                    cache.prefetch(aid, now)
            except Exception:
                pass     # PoolError is legal when over-pinned
            pool.check_invariants()
            assert pool.used_adapters == cache.resident_tokens()
            # Pinned adapters must stay resident.
            for a, c in pinned.items():
                if c > 0:
                    assert cache.resident(a), f"pinned {a} evicted!"


class TestPagedPoolInvariants:
    @given(ops=st.lists(
        st.tuples(st.sampled_from(["grow", "shrink", "release",
                                   "hold", "drop"]),
                  st.integers(0, 4),      # req / adapter id
                  st.integers(1, 6)),     # pages (or adapter tokens x10)
        min_size=1, max_size=200),
        page_size=st.sampled_from([4, 8, 16]))
    @settings(max_examples=40, deadline=None)
    def test_paged_churn_preserves_invariants(self, ops, page_size):
        """Random page grow/shrink/release interleaved with adapter
        holds/drops (the paged engine's churn): accounting stays exact,
        request holds stay page-multiples, capacity is never exceeded."""
        pool = MemoryPool(capacity_tokens=240, page_size=page_size)
        pages_held: dict[int, int] = {}
        for op, rid, n in ops:
            try:
                if op == "grow":
                    pool.reserve_request_pages(rid, n)
                    pages_held[rid] = pages_held.get(rid, 0) + n
                elif op == "shrink":
                    give = min(n, pages_held.get(rid, 0))
                    pool.shrink_request(rid, give * page_size)
                    if pages_held.get(rid) is not None:
                        pages_held[rid] -= give
                        if pages_held[rid] == 0:
                            del pages_held[rid]
                elif op == "release":
                    pool.release_request(rid)
                    pages_held.pop(rid, None)
                elif op == "hold":
                    pool.hold_adapter(rid, n * 10)
                elif op == "drop":
                    pool.drop_adapter(rid)
            except Exception:
                pass        # PoolError is legal when over-committed
            pool.check_invariants()
            assert pool.used_requests == \
                sum(pages_held.values()) * page_size
            for rid_, p in pages_held.items():
                assert pool.request_pages(rid_) == p
            assert pool.free_pages * page_size <= pool.free_tokens

    @given(ops=st.lists(
        st.tuples(st.sampled_from(["grow", "adopt", "ref", "unref",
                                   "release", "shrink", "hold", "drop"]),
                  st.integers(0, 4),      # req / adapter / page pick
                  st.integers(1, 6)),     # pages (or adapter tokens x10)
        min_size=1, max_size=200),
        page_size=st.sampled_from([4, 8, 16]))
    @settings(max_examples=200, deadline=None)
    def test_shared_refcount_churn_preserves_invariants(self, ops,
                                                        page_size):
        """The prefix-cache ledger under random interleavings of
        reserve/adopt(shrink→add_shared)/share/release_shared against a
        shadow refcount model: counts agree exactly, pages free exactly
        when the last reference drops, and the pool's own invariants
        (conservation, page-multiples, no zero holds) never break."""
        pool = MemoryPool(capacity_tokens=240, page_size=page_size)
        pages_held: dict[int, int] = {}
        refs: dict[int, int] = {}       # shadow model: page -> refcount
        next_pid = 100
        for op, rid, n in ops:
            try:
                if op == "grow":
                    pool.reserve_request_pages(rid, n)
                    pages_held[rid] = pages_held.get(rid, 0) + n
                elif op == "adopt" and pages_held.get(rid, 0) > 0:
                    # The engine's adoption transaction: a full page
                    # moves from the request ledger to the shared one.
                    pool.shrink_request(rid, page_size)
                    pid, next_pid = next_pid, next_pid + 1
                    pool.add_shared_page(pid)
                    refs[pid] = 1
                    pages_held[rid] -= 1
                    if pages_held[rid] == 0:
                        del pages_held[rid]
                elif op == "ref" and refs:
                    pid = sorted(refs)[rid % len(refs)]
                    pool.share_pages([pid])
                    refs[pid] += 1
                elif op == "unref" and refs:
                    pid = sorted(refs)[rid % len(refs)]
                    freed = pool.release_shared([pid])
                    refs[pid] -= 1
                    if refs[pid] == 0:
                        assert freed == [pid], (
                            "last release must free the page")
                        del refs[pid]
                    else:
                        assert freed == []
                elif op == "release":
                    pool.release_request(rid)
                    pages_held.pop(rid, None)
                elif op == "shrink":
                    give = min(n, pages_held.get(rid, 0))
                    pool.shrink_request(rid, give * page_size)
                    if pages_held.get(rid) is not None:
                        pages_held[rid] -= give
                        if pages_held[rid] == 0:
                            del pages_held[rid]
                elif op == "hold":
                    pool.hold_adapter(rid, n * 10)
                elif op == "drop":
                    pool.drop_adapter(rid)
            except Exception:
                pass        # PoolError is legal when over-committed
            pool.check_invariants()
            assert pool.used_requests == \
                sum(pages_held.values()) * page_size
            assert pool.used_shared == len(refs) * page_size
            assert pool.shared_page_ids() == set(refs)
            for pid, c in refs.items():
                assert pool.shared_refcount(pid) == c

    def test_shrink_boundaries(self):
        """shrink_request edges: non-multiples and over-shrinks raise
        without drifting the ledger, shrink-to-zero pops the hold, and
        a zero-token reserve never creates a phantom entry."""
        from repro.core import PoolError
        import pytest as _pytest
        pool = MemoryPool(capacity_tokens=64, page_size=8)
        pool.reserve_request_pages(1, 3)
        free0 = pool.free_tokens
        with _pytest.raises(PoolError):
            pool.shrink_request(1, 5)       # not a page multiple
        with _pytest.raises(PoolError):
            pool.shrink_request(1, 32)      # exceeds the 24-token hold
        with _pytest.raises(PoolError):
            pool.shrink_request(1, -8)
        assert pool.free_tokens == free0 and pool.request_pages(1) == 3
        pool.shrink_request(1, 8)
        assert pool.request_pages(1) == 2
        pool.shrink_request(1, 16)          # exactly to zero
        assert pool.request_pages(1) == 0
        assert pool.used_requests == 0 and pool.free_tokens == 64
        pool.reserve_request_pages(2, 0)    # zero-token reserve: no-op
        pool.reserve_request(3, 0)
        pool.check_invariants()             # asserts no zero-token holds
        assert pool.release_request(2) == 0
        assert pool.free_tokens == 64

    def test_non_page_multiple_hold_rejected(self):
        from repro.core import PoolError
        import pytest as _pytest
        pool = MemoryPool(capacity_tokens=64, page_size=8)
        with _pytest.raises(PoolError):
            pool.reserve_request(1, 12)
        pool.reserve_request_pages(1, 2)
        with _pytest.raises(PoolError):
            pool.shrink_request(1, 3)
        pool.shrink_request(1, 8)
        pool.check_invariants()
        assert pool.request_pages(1) == 1


class TestMathProperties:
    @given(v=st.lists(st.floats(0.0, 1.0), min_size=2, max_size=300))
    @settings(max_examples=40, deadline=None)
    def test_kmeans_cutoffs_partition_the_line(self, v):
        arr = np.asarray(v)
        k, cents, cuts = choose_queues(arr, k_max=4)
        assert 1 <= k <= 4
        assert len(cuts) == k - 1
        assert list(cuts) == sorted(cuts)
        for x in v:
            assert 0 <= queue_index(x, cuts) < k

    @given(n=st.integers(1, 4),
           total=st.integers(100, 100000),
           seed=st.integers(0, 100))
    @settings(max_examples=40, deadline=None)
    def test_quotas_partition_budget(self, n, total, seed):
        rng = np.random.default_rng(seed)
        queues = [QueueStats(max_size=float(rng.integers(10, 1000)),
                             duration=float(rng.uniform(0.1, 10)),
                             arrival_rate=float(rng.uniform(0, 20)),
                             slo=5.0) for _ in range(n)]
        q = assign_quotas(queues, total)
        assert sum(q) == total
        assert all(x >= 1 for x in q)

    @given(inp=st.integers(1, 2048), out=st.integers(1, 512),
           rank=st.sampled_from([8, 16, 32, 64, 128]))
    @settings(max_examples=40, deadline=None)
    def test_cost_model_monotone(self, inp, out, rank):
        cm = CostModel()
        t1 = cm.isolated_time(inp, out, rank)
        assert t1 > 0
        assert cm.isolated_time(inp + 64, out, rank) >= t1
        assert cm.isolated_time(inp, out + 16, rank) >= t1
        assert cm.isolated_ttft(inp, 128) >= cm.isolated_ttft(inp, 8)
