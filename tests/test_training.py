"""Training substrate tests: optimizer, checkpoint, recovery, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # no network in CI containers: shim it
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs import get_config
from repro.training import (AdamWConfig, AsyncCheckpointer, DataConfig,
                            Heartbeat, NodeFailure, StragglerDetector,
                            SyntheticLM, adamw_update,
                            compress_with_feedback, init_opt_state,
                            init_train_state, latest_step,
                            make_train_step, restore_checkpoint,
                            run_with_recovery, save_checkpoint, schedule)


class TestOptimizer:
    def setup_method(self):
        self.cfg = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=100)
        self.params = {"layers/w": jnp.ones((4, 4)),
                       "layers/norm": jnp.ones((4,))}

    def test_update_moves_params(self):
        opt = init_opt_state(self.params, self.cfg)
        grads = {k: jnp.ones_like(v) for k, v in self.params.items()}
        new_p, new_s, m = adamw_update(self.params, grads, opt, self.cfg)
        assert float(jnp.abs(new_p["layers/w"] - 1.0).max()) > 0
        assert int(new_s["step"]) == 1
        assert float(m["grad_norm"]) > 0

    def test_clipping_bounds_update(self):
        opt = init_opt_state(self.params, self.cfg)
        grads = {k: 1e6 * jnp.ones_like(v) for k, v in self.params.items()}
        new_p, _, m = adamw_update(self.params, grads, opt, self.cfg)
        assert np.isfinite(float(new_p["layers/w"].sum()))

    def test_no_decay_on_norms(self):
        cfg = AdamWConfig(lr=1e-2, weight_decay=10.0, warmup_steps=0)
        opt = init_opt_state(self.params, cfg)
        grads = {k: jnp.zeros_like(v) for k, v in self.params.items()}
        new_p, _, _ = adamw_update(self.params, grads, opt, cfg)
        np.testing.assert_allclose(np.asarray(new_p["layers/norm"]),
                                   np.ones(4))        # untouched
        assert float(new_p["layers/w"].max()) < 1.0   # decayed

    def test_bf16_moments(self):
        cfg = AdamWConfig(moment_dtype="bfloat16")
        opt = init_opt_state(self.params, cfg)
        assert opt["m/layers/w"].dtype == jnp.bfloat16

    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
        assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


class TestTrainLoop:
    def test_loss_decreases(self):
        cfg = get_config("internlm2-1.8b").reduced()
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)
        params, opt = init_train_state(cfg, ocfg, jax.random.PRNGKey(0),
                                       jnp.float32)
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=32, global_batch=8))
        step = jax.jit(make_train_step(cfg, ocfg))
        losses = []
        for i in range(30):
            b = data.batch(i)
            params, opt, m = step(params, opt,
                                  {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.3

    def test_data_deterministic_and_host_sharded(self):
        c = DataConfig(vocab_size=64, seq_len=16, global_batch=8,
                       n_hosts=2, host_id=0)
        a = SyntheticLM(c).batch(3)
        b = SyntheticLM(c).batch(3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        other = SyntheticLM(DataConfig(vocab_size=64, seq_len=16,
                                       global_batch=8, n_hosts=2,
                                       host_id=1)).batch(3)
        assert not np.array_equal(a["tokens"], other["tokens"])
        assert a["tokens"].shape == (4, 16)   # local batch


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        trees = {"params": {"layers/w": jnp.arange(6.0).reshape(2, 3)},
                 "opt": {"step": jnp.asarray(7)}}
        save_checkpoint(str(tmp_path), 7, trees, extra={"mesh": [2, 2]})
        step, out = restore_checkpoint(str(tmp_path))
        assert step == 7
        np.testing.assert_array_equal(np.asarray(out["params"]["layers/w"]),
                                      np.arange(6.0).reshape(2, 3))

    def test_latest_and_prune(self, tmp_path):
        for s in (1, 2, 3, 4):
            save_checkpoint(str(tmp_path), s,
                            {"params": {"w": jnp.zeros(2)}})
        assert latest_step(str(tmp_path)) == 4
        from repro.training import prune_checkpoints
        prune_checkpoints(str(tmp_path), keep=2)
        steps = sorted(os.listdir(tmp_path))
        assert steps == ["step_00000003", "step_00000004"]

    def test_async_checkpointer(self, tmp_path):
        ck = AsyncCheckpointer(str(tmp_path))
        ck.save(5, {"params": {"w": jnp.ones(3)}})
        ck.wait()
        step, out = restore_checkpoint(str(tmp_path))
        assert step == 5

    def test_elastic_restore_with_sharding(self, tmp_path):
        """Restore onto an explicit (single-device) sharding."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        save_checkpoint(str(tmp_path), 1,
                        {"params": {"w": jnp.arange(8.0)}})
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        sh = NamedSharding(mesh, P())
        step, out = restore_checkpoint(
            str(tmp_path), shardings={"params": {"w": sh}})
        assert out["params"]["w"].sharding == sh


class TestRecovery:
    def test_recovers_from_injected_failures(self, tmp_path):
        state = {"x": 0, "restores": 0}
        saved = {"x": 0, "step": 0}
        fail_at = {10, 25}

        def train_one(step):
            if step in fail_at:
                fail_at.discard(step)
                raise NodeFailure(host=3)
            state["x"] += 1
            return {"loss": 1.0 / (step + 1)}

        def save(step):
            saved.update(step=step, x=state["x"])

        def restore():
            state["x"] = saved["x"]
            state["restores"] += 1
            return saved["step"]

        out = run_with_recovery(train_one, save, restore, n_steps=40,
                                checkpoint_every=5)
        assert out["steps_done"] == 40
        assert out["recoveries"] == 2
        assert state["restores"] == 3   # initial + 2 failures

    def test_heartbeat_marks_dead(self):
        hb = Heartbeat(n_hosts=3, timeout=5.0)
        hb.beat(0, now=0.0)
        hb.beat(1, now=0.0)
        hb.beat(2, now=8.0)
        assert hb.dead_hosts(now=9.0) == [0, 1]

    def test_straggler_detection(self):
        sd = StragglerDetector(n_hosts=4, threshold=1.5)
        for _ in range(10):
            for h in range(3):
                sd.observe(h, 1.0)
            sd.observe(3, 3.0)
        assert sd.stragglers() == [3]


class TestCompression:
    def test_int8_roundtrip_error_small(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(
            size=(64, 64)).astype(np.float32))}
        _, deq, err = compress_with_feedback(g, None)
        rel = float(jnp.linalg.norm(deq["w"] - g["w"])
                    / jnp.linalg.norm(g["w"]))
        assert rel < 0.02

    def test_error_feedback_accumulates(self):
        g = {"w": jnp.full((8,), 1e-8, jnp.float32)}   # below 1 quantum
        _, deq, err = compress_with_feedback(g, None)
        # Tiny grads quantise to zero; the residual must carry them.
        assert float(jnp.abs(err["w"]).sum()) > 0

    @given(scale=st.floats(1e-4, 1e3))
    @settings(max_examples=20, deadline=None)
    def test_quantize_bounded_error(self, scale):
        from repro.training.compression import dequantize_int8, quantize_int8
        g = jnp.asarray(np.random.default_rng(1).normal(
            size=(128,)).astype(np.float32)) * scale
        q, s = quantize_int8(g)
        err = float(jnp.abs(dequantize_int8(q, s) - g).max())
        assert err <= float(s) * 0.5 + 1e-9
