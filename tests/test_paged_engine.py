"""Paged KV data plane: dense/paged parity, page accounting, preemption.

The paged engine must produce exactly the tokens the dense engine does
(the page table is a layout, not a policy), while the MemoryPool sees
*actual* page occupancy instead of the dense worst-case reservation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Request
from repro.models import api
from repro.serving.engine import ChameleonEngine, EngineConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def make_engine(small_model, paged, **kw):
    cfg, params = small_model
    defaults = dict(max_slots=4, max_len=128, n_lora_slots=4,
                    n_adapters=8, seed=0, paged=paged, page_size=16)
    defaults.update(kw)
    return ChameleonEngine(cfg, params, EngineConfig(**defaults))


def fixed_trace(n=12, seed=3, adapters=8):
    rng = np.random.default_rng(seed)
    return [(int(rng.integers(4, 30)), int(rng.integers(2, 20)),
             int(rng.integers(0, adapters))) for _ in range(n)]


def step_until(eng, cond, timeout_s=30.0):
    """Step until ``cond()`` — placement waits on the async adapter
    load, whose device write completes on its own wall-clock schedule,
    so the bound is time, not a step count."""
    import time
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        eng.step()
        if cond():
            return True
    return False


def run_checked(eng, reqs, max_steps=10_000):
    """Drain with pool invariants checked after every engine step."""
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.busy() and steps < max_steps:
        eng.step()
        eng.pool.check_invariants()
        steps += 1
    assert not eng.busy(), "engine failed to drain"


class TestPagedParity:
    def test_dense_paged_token_parity(self, small_model):
        """Greedy decode over a fixed trace: paged == dense, token for
        token, with pool invariants holding after every step."""
        specs = fixed_trace()
        outputs = {}
        for paged in (False, True):
            eng = make_engine(small_model, paged=paged)
            reqs = [Request(input_len=i, output_len=o, adapter_id=a)
                    for i, o, a in specs]
            run_checked(eng, reqs)
            assert eng.stats()["completed"] == len(specs)
            outputs[paged] = [eng.outputs[r.req_id] for r in reqs]
        assert outputs[True] == outputs[False], (
            "paged KV layout changed decoded tokens")

    def test_paged_flag_selects_data_plane(self, small_model):
        dense = make_engine(small_model, paged=False)
        paged = make_engine(small_model, paged=True)
        assert dense.kv is not None and not dense.paged
        assert paged.kv is None and paged.paged
        assert paged.pool.page_size == 16 and dense.pool.page_size == 1


class TestPagedAccounting:
    def test_pool_tracks_actual_pages(self, small_model):
        """Request holds equal allocated pages exactly, every step."""
        eng = make_engine(small_model, paged=True)
        reqs = [Request(input_len=i, output_len=o, adapter_id=a)
                for i, o, a in fixed_trace(8, seed=5)]
        for r in reqs:
            eng.submit(r)
        ps = eng.pool.page_size
        total = eng.n_pages - 1
        steps = 0
        while eng.busy() and steps < 10_000:
            eng.step()
            eng.pool.check_invariants(free_page_ids=eng.free_pages)
            # Prefix sharing (default-on) splits a slot's pages into
            # private (request ledger) and shared (tree ledger, maybe
            # mapped by several slots).
            shared = set(eng.pool.shared_page_ids())
            priv = sum(1 for plist in eng.slot_pages
                       for p in plist if p not in shared)
            assert eng.pool.used_requests == priv * ps
            assert len(eng.free_pages) + priv + len(shared) == total
            steps += 1

    def test_pages_freed_on_drain(self, small_model):
        eng = make_engine(small_model, paged=True)
        run_checked(eng, [Request(input_len=i, output_len=o, adapter_id=a)
                          for i, o, a in fixed_trace(6, seed=7)])
        assert eng.pool.used_requests == 0
        # Adopted prompt pages stay tree-resident after drain (warm
        # prefixes, like warm adapters); everything else is free.
        assert len(eng.free_pages) + eng.pool.n_shared_pages \
            == eng.n_pages - 1
        assert not eng.page_table.any()
        assert all(not p for p in eng.slot_pages)

    def test_holds_grow_with_decode_not_prediction(self, small_model):
        """The defining difference vs dense: a freshly placed request
        holds its prompt pages, not input + predicted output."""
        eng = make_engine(small_model, paged=True)
        r = Request(input_len=20, output_len=60, adapter_id=0)
        eng.submit(r)
        assert step_until(eng, lambda: r.req_id in eng.pool._request_holds)
        ps = eng.pool.page_size
        held = eng.pool._request_holds[r.req_id]
        assert held <= eng.pool.pages_for(20 + 2) * ps, (
            "paged hold must track actual KV, not the predicted "
            f"worst case (held {held})")
        eng.drain()
        assert eng.pool.used_requests == 0


class TestPreemption:
    def test_out_of_pages_preempts_and_recovers(self, small_model):
        """When no page can be allocated mid-decode the slot is
        preempted (squash path) and the request later re-executes."""
        eng = make_engine(small_model, paged=True)
        r = Request(input_len=8, output_len=60, adapter_id=0)
        eng.submit(r)
        # Placed once the async adapter load lands: 1 page covers
        # 8+8 toks.
        assert step_until(eng, lambda: eng.active.any())
        stolen, eng.free_pages = eng.free_pages, []
        for _ in range(20):              # decode crosses the page bound
            eng.step()
            eng.pool.check_invariants()
            if eng.n_preempted:
                break
        assert eng.n_preempted >= 1
        assert not eng.active.any()
        assert eng.sched.pending_count() >= 1, "preemptee requeued"
        eng.free_pages = stolen
        eng.drain()
        assert eng.stats()["completed"] == 1
        assert r.generated == r.output_len
        assert eng.pool.used_requests == 0

    def test_admission_rounds_to_pages(self, small_model):
        """A request admitted by the scheduler can always get its
        page-rounded prompt allocation: admission demand is rounded up
        to whole pages in paged mode, so a boundary-straddling prompt
        (17 tokens, 16-token pages) never churns admit -> bounce."""
        eng = make_engine(small_model, paged=True)
        r = Request(input_len=17, output_len=4, adapter_id=0)
        eng.submit(r)
        assert step_until(eng, lambda: eng.active.any()), (
            "prompt pages must follow admission")
        assert eng.n_preempted == 0
        eng.drain()
        assert eng.stats()["completed"] == 1

    def test_page_stats_exported(self, small_model):
        eng = make_engine(small_model, paged=True)
        run_checked(eng, [Request(input_len=12, output_len=4,
                                  adapter_id=0)])
        st = eng.kv_page_stats()
        assert st["kv_pages_total"] == eng.n_pages - 1
        assert st["kv_pages_used"] == 0          # drained
        m = eng.metrics()
        assert "kv_pages_total" in m.sched_stats
        assert m.sched_stats["batch_occupancy_mean"] > 0
