"""Pallas kernel validation: shape/dtype sweeps vs jnp oracles
(interpret mode — the kernel body executes on CPU), plus hypothesis
property tests for the packing/paging helpers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # no network in CI containers: shim it
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.bgmv import bgmv
from repro.kernels.paged_attention import paged_attention
from repro.kernels.sgmv import pack_segments, sgmv

KEY = jax.random.PRNGKey(7)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


class TestBGMV:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("Bt,din,r,dout,n", [
        (1, 128, 8, 128, 2),
        (4, 256, 16, 384, 6),
        (8, 512, 64, 512, 3),
        (5, 128, 128, 256, 10),     # rank 128 (paper's max)
    ])
    def test_matches_ref(self, Bt, din, r, dout, n, dtype):
        ks = jax.random.split(KEY, 4)
        x = jax.random.normal(ks[0], (Bt, din), dtype)
        A = (jax.random.normal(ks[1], (n, din, r)) * 0.05).astype(dtype)
        B = (jax.random.normal(ks[2], (n, r, dout)) * 0.05).astype(dtype)
        idx = jax.random.randint(ks[3], (Bt,), 0, n)
        y = bgmv(x, A, B, idx, out_tile=128, interpret=True)
        np.testing.assert_allclose(
            np.asarray(y, np.float32),
            np.asarray(ref.bgmv_ref(x, A, B, idx), np.float32),
            **tol(dtype))

    def test_all_same_adapter(self):
        ks = jax.random.split(KEY, 3)
        x = jax.random.normal(ks[0], (4, 128))
        A = jax.random.normal(ks[1], (3, 128, 8)) * 0.1
        B = jax.random.normal(ks[2], (3, 8, 128)) * 0.1
        idx = jnp.full((4,), 2)
        y = bgmv(x, A, B, idx, out_tile=128, interpret=True)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray((x @ A[2]) @ B[2]),
                                   rtol=1e-5, atol=1e-5)

    def test_zero_adapter_is_identity_delta(self):
        x = jax.random.normal(KEY, (2, 128))
        A = jnp.zeros((2, 128, 8))
        B = jnp.zeros((2, 8, 128))
        y = bgmv(x, A, B, jnp.zeros(2, jnp.int32), interpret=True)
        assert float(jnp.abs(y).max()) == 0.0


class TestSGMV:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("T,din,r,dout,n,tile", [
        (128, 128, 8, 128, 2, 64),
        (256, 128, 8, 256, 5, 64),
        (512, 256, 32, 384, 4, 128),
    ])
    def test_matches_ref(self, T, din, r, dout, n, tile, dtype):
        ks = jax.random.split(KEY, 4)
        x = jax.random.normal(ks[0], (T, din), dtype)
        A = (jax.random.normal(ks[1], (n, din, r)) * 0.05).astype(dtype)
        B = (jax.random.normal(ks[2], (n, r, dout)) * 0.05).astype(dtype)
        ts = jax.random.randint(ks[3], (T // tile,), 0, n)
        y = sgmv(x, A, B, ts, tile=tile, out_tile=128, interpret=True)
        np.testing.assert_allclose(
            np.asarray(y, np.float32),
            np.asarray(ref.sgmv_ref(x, A, B, ts, tile), np.float32),
            **tol(dtype))

    def test_ragged_wrapper_matches_per_request_matmul(self):
        ks = jax.random.split(KEY, 3)
        din, r, dout, n = 128, 8, 256, 5
        seq_lens, slots = [10, 33, 64, 7], [2, 0, 4, 1]
        x = jax.random.normal(ks[0], (sum(seq_lens), din))
        A = jax.random.normal(ks[1], (n, din, r)) * 0.05
        B = jax.random.normal(ks[2], (n, r, dout)) * 0.05
        y = ops.lora_sgmv(x, A, B, seq_lens, slots, tile=64,
                          prefer_kernel=True, interpret=True)
        off, parts = 0, []
        for L, s in zip(seq_lens, slots):
            parts.append((x[off:off + L] @ A[s]) @ B[s])
            off += L
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(jnp.concatenate(parts)),
                                   rtol=1e-5, atol=1e-5)

    @given(seq_lens=st.lists(st.integers(1, 200), min_size=1, max_size=8),
           tile=st.sampled_from([32, 64, 128]))
    @settings(max_examples=30, deadline=None)
    def test_pack_segments_properties(self, seq_lens, tile):
        slots = list(range(len(seq_lens)))
        perm, tile_slot, padded_T = pack_segments(seq_lens, slots, tile)
        assert padded_T % tile == 0
        assert len(tile_slot) == padded_T // tile
        # Every source token appears exactly once.
        real = perm[perm >= 0]
        assert sorted(real.tolist()) == list(range(sum(seq_lens)))
        # No tile spans two adapters.
        for t in range(padded_T // tile):
            rows = perm[t * tile:(t + 1) * tile]
            srcs = rows[rows >= 0]
            if len(srcs):
                bounds = np.cumsum([0] + list(seq_lens))
                owners = np.searchsorted(bounds, srcs, side="right") - 1
                assert len(set(owners.tolist())) == 1


class TestPagedAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,Kh,G,dh,page,P", [
        (1, 1, 1, 64, 16, 2),
        (3, 2, 4, 64, 16, 4),
        (2, 4, 8, 128, 32, 3),
    ])
    def test_matches_ref(self, B, Kh, G, dh, page, P, dtype):
        n_pages = B * P + 4
        ks = jax.random.split(KEY, 4)
        q = jax.random.normal(ks[0], (B, Kh, G, dh), dtype)
        kp = jax.random.normal(ks[1], (n_pages, page, Kh, dh), dtype)
        vp = jax.random.normal(ks[2], (n_pages, page, Kh, dh), dtype)
        pt = jax.random.permutation(ks[3], n_pages)[:B * P].reshape(B, P)
        lens = jnp.asarray(
            np.random.default_rng(0).integers(1, P * page + 1, B))
        y = paged_attention(q, kp, vp, pt, lens, interpret=True)
        np.testing.assert_allclose(
            np.asarray(y, np.float32),
            np.asarray(ref.paged_attention_ref(q, kp, vp, pt, lens),
                       np.float32), **tol(dtype))

    def test_single_valid_token_returns_its_value(self):
        """With length 1, output must equal v of the single token."""
        B, Kh, G, dh, page, P = 1, 1, 2, 64, 16, 2
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Kh, G, dh))
        kp = jax.random.normal(ks[1], (4, page, Kh, dh))
        vp = jax.random.normal(ks[2], (4, page, Kh, dh))
        pt = jnp.array([[1, 3]])
        y = paged_attention(q, kp, vp, pt, jnp.array([1]), interpret=True)
        expect = jnp.broadcast_to(vp[1, 0, 0], (G, dh))
        np.testing.assert_allclose(np.asarray(y[0, 0]), np.asarray(expect),
                                   rtol=1e-5, atol=1e-5)

    def test_page_table_permutation_invariance(self):
        """Same logical KV in different physical pages -> same output."""
        B, Kh, G, dh, page, P = 1, 2, 2, 64, 8, 3
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Kh, G, dh))
        kv = jax.random.normal(ks[1], (P * page, Kh, dh))
        vv = jax.random.normal(ks[2], (P * page, Kh, dh))
        lens = jnp.array([P * page])

        def layout(order):
            kp = jnp.zeros((8, page, Kh, dh))
            vp = jnp.zeros((8, page, Kh, dh))
            for logical, physical in enumerate(order):
                kp = kp.at[physical].set(
                    kv[logical * page:(logical + 1) * page])
                vp = vp.at[physical].set(
                    vv[logical * page:(logical + 1) * page])
            pt = jnp.array([order])
            return paged_attention(q, kp, vp, pt, lens, interpret=True)

        y1 = layout([0, 1, 2])
        y2 = layout([5, 2, 7])
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,S,H,Kh,dh,causal", [
        (1, 128, 2, 2, 64, True),
        (2, 256, 4, 2, 64, True),     # GQA G=2
        (1, 256, 8, 1, 128, True),    # MQA
        (2, 128, 2, 2, 64, False),
    ])
    def test_matches_ref(self, B, S, H, Kh, dh, causal, dtype):
        from repro.kernels.flash_attention import flash_attention
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, dh), dtype)
        k = jax.random.normal(ks[1], (B, S, Kh, dh), dtype)
        v = jax.random.normal(ks[2], (B, S, Kh, dh), dtype)
        y = flash_attention(q, k, v, causal=causal, q_block=64,
                            kv_block=64, interpret=True)
        y_ref = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   **tol(dtype))

    def test_first_token_attends_only_itself(self):
        from repro.kernels.flash_attention import flash_attention
        ks = jax.random.split(KEY, 3)
        B, S, H, dh = 1, 128, 2, 64
        q = jax.random.normal(ks[0], (B, S, H, dh))
        k = jax.random.normal(ks[1], (B, S, H, dh))
        v = jax.random.normal(ks[2], (B, S, H, dh))
        y = flash_attention(q, k, v, causal=True, q_block=64,
                            kv_block=64, interpret=True)
        np.testing.assert_allclose(np.asarray(y[0, 0]),
                                   np.asarray(v[0, 0]), rtol=1e-5,
                                   atol=1e-5)
