"""Cross-adapter prefix KV reuse: whole-engine token parity + unit tests.

The radix prefix cache (DESIGN §2) changes *where* prompt KV comes
from — cached pages mapped into the page table instead of re-prefilled
— but must never change *which* tokens are produced. This suite A/Bs
``prefix_cache=True`` against the seed placement path across
greedy/sampled, multi-adapter traces, mid-page copy-on-write forks,
squash-while-shared and eviction-under-pressure, mirroring
``test_hotloop_parity.py``; plus direct radix-tree unit tests on a bare
``MemoryPool``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import MemoryPool, PrefixCache, Request, RequestState, \
    SamplingParams
from repro.models import api
from repro.serving.engine import ChameleonEngine, EngineConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


BASE = dict(max_slots=4, max_len=128, n_lora_slots=4, n_adapters=8,
            seed=0)


def make_engine(small_model, prefix, **kw):
    cfg, params = small_model
    return ChameleonEngine(cfg, params, EngineConfig(
        **{**BASE, **kw, "prefix_cache": prefix}))


def run_prompts(eng, prompts, adapters, out_len=8, sampling=None,
                max_steps=20_000):
    """Submit real-token-id requests and drain with per-step invariant
    checks (including free-list disjointness against shared pages)."""
    handles = [eng.submit(Request(input_len=len(p), output_len=out_len,
                                  adapter_id=a, prompt=list(p)),
                          sampling=sampling)
               for p, a in zip(prompts, adapters)]
    steps = 0
    while eng.busy() and steps < max_steps:
        eng.step()
        eng.pool.check_invariants(
            free_page_ids=getattr(eng, "free_pages", None))
        steps += 1
    assert not eng.busy(), "engine failed to drain"
    return [h.tokens for h in handles]


def shared_prefix_prompts(n=8, prefix_len=40, n_prefixes=2, seed=11,
                          vocab=256):
    """n prompts drawn from n_prefixes fixed preambles + unique
    suffixes — the substrate every parity test replays on both arms."""
    rng = np.random.default_rng(seed)
    pres = [rng.integers(3, vocab, size=prefix_len).tolist()
            for _ in range(n_prefixes)]
    return [pres[i % n_prefixes]
            + rng.integers(3, vocab, size=int(rng.integers(4, 13))).tolist()
            for i in range(n)]


class TestPrefixParity:
    def test_greedy_token_parity_multi_adapter(self, small_model):
        """Prefix on == prefix off, token for token, on a multi-adapter
        shared-prefix trace — and the on-arm actually reuses pages."""
        prompts = shared_prefix_prompts(n=8)
        adapters = [i % 2 for i in range(8)]   # prefix i%2 ↔ adapter i%2
        outs = {}
        for prefix in (False, True):
            eng = make_engine(small_model, prefix)
            outs[prefix] = run_prompts(eng, prompts, adapters)
            assert eng.stats()["completed"] == len(prompts)
            if prefix:
                assert eng.prefix_hit_tokens > 0, "no pages were reused"
                assert eng.stats()["prefix_hit_rate"] > 0
        assert outs[True] == outs[False], (
            "prefix cache changed decoded tokens")

    def test_sampled_token_parity(self, small_model):
        """Stochastic sampling is keyed on (seed, position); skipping
        the cached prefix must not shift the sampled stream."""
        sp = SamplingParams(temperature=0.8, top_k=12, top_p=0.9,
                            seed=1234)
        prompts = shared_prefix_prompts(n=6, seed=13)
        adapters = [0] * 6
        outs = {}
        for prefix in (False, True):
            eng = make_engine(small_model, prefix)
            outs[prefix] = run_prompts(eng, prompts, adapters,
                                       sampling=sp)
        assert outs[True] == outs[False], (
            "prefix cache changed sampled tokens")

    def test_exact_mode_isolates_adapters(self, small_model):
        """exact mode: same prompt under a *different* adapter must not
        hit — LoRA touches q/k/v/o, so its KV differs."""
        eng = make_engine(small_model, True)
        prompts = [shared_prefix_prompts(n=1, seed=17)[0]] * 2
        run_prompts(eng, prompts, adapters=[0, 1])
        assert eng.prefix_hit_tokens == 0
        # Same adapter third time around: now it hits.
        run_prompts(eng, prompts[:1], adapters=[0])
        assert eng.prefix_hit_tokens > 0

    def test_alora_cross_adapter_sharing_and_parity(self, small_model):
        """aLoRA mode (base-model prompt prefill): one tree serves every
        adapter, so the same prompt under different adapters shares
        pages — and on/off arms stay token-identical (both arms prefill
        with the base model, so the A/B is paired)."""
        prompts = shared_prefix_prompts(n=6, seed=19)
        adapters = [i % 3 for i in range(6)]    # prefixes cross adapters
        outs = {}
        for prefix in (False, True):
            eng = make_engine(small_model, prefix, prefix_mode="alora")
            outs[prefix] = run_prompts(eng, prompts, adapters)
            if prefix:
                assert eng.prefix_hit_tokens > 0, (
                    "alora mode should share across adapters")
        assert outs[True] == outs[False], (
            "alora prefix cache changed decoded tokens")

    def test_cow_fork_mid_page_divergence(self, small_model):
        """Two prompts agreeing on 24 tokens (1.5 pages): the second
        placement must fork the half-matching page copy-on-write and
        still decode exactly the prefix-off tokens."""
        rng = np.random.default_rng(23)
        base = rng.integers(3, 256, size=48).tolist()
        prompts = [base,
                   base[:24] + rng.integers(3, 256, size=20).tolist()]
        outs = {}
        for prefix in (False, True):
            eng = make_engine(small_model, prefix)
            # Sequential so the first request's pages are adopted
            # before the second one matches.
            outs[prefix] = run_prompts(eng, prompts[:1], adapters=[0]) \
                + run_prompts(eng, prompts[1:], adapters=[0])
            if prefix:
                assert eng.n_cow_forks >= 1, "divergence must COW-fork"
                assert eng.prefix_hit_tokens >= 24
        assert outs[True] == outs[False], (
            "COW fork changed decoded tokens")

    def test_squash_while_shared(self, small_model):
        """Preempting a slot that maps shared prefix pages must release
        only its references (the tree keeps the pages), and the squash
        continuation must reproduce the prefix-off tokens exactly."""
        prompts = shared_prefix_prompts(n=2, n_prefixes=1, seed=29)
        ref_eng = make_engine(small_model, False)
        ref = run_prompts(ref_eng, prompts, adapters=[0, 0], out_len=40)

        eng = make_engine(small_model, True)
        # Warm the tree with the first request, then squash the second.
        first = run_prompts(eng, prompts[:1], adapters=[0], out_len=40)
        h = eng.submit(Request(input_len=len(prompts[1]), output_len=40,
                               adapter_id=0, prompt=list(prompts[1])))
        it = h.stream()
        for _ in range(4):
            next(it)
        assert eng.prefix_hit_tokens > 0, "second request should hit"
        stolen, eng.free_pages = eng.free_pages, []
        for _ in range(40):
            eng.step()
            eng.pool.check_invariants(free_page_ids=eng.free_pages)
            if eng.n_preempted:
                break
        assert eng.n_preempted >= 1, "steal must force a preemption"
        eng.free_pages = stolen
        eng.drain()
        assert h.state is RequestState.FINISHED
        assert first == ref[:1] and h.tokens == ref[1], (
            "squash-while-shared diverged from the prefix-off run")
        # Every surviving shared page is back to the tree's own ref.
        assert all(eng.pool.shared_refcount(p) == 1
                   for p in eng.pool.shared_page_ids())
        assert eng.pool.used_requests == 0

    def test_eviction_under_pressure(self, small_model):
        """Distinct long prompts on a small pool: the tree must shed
        LRU leaves to keep admission alive — every request completes
        and pool conservation holds at every step."""
        eng = make_engine(small_model, True, max_slots=2, max_len=64,
                          n_lora_slots=2, n_adapters=4)
        rng = np.random.default_rng(5)
        n = 0
        while eng.prefix.evictions == 0 and n < 40:
            p = rng.integers(3, 256, size=48).tolist()
            run_prompts(eng, [p], adapters=[n % 4], out_len=4)
            n += 1
        assert eng.prefix.evictions >= 1, (
            f"no evictions after {n} distinct 48-token prompts")
        assert eng.stats()["completed"] == n
        eng.pool.check_invariants(free_page_ids=eng.free_pages)

    def test_refcounts_return_to_one_after_drain(self, small_model):
        """End state: no request holds, every cached page held exactly
        once (by the tree), hit/lookup counters consistent."""
        eng = make_engine(small_model, True)
        prompts = shared_prefix_prompts(n=8, seed=31)
        run_prompts(eng, prompts, adapters=[0] * 8)
        assert eng.pool.used_requests == 0
        shared = eng.pool.shared_page_ids()
        assert shared, "drain should leave the tree warm"
        assert all(eng.pool.shared_refcount(p) == 1 for p in shared)
        assert len(eng.prefix) == len(shared)
        s = eng.stats()
        assert s["prefix_hit_tokens"] <= s["prefix_lookup_tokens"]

    def test_dense_mode_flag_is_noop(self, small_model):
        """prefix_cache=True on the dense slab quietly disables the
        cache (pages are the unit of sharing) — no stats, same run."""
        eng = make_engine(small_model, True, paged=False)
        assert eng.prefix is None
        run_prompts(eng, shared_prefix_prompts(n=2), adapters=[0, 0])
        assert "prefix_hit_rate" not in eng.stats()

    def test_off_flag_restores_seed_shape(self, small_model):
        eng = make_engine(small_model, False)
        assert eng.prefix is None and eng.pool.n_shared_pages == 0

    def test_bad_prefix_mode_rejected(self, small_model):
        cfg, params = small_model
        with pytest.raises(ValueError, match="prefix_mode"):
            ChameleonEngine(cfg, params, EngineConfig(
                **BASE, prefix_mode="fuzzy"))


class TestPrefixCacheUnit:
    """Radix tree semantics on a bare pool — no engine, no model."""

    def _cache(self, ps=4, capacity=160):
        pool = MemoryPool(capacity, page_size=ps)
        return pool, PrefixCache(pool, ps)

    def _adopt(self, pool, cache, sig, tokens, pages):
        adopted = cache.insert(sig, tokens, pages)
        for pid in adopted:
            pool.add_shared_page(pid)
        return adopted

    def test_requires_paged_pool(self):
        pool = MemoryPool(64, page_size=1)
        with pytest.raises(ValueError):
            PrefixCache(pool, 1)

    def test_insert_match_roundtrip_and_limit(self):
        pool, cache = self._cache()
        toks = list(range(12))
        assert self._adopt(pool, cache, 0, toks, [10, 11, 12]) == \
            [10, 11, 12]
        pages, n, pp, pl = cache.match(0, toks + [99], limit=12)
        assert (pages, n, pp) == ([10, 11, 12], 12, None)
        # limit=11 stops the whole-page walk at 8 and COW-matches 3
        # tokens into the third page.
        pages, n, pp, pl = cache.match(0, toks, limit=11)
        assert (pages, n, pp, pl) == ([10, 11], 8, 12, 3)

    def test_lcp_partial_match_on_divergence(self):
        pool, cache = self._cache()
        self._adopt(pool, cache, 0, list(range(8)), [10, 11])
        div = [0, 1, 2, 3, 4, 5, 99, 98, 97]
        pages, n, pp, pl = cache.match(0, div, limit=len(div))
        assert (pages, n, pp, pl) == ([10], 4, 11, 2)

    def test_duplicate_keys_rejected(self):
        """First writer wins: re-inserting the same token path adopts
        nothing (the duplicate pages stay private to their request)."""
        pool, cache = self._cache()
        toks = list(range(8))
        self._adopt(pool, cache, 0, toks, [10, 11])
        assert cache.insert(0, toks, [20, 21]) == []
        assert len(cache) == 2

    def test_sigs_are_isolated(self):
        pool, cache = self._cache()
        toks = list(range(8))
        self._adopt(pool, cache, 0, toks, [10, 11])
        pages, n, pp, _ = cache.match(1, toks, limit=8)
        assert (pages, n, pp) == ([], 0, None)
        # Same path under another sig is a fresh subtree.
        assert self._adopt(pool, cache, 1, toks, [20, 21]) == [20, 21]

    def test_evict_lru_order_and_leaf_only(self):
        pool, cache = self._cache()
        a = list(range(0, 4))
        b = list(range(100, 104))
        self._adopt(pool, cache, 0, a, [10])
        self._adopt(pool, cache, 0, b, [11])
        cache.match(0, a, limit=4)       # touch a: b becomes LRU
        assert cache.evict_lru(1) == [11]
        assert cache.evict_lru(1) == [10]
        assert len(cache) == 0 and pool.n_shared_pages == 0

    def test_evict_skips_referenced_pages(self):
        """A page some request still maps (refcount > 1) is never a
        victim; chains unwind leaf-first once released."""
        pool, cache = self._cache()
        self._adopt(pool, cache, 0, list(range(8)), [10, 11])
        pool.share_pages([11])           # a live request maps the leaf
        assert cache.evict_lru(2) == []  # leaf pinned, parent not a leaf
        pool.release_shared([11])
        assert cache.evict_lru(2) == [11, 10]
        assert cache.evictions == 2
