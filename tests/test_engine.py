"""Integration tests: JAX serving engine + Chameleon control plane."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Request
from repro.models import api
from repro.serving.engine import ChameleonEngine, EngineConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def make_engine(small_model, **kw):
    cfg, params = small_model
    defaults = dict(max_slots=4, max_len=128, n_lora_slots=4,
                    n_adapters=8, seed=0)
    defaults.update(kw)
    return ChameleonEngine(cfg, params, EngineConfig(**defaults))


def submit_n(eng, n, seed=0, adapters=8):
    rng = np.random.default_rng(seed)
    reqs = [Request(input_len=int(rng.integers(4, 30)),
                    output_len=int(rng.integers(2, 20)),
                    adapter_id=int(rng.integers(0, adapters)))
            for _ in range(n)]
    for r in reqs:
        eng.submit(r)
    return reqs


class TestEngine:
    def test_all_requests_complete(self, small_model):
        eng = make_engine(small_model)
        reqs = submit_n(eng, 12)
        eng.run_until_drained()
        assert eng.stats()["completed"] == 12
        for r in reqs:
            assert r.finish_time is not None

    def test_output_lengths_respected(self, small_model):
        eng = make_engine(small_model)
        reqs = submit_n(eng, 6)
        eng.run_until_drained()
        for r in reqs:
            assert r.generated == r.output_len

    def test_cache_hits_on_adapter_reuse(self, small_model):
        eng = make_engine(small_model, n_adapters=2)
        submit_n(eng, 10, adapters=2)
        eng.run_until_drained()
        st = eng.stats()
        assert st["cache"]["hits"] > 0
        assert st["cache"]["misses"] <= 2 + st["cache"]["evictions"]

    def test_adapters_change_model_output(self, small_model):
        """Same prompt through two adapters must produce different
        logits — proves the multi-adapter LoRA path is live."""
        cfg, params = small_model
        eng = make_engine(small_model)
        r1 = Request(input_len=12, output_len=6, adapter_id=0)
        r2 = Request(input_len=12, output_len=6, adapter_id=5)
        eng.submit(r1)
        eng.submit(r2)
        eng.run_until_drained()
        o1 = eng.outputs[r1.req_id]
        o2 = eng.outputs[r2.req_id]
        assert o1 != o2, "different adapters must decode differently"

    def test_same_adapter_same_prompt_deterministic(self, small_model):
        eng = make_engine(small_model)
        r1 = Request(input_len=12, output_len=6, adapter_id=3)
        r2 = Request(input_len=12, output_len=6, adapter_id=3)
        eng.submit(r1)
        eng.submit(r2)
        eng.run_until_drained()
        assert eng.outputs[r1.req_id] == eng.outputs[r2.req_id]

    def test_more_adapters_than_slots(self, small_model):
        """Eviction pressure: 8 adapters, 3 slots — must still finish."""
        eng = make_engine(small_model, n_lora_slots=3)
        submit_n(eng, 16, adapters=8)
        eng.run_until_drained()
        st = eng.stats()
        assert st["completed"] == 16
        assert st["cache"]["evictions"] > 0
        assert len(st["resident_adapters"]) <= 3

    def test_pool_clean_after_drain(self, small_model):
        eng = make_engine(small_model)
        submit_n(eng, 8)
        eng.run_until_drained()
        eng.pool.check_invariants()
        assert eng.pool.used_requests == 0
