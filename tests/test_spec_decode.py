"""Speculative draft-verify decoding: whole-engine parity + accounting.

Speculation (DESIGN §2) changes *how many* dispatches produce the
tokens — a small dense draft proposes ``spec_k`` tokens per row, one
multi-token target verify scores them all, acceptance/bonus/rollback
stay on device — but must never change *which* tokens greedy decoding
produces. This suite A/Bs spec against non-spec across paged/dense,
checks the rejection-sampling math against the pure-Python oracle,
exercises mid-burst squash/cancel/deadline, page-accounting honesty
through speculative grow/shrink cycles, draft-KV bookkeeping, the
construction-time config errors, the fallback warnings, and the
exported gauges.
"""
import warnings as _warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Request, RequestState, SamplingParams
from repro.core.sampling import spec_residual_reference
from repro.models import api
from repro.serving.engine import (AdapterCatalog, ChameleonEngine,
                                  EngineConfig)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


@pytest.fixture(scope="module")
def draft_model(small_model):
    """An honest *separate* dense draft: different arch, different
    weights, same vocabulary. Its proposals mostly disagree with the
    target, so these runs exercise the rejection/rollback path."""
    cfg, _ = small_model
    dcfg = get_config("internlm2-1.8b").reduced(
        n_layers=2, vocab_size=cfg.vocab_size)
    dparams = api.init_params(dcfg, jax.random.PRNGKey(7), jnp.float32)
    return dcfg, dparams


def zeroed_catalog(cfg, n_adapters=8, r_max=32):
    """LoRA adapters whose delta is exactly zero: the base-weights-only
    draft then sees the same logits path as the target, which makes a
    *self*-draft agree everywhere (acceptance 1.0)."""
    cat = AdapterCatalog(cfg, n_adapters, r_max, seed=0)
    for aid in cat.weights:
        cat.weights[aid] = {
            k: (jnp.zeros_like(a), jnp.zeros_like(b))
            for k, (a, b) in cat.weights[aid].items()}
    return cat


BASE = dict(max_slots=4, max_len=128, n_lora_slots=4, n_adapters=8,
            seed=0)


def make_engine(small_model, *, spec, draft=None, catalog=None, **kw):
    cfg, params = small_model
    return ChameleonEngine(
        cfg, params,
        EngineConfig(**{**BASE, **kw, "spec_decode": spec}),
        catalog=catalog, draft=draft)


def run_to_completion(eng, specs, sampling=None, max_steps=20_000):
    reqs = [Request(input_len=i, output_len=o, adapter_id=a)
            for i, o, a in specs]
    handles = [eng.submit(r, sampling=sampling) for r in reqs]
    steps = 0
    while eng.busy() and steps < max_steps:
        eng.step()
        eng.pool.check_invariants()
        steps += 1
    assert not eng.busy(), "engine failed to drain"
    return reqs, handles


def fixed_trace(n=10, seed=3, adapters=8):
    rng = np.random.default_rng(seed)
    return [(int(rng.integers(4, 30)), int(rng.integers(2, 40)),
             int(rng.integers(0, adapters))) for _ in range(n)]


class TestSpecGreedyParity:
    @pytest.mark.parametrize("paged", (False, True))
    def test_greedy_token_parity_disagreeing_draft(
            self, small_model, draft_model, paged):
        """Worst case: a draft that almost always disagrees with the
        target. Every round rejects early, rollback runs constantly —
        and the emitted tokens must still be bit-identical to the
        non-speculative fused loop."""
        specs = fixed_trace()
        outs = {}
        for spec in (False, True):
            eng = make_engine(small_model, spec=spec, paged=paged,
                              draft=draft_model if spec else None)
            reqs, handles = run_to_completion(eng, specs)
            assert eng.stats()["completed"] == len(specs)
            outs[spec] = [h.tokens for h in handles]
            if spec:
                st = eng.spec_stats()
                assert st["spec_drafted_tokens"] > 0
                assert st["spec_verify_dispatches"] > 0
        assert outs[True] == outs[False], (
            "speculative decode changed greedy tokens")

    @pytest.mark.parametrize("paged", (False, True))
    def test_greedy_full_acceptance_self_draft(self, small_model, paged):
        """Best case: target drafting for itself with zeroed LoRA
        deltas — verify must accept every proposal (acceptance 1.0),
        tokens still identical to non-spec."""
        cfg, params = small_model
        specs = fixed_trace(n=4, seed=9)
        outs = {}
        for spec in (False, True):
            eng = make_engine(small_model, spec=spec, paged=paged,
                              catalog=zeroed_catalog(cfg),
                              draft=(cfg, params) if spec else None)
            _, handles = run_to_completion(eng, specs)
            outs[spec] = [h.tokens for h in handles]
            if spec:
                st = eng.spec_stats()
                assert st["spec_accept_rate"] == 1.0, st
                assert st["spec_accepted_tokens"] == \
                    st["spec_drafted_tokens"] > 0
        assert outs[True] == outs[False]


class TestSpecSampling:
    def test_sampled_deterministic_and_layout_invariant(
            self, small_model, draft_model):
        """Seeded sampling through the rejection sampler is keyed on
        (seed, position): the same engine run twice emits the same
        tokens, and dense vs paged KV layouts agree."""
        sp = SamplingParams(temperature=0.8, top_k=12, top_p=0.9,
                            seed=1234)
        specs = fixed_trace(n=6, seed=5)
        outs = {}
        for tag, paged in (("paged_a", True), ("paged_b", True),
                           ("dense", False)):
            eng = make_engine(small_model, spec=True, paged=paged,
                              draft=draft_model)
            _, handles = run_to_completion(eng, specs, sampling=sp)
            outs[tag] = [h.tokens for h in handles]
        assert outs["paged_a"] == outs["paged_b"], (
            "seeded speculative sampling is not deterministic")
        assert outs["paged_a"] == outs["dense"], (
            "KV layout changed speculative sampled tokens")

    def test_mixed_greedy_and_sampled_batch(self, small_model,
                                            draft_model):
        """Greedy and seeded-sampled rows co-batched in one spec run:
        the greedy rows must match the non-spec greedy run exactly
        (their acceptance is pure argmax; the sampled rows' streams
        must not perturb them)."""
        sp = SamplingParams(temperature=0.9, top_k=20, seed=77)
        plans = [(8, 12, 0, None), (6, 15, 1, sp),
                 (10, 10, 2, None), (5, 20, 3, sp)]
        outs = {}
        for spec in (False, True):
            eng = make_engine(small_model, spec=spec,
                              draft=draft_model if spec else None,
                              paged=True)
            handles = [eng.submit(Request(input_len=i, output_len=o,
                                          adapter_id=a), sampling=s)
                       for i, o, a, s in plans]
            eng.drain()
            outs[spec] = [h.tokens for h in handles]
        greedy_rows = [j for j, p in enumerate(plans) if p[3] is None]
        for j in greedy_rows:
            assert outs[True][j] == outs[False][j], (
                f"greedy row {j} diverged in a mixed batch")

    def test_rejection_rule_preserves_target_distribution(self):
        """The distribution-preservation identity behind rejection
        sampling: emitting draft ``d ~ q`` with prob ``min(1, p/q)``
        and otherwise resampling from the residual yields exactly
        ``p``. Checked numerically against the pure-Python oracle the
        device rule mirrors."""
        rng = np.random.default_rng(0)
        for _ in range(50):
            v = int(rng.integers(2, 12))
            p = rng.dirichlet(np.ones(v))
            q = rng.dirichlet(np.ones(v))
            res = np.asarray(spec_residual_reference(list(p), list(q)))
            accept = np.minimum(1.0, p / np.maximum(q, 1e-30))
            reject_mass = float(np.sum(q * (1.0 - accept)))
            emitted = q * accept + reject_mass * res
            np.testing.assert_allclose(emitted, p, atol=1e-12)
        # Degenerate p == q: zero residual mass falls back to p.
        p = rng.dirichlet(np.ones(8))
        np.testing.assert_allclose(
            spec_residual_reference(list(p), list(p)), p, atol=1e-12)


class TestSpecLifecycle:
    def test_mid_stream_squash_parity(self, small_model, draft_model):
        """Page preemption mid-spec-burst: grow-for-speculation pages
        must be reclaimable, the squash must preserve the streamed
        prefix, and the continuation must land on exactly the non-spec
        tokens."""
        spec = dict(input_len=8, output_len=40, adapter_id=0)
        ref_eng = make_engine(small_model, spec=False)
        ref = ref_eng.submit(Request(**spec)).result().tokens

        eng = make_engine(small_model, spec=True, draft=draft_model)
        h = eng.submit(Request(**spec))
        it = h.stream()
        for _ in range(4):
            next(it)
        prefix = list(h.tokens)
        stolen, eng.free_pages = eng.free_pages, []
        for _ in range(30):
            eng.step()
            if eng.n_preempted:
                break
        assert eng.n_preempted >= 1, "steal must force a preemption"
        assert h.tokens[:len(prefix)] == prefix, "stream rewound"
        eng.free_pages = stolen
        eng.drain()
        assert h.state is RequestState.FINISHED
        assert h.tokens == ref, "squash continuation diverged"
        assert h.req.squash_count >= 1

    def test_cancel_during_spec_burst(self, small_model, draft_model):
        eng = make_engine(small_model, spec=True, draft=draft_model)
        h = eng.submit(Request(input_len=8, output_len=100,
                               adapter_id=0))
        next(h.stream())
        n_at_cancel = len(h.tokens)
        assert h.cancel()
        eng.drain()
        assert h.state is RequestState.CANCELLED
        assert len(h.tokens) == n_at_cancel, (
            "post-cancel tokens leaked to the handle")
        eng.pool.check_invariants()
        assert eng.pool.used_requests == 0

    def test_deadline_expiry_during_spec(self, small_model,
                                         draft_model):
        """A ttl passing mid-decode under a virtual clock must expire
        the request cleanly — slot, pages and draft bookkeeping all
        released."""
        cfg, params = small_model
        vnow = [0.0]
        eng = ChameleonEngine(
            cfg, params,
            EngineConfig(**BASE, spec_decode=True),
            draft=draft_model, clock=lambda: vnow[0])
        h = eng.submit(Request(input_len=8, output_len=5000,
                               adapter_id=0), ttl=10.0)
        for _ in range(6):      # place + a few speculative bursts
            eng.step()
        vnow[0] = 1e9
        eng.drain()
        assert h.state is RequestState.EXPIRED
        eng.pool.check_invariants(free_page_ids=eng.free_pages)
        assert eng.pool.used_requests == 0
        assert int(np.sum(eng._draft_len)) == 0

    def test_page_accounting_holds_every_spec_step(self, small_model,
                                                   draft_model):
        """Pool invariants and the private/shared page arithmetic hold
        at every step boundary through speculative grow/shrink cycles:
        pages grown for a burst are popped back after the drain, so no
        step ends with phantom occupancy."""
        eng = make_engine(small_model, spec=True, paged=True,
                          draft=draft_model)
        reqs = [Request(input_len=i, output_len=o, adapter_id=a)
                for i, o, a in fixed_trace(8, seed=7)]
        for r in reqs:
            eng.submit(r)
        ps = eng.pool.page_size
        total = eng.n_pages - 1
        steps = 0
        while eng.busy() and steps < 10_000:
            eng.step()
            eng.pool.check_invariants(free_page_ids=eng.free_pages)
            shared = set(eng.pool.shared_page_ids())
            priv = sum(1 for plist in eng.slot_pages
                       for p in plist if p not in shared)
            assert eng.pool.used_requests == priv * ps
            assert len(eng.free_pages) + priv + len(shared) == total
            steps += 1
        assert eng.stats()["completed"] == len(reqs)

    def test_draft_kv_freed_on_finish(self, small_model, draft_model):
        """The draft-cache mirror is per-slot bookkeeping: a finished
        slot's ``_draft_len`` must drop to 0 so the next occupant
        re-syncs from scratch instead of reading a stale mirror."""
        eng = make_engine(small_model, spec=True, draft=draft_model)
        run_to_completion(eng, fixed_trace(n=6, seed=11))
        assert int(np.sum(eng._draft_len)) == 0, (
            f"stale draft-KV mirror after drain: {eng._draft_len}")


class TestSpecConfigErrors:
    def test_non_dense_draft_raises_at_construction(self, small_model):
        """Satellite: asking a hybrid (SSM+attention) model to draft
        must fail loudly at engine construction, naming the family and
        the capability gate — never inside jit."""
        zcfg = get_config("zamba2-1.2b").reduced()
        with pytest.raises(ValueError) as ei:
            make_engine(small_model, spec=True, draft=(zcfg, {}))
        msg = str(ei.value)
        assert "zamba2" in msg and zcfg.family.name in msg
        assert "supports_spec_draft" in msg
        assert "internlm2-1.8b" in msg      # actionable suggestion

    def test_non_dense_draft_by_name_raises(self, small_model):
        with pytest.raises(ValueError, match="dense draft"):
            make_engine(small_model, spec=True,
                        spec_draft="zamba2-1.2b")

    def test_vocab_mismatch_raises(self, small_model, draft_model):
        dcfg, dparams = draft_model
        bad = dcfg.reduced(n_layers=2, vocab_size=dcfg.vocab_size // 2)
        with pytest.raises(ValueError, match="vocab_size"):
            make_engine(small_model, spec=True, draft=(bad, {}))

    def test_bad_spec_k_raises(self, small_model, draft_model):
        with pytest.raises(ValueError, match="spec_k"):
            make_engine(small_model, spec=True, draft=draft_model,
                        spec_k=0)

    def test_nonfused_engine_warns_and_runs_nonspec(self, small_model,
                                                    draft_model):
        """spec inside the *seed* two-dispatch loop is unsupported:
        construction warns once and the engine decodes exactly like a
        plain non-fused engine."""
        with pytest.warns(RuntimeWarning, match="spec_decode"):
            eng = make_engine(small_model, spec=True, draft=draft_model,
                              fused_hotloop=False)
        assert not eng.spec
        _, handles = run_to_completion(eng, fixed_trace(n=3, seed=2))
        ref = make_engine(small_model, spec=False, fused_hotloop=False)
        _, ref_handles = run_to_completion(ref, fixed_trace(n=3, seed=2))
        assert [h.tokens for h in handles] == \
            [h.tokens for h in ref_handles]

    def test_unsupported_family_fused_warning_names_path(self):
        """Satellite: fused_hotloop=True on a family with no fused
        decode path (hybrid SSM) warns once at construction, naming
        the family and the capability gate, and leaves the engine on
        the seed loop."""
        cfg = get_config("zamba2-1.2b").reduced()
        assert not api.supports_fused(cfg)
        params = api.init_params(cfg, jax.random.PRNGKey(0),
                                 jnp.float32)
        with _warnings.catch_warnings(record=True) as w:
            _warnings.simplefilter("always")
            eng = ChameleonEngine(cfg, params, EngineConfig(
                max_slots=2, max_len=64, n_lora_slots=2, n_adapters=2,
                seed=0, fused_hotloop=True))
        fused_w = [x for x in w
                   if "fused_hotloop=True ignored" in str(x.message)]
        assert len(fused_w) == 1
        msg = str(fused_w[0].message)
        assert cfg.family.name in msg and "supports_fused" in msg
        assert not eng.fused


class TestSpecMetrics:
    def test_gauges_emitted_and_reset(self, small_model, draft_model):
        eng = make_engine(small_model, spec=True, draft=draft_model)
        run_to_completion(eng, fixed_trace(n=3, seed=4))
        st = eng.stats()
        for g in ("spec_accept_rate", "spec_drafted_tokens",
                  "spec_accepted_tokens", "spec_draft_dispatches",
                  "spec_verify_dispatches", "spec_dispatches",
                  "spec_k_eff"):
            assert g in st, f"{g} missing from stats()"
        assert st["spec_drafted_tokens"] > 0
        assert 0.0 <= st["spec_accept_rate"] <= 1.0
        m = eng.metrics().sched_stats
        assert m["spec_drafted_tokens"] == st["spec_drafted_tokens"]
        eng.reset_stats()
        st2 = eng.spec_stats()
        assert st2["spec_drafted_tokens"] == 0
        assert st2["spec_draft_dispatches"] == 0

    def test_spec_off_emits_no_gauges(self, small_model):
        eng = make_engine(small_model, spec=False)
        assert eng.spec_stats() == {}
        assert "spec_accept_rate" not in eng.stats()
