"""Integration tests: trace synthesis + DES + paper-claim directionality."""
import numpy as np
import pytest

from repro.serving import (NodeConfig, TraceConfig, build_node, synthesize)


def run(system, rps=10.0, seed=1, duration=60.0, **trace_kw):
    sim, adapters, cost = build_node(system, NodeConfig())
    trace = synthesize(TraceConfig(rps=rps, duration_s=duration, seed=seed,
                                   **trace_kw), list(adapters.values()))
    return sim.run(trace), sim, trace


class TestTrace:
    def test_deterministic(self):
        _, _, t1 = run("slora", seed=3)
        _, _, t2 = run("slora", seed=3)
        assert [r.input_len for r in t1.requests] == \
               [r.input_len for r in t2.requests]
        assert [r.adapter_id for r in t1.requests] == \
               [r.adapter_id for r in t2.requests]

    def test_rps_calibration(self):
        _, _, t = run("slora", rps=8.0, duration=120.0)
        assert abs(t.rps_realised() - 8.0) < 1.0

    def test_powerlaw_rank_popularity(self):
        _, sim, t = run("slora", rps=10.0, duration=120.0)
        ranks = [sim.adapters[r.adapter_id].rank for r in t.requests]
        counts = {rk: ranks.count(rk) for rk in (8, 128)}
        assert counts[8] > 5 * counts[128], counts

    def test_heavy_tail_outputs(self):
        _, _, t = run("slora", rps=10.0, duration=120.0)
        outs = np.array([r.output_len for r in t.requests])
        assert np.percentile(outs, 99) > 4 * np.median(outs)


class TestSimulator:
    def test_all_requests_complete(self):
        m, _, t = run("chameleon", rps=8.0)
        assert m.completed() == t.n

    def test_deterministic_metrics(self):
        m1, _, _ = run("chameleon", rps=8.0, seed=5)
        m2, _, _ = run("chameleon", rps=8.0, seed=5)
        assert m1.p99_ttft() == m2.p99_ttft()
        assert m1.p50_ttft() == m2.p50_ttft()

    def test_pool_drains_clean(self):
        m, sim, _ = run("chameleon", rps=8.0)
        sim.pool.check_invariants()
        assert sim.pool.used_requests == 0   # all reservations returned

    def test_ttft_includes_queueing(self):
        m, _, _ = run("slora", rps=12.0)
        assert m.p99_ttft() > m.p50_ttft() > 0

    @pytest.mark.parametrize("system", ["slora", "userve-sjf", "chameleon",
                                        "chameleon-nocache",
                                        "chameleon-nosched",
                                        "chameleon-lru",
                                        "chameleon-fairshare",
                                        "chameleon-prefetch",
                                        "chameleon-outputonly"])
    def test_every_system_runs(self, system):
        m, _, t = run(system, rps=6.0, duration=30.0)
        assert m.completed() == t.n
        assert np.isfinite(m.p99_ttft())


class TestPaperDirectionality:
    """The paper's qualitative claims, as regression guards."""

    def test_chameleon_beats_slora_tail_at_high_load(self):
        # Margin calibrated to streaming-honest TTFT: a squashed
        # request keeps the timestamp of the first token it actually
        # streamed (core/request.reset_for_requeue), so re-execution no
        # longer inflates either system's tail — the requeue stall now
        # shows up in TBT, not TTFT. That accounting change shrinks the
        # headline gap (slora's old tail was dominated by re-measured
        # squash TTFTs) without changing a single scheduling decision.
        m_s, _, _ = run("slora", rps=12.0, duration=120.0)
        m_c, _, _ = run("chameleon", rps=12.0, duration=120.0)
        assert m_c.p99_ttft() < 0.75 * m_s.p99_ttft(), (
            m_c.p99_ttft(), m_s.p99_ttft())

    def test_chameleon_beats_slora_median_at_high_load(self):
        m_s, _, _ = run("slora", rps=12.0, duration=120.0)
        m_c, _, _ = run("chameleon", rps=12.0, duration=120.0)
        assert m_c.p50_ttft() < m_s.p50_ttft()

    def test_sjf_starves_long_requests(self):
        """Fig 13: SJF's tail is *worse* than FIFO's at high load."""
        m_f, _, _ = run("slora", rps=13.0, duration=120.0)
        m_j, _, _ = run("userve-sjf", rps=13.0, duration=120.0)
        assert m_j.p99_ttft() > m_f.p99_ttft()

    def test_sjf_helps_median(self):
        m_f, _, _ = run("slora", rps=13.0, duration=120.0)
        m_j, _, _ = run("userve-sjf", rps=13.0, duration=120.0)
        assert m_j.p50_ttft() < m_f.p50_ttft()

    def test_cache_raises_hit_rate(self):
        m_s, sim_s, _ = run("slora", rps=10.0, duration=120.0)
        m_c, sim_c, _ = run("chameleon-nosched", rps=10.0, duration=120.0)
        assert m_c.cache_stats["hit_rate"] > m_s.cache_stats["hit_rate"]

    def test_cache_cuts_link_traffic(self):
        m_s, _, _ = run("slora", rps=10.0, duration=120.0)
        m_c, _, _ = run("chameleon-nosched", rps=10.0, duration=120.0)
        assert m_c.cache_stats["gb_loaded"] < m_s.cache_stats["gb_loaded"]

    def test_squash_rate_below_5pct(self):
        m, sim, t = run("chameleon", rps=12.0, duration=120.0)
        assert sim.sched.n_squashed <= 0.05 * t.n, (
            f"squashed {sim.sched.n_squashed}/{t.n}")

    def test_low_load_systems_equivalent(self):
        m_s, _, _ = run("slora", rps=4.0, duration=60.0)
        m_c, _, _ = run("chameleon", rps=4.0, duration=60.0)
        assert abs(m_s.p50_ttft() - m_c.p50_ttft()) < 0.05
