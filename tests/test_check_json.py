"""The CI schema gate itself: ``benchmarks.check_json`` must reject
malformed documents and documents that silently drop a required
acceptance claim — otherwise a benchmark entrypoint can change shape
and the bench-smoke job keeps passing on nothing.
"""
import json

import pytest

from benchmarks.check_json import REQUIRED_VALIDATED, check_doc, main


def good_doc(name="fig10_latency_load_prefix_ab"):
    validated = {k: True for k in REQUIRED_VALIDATED.get(name, set())}
    validated.setdefault("extra_claim", 1.5)
    return {
        "name": name,
        "paper_ref": "Figures 10, 11, 12",
        "rows": [{"mode": "off", "p99_ttft": 1.0},
                 {"mode": "on", "p99_ttft": 0.5}],
        "validated": validated,
    }


class TestCheckDoc:
    def test_well_formed_doc_passes(self):
        assert check_doc(good_doc(), "x.json") == []

    @pytest.mark.parametrize("name", sorted(REQUIRED_VALIDATED))
    def test_each_missing_required_key_rejected(self, name):
        """Dropping any single required validated key must fail the
        schema check for every registered benchmark."""
        for key in sorted(REQUIRED_VALIDATED[name]):
            doc = good_doc(name)
            del doc["validated"][key]
            errs = check_doc(doc, "x.json")
            assert errs and key in errs[0], (
                f"{name}: missing {key!r} not rejected: {errs}")

    def test_unregistered_name_needs_no_keys(self):
        doc = good_doc("some_future_benchmark")
        doc["validated"] = {"whatever": 1}
        assert check_doc(doc, "x.json") == []

    @pytest.mark.parametrize("mutate", [
        lambda d: d.pop("name"),
        lambda d: d.pop("rows"),
        lambda d: d.__setitem__("rows", []),
        lambda d: d.__setitem__("validated", [1, 2]),
        lambda d: d["rows"].append({"other": 1}),        # key drift
        lambda d: d["rows"].append({"mode": {"a": 1},    # nested dict
                                    "p99_ttft": 1.0}),
    ])
    def test_malformed_docs_rejected(self, mutate):
        doc = good_doc()
        mutate(doc)
        assert check_doc(doc, "x.json"), "malformed doc passed"

    def test_non_object_rejected(self):
        assert check_doc([1, 2, 3], "x.json")


class TestMain:
    def test_main_flags_bad_file(self, tmp_path, capsys):
        ok = tmp_path / "ok.json"
        ok.write_text(json.dumps(good_doc()))
        bad = tmp_path / "bad.json"
        doc = good_doc()
        del doc["validated"]["tokens_identical"]
        bad.write_text(json.dumps(doc))
        assert main([str(ok)]) == 0
        assert main([str(ok), str(bad)]) == 1
        assert "tokens_identical" in capsys.readouterr().err

    def test_main_unreadable_and_usage(self, tmp_path):
        assert main([]) == 2
        garbled = tmp_path / "garbled.json"
        garbled.write_text("{not json")
        assert main([str(garbled)]) == 1
