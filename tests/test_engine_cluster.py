"""Cluster data plane over real engines: routing units + e2e parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Request
from repro.models import api
from repro.serving.cluster import (EngineCluster, EngineClusterConfig,
                                   Router)
from repro.serving.engine import AdapterCatalog, ChameleonEngine, EngineConfig
from repro.serving.trace import Trace, TraceConfig, downscale_for_engine


# ------------------------------------------------------------------
# Router units (no jax needed)
# ------------------------------------------------------------------
class TestRouter:
    def test_round_robin_cycles(self):
        r = Router("round_robin", 3)
        assert [r.route(0) for _ in range(6)] == [0, 1, 2, 0, 1, 2]

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError):
            Router("clairvoyant", 2)

    def test_least_loaded_picks_min(self):
        r = Router("least_loaded", 3)
        assert r.route(7, loads=[3.0, 0.5, 2.0]) == 1

    def test_least_loaded_requires_loads(self):
        with pytest.raises(ValueError):
            Router("least_loaded", 3).route(7)

    def test_affinity_follows_residency(self):
        """An adapter's requests stay on the replica that has it
        resident, even when another replica is (mildly) less loaded."""
        r = Router("adapter_affinity", 3)
        node = r.route(5, loads=[1.4, 1.0, 1.5],
                       resident=[True, False, False])
        assert node == 0

    def test_affinity_spills_when_target_saturated(self):
        """Least-loaded balancing kicks in once the affinity target
        exceeds the overload bound (dLoRA imbalance trap, bounded)."""
        r = Router("adapter_affinity", 3, overload_factor=1.5)
        assert r.route(5, loads=[9.0, 1.0, 5.0],
                       resident=[True, False, False]) == 1

    def test_affinity_sticky_hint_without_residency(self):
        r = Router("adapter_affinity", 3)
        first = r.route(5, loads=[1.0, 0.0, 1.0])
        again = r.route(5, loads=[1.0, 1.0, 1.0])
        assert first == 1 and again == 1

    def test_affinity_consistent_hash_without_load_feed(self):
        """No load signal at all: placement degrades to a consistent
        hash — deterministic across router instances."""
        a = Router("adapter_affinity", 4)
        b = Router("adapter_affinity", 4)
        picks_a = [a.route(aid) for aid in range(32)]
        picks_b = [b.route(aid) for aid in range(32)]
        assert picks_a == picks_b
        assert len(set(picks_a)) > 1          # not all on one node

    def test_hash_stability_under_node_add(self):
        """Rendezvous hashing: growing the cluster remaps only a
        fraction of adapters."""
        small, big = Router("adapter_affinity", 4), \
            Router("adapter_affinity", 5)
        moved = sum(small._hash_node(a) != big._hash_node(a)
                    for a in range(200))
        assert moved < 100      # ~1/5 expected; far below full remap


# ------------------------------------------------------------------
# Real-engine cluster e2e
# ------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def request_specs(n, seed=0, adapters=8):
    rng = np.random.default_rng(seed)
    return [(int(rng.integers(4, 30)), int(rng.integers(2, 16)),
             int(rng.integers(0, adapters))) for _ in range(n)]


ECFG = dict(max_slots=4, max_len=128, n_lora_slots=4, n_adapters=8)


class TestEngineCluster:
    def test_two_engine_drain_token_parity(self, small_model):
        """End-to-end: the same requests through 1 engine and through a
        2-engine cluster must decode the *same tokens* — replicas share
        the AdapterCatalog, so placement may change latency, never
        content."""
        cfg, params = small_model
        specs = request_specs(10, seed=3)

        eng = ChameleonEngine(cfg, params, EngineConfig(**ECFG))
        solo = [Request(input_len=i, output_len=o, adapter_id=a)
                for i, o, a in specs]
        for r in solo:
            eng.submit(r)
        eng.drain()

        cluster = EngineCluster(cfg, params, EngineConfig(**ECFG),
                                EngineClusterConfig(n_engines=2))
        dup = [Request(input_len=i, output_len=o, adapter_id=a)
               for i, o, a in specs]
        for r in dup:
            cluster.submit(r)
        cluster.drain()

        merged, per_node = cluster.metrics()
        assert merged.completed() == len(specs)
        assert sum(m.completed() for m in per_node) == len(specs)
        outputs = {}
        for e in cluster.engines:
            outputs.update(e.outputs)
        for a, b in zip(solo, dup):
            assert eng.outputs[a.req_id] == outputs[b.req_id], \
                (a.input_len, a.adapter_id)

    def test_catalog_shared_not_duplicated(self, small_model):
        cfg, params = small_model
        cluster = EngineCluster(cfg, params, EngineConfig(**ECFG),
                                EngineClusterConfig(n_engines=3))
        for e in cluster.engines:
            assert e.catalog is cluster.catalog
            assert e.host_adapters is cluster.catalog.weights

    def test_affinity_routes_to_resident_replica(self, small_model):
        """Once adapter 0 is resident on the replica that served it,
        later adapter-0 requests keep landing there."""
        cfg, params = small_model
        cluster = EngineCluster(cfg, params, EngineConfig(**ECFG),
                                EngineClusterConfig(
                                    n_engines=2,
                                    policy="adapter_affinity"))
        first = cluster.submit(Request(input_len=8, output_len=2,
                                       adapter_id=0)).node
        cluster.drain()
        assert cluster.engines[first].cache.resident(0)
        for _ in range(3):
            handle = cluster.submit(Request(input_len=8, output_len=2,
                                            adapter_id=0))
            assert handle.node == first
            cluster.drain()

    def test_run_replays_arrivals_and_reports(self, small_model):
        cfg, params = small_model
        tcfg = TraceConfig(rps=8.0, duration_s=1.0, n_adapters=8, seed=0)
        reqs = [Request(input_len=12, output_len=4, adapter_id=i % 8,
                        arrival_time=0.05 * i) for i in range(8)]
        trace = downscale_for_engine(
            Trace(requests=reqs, config=tcfg), 8, 32, 8)
        cluster = EngineCluster(cfg, params, EngineConfig(**ECFG),
                                EngineClusterConfig(n_engines=2))
        merged, per_node = cluster.run(trace.requests)
        assert merged.completed() == len(reqs)
        assert merged.p99_ttft() > 0.0
        assert merged.cache_stats["hits"] + merged.cache_stats["misses"] > 0
        assert len(per_node) == 2