"""Cluster-level routing over Chameleon nodes."""
import numpy as np
import pytest

from repro.serving.cluster import run_cluster


@pytest.fixture(scope="module")
def results():
    out = {}
    for policy in ("round_robin", "least_loaded", "adapter_affinity"):
        m, per = run_cluster(policy, rps=48.0, n_nodes=4, duration=90.0)
        out[policy] = (m, per)
    return out


def test_all_requests_complete(results):
    for policy, (m, per) in results.items():
        assert m.completed() == m.n_submitted, policy


def test_load_roughly_balanced(results):
    for policy, (m, per) in results.items():
        counts = [x.completed() for x in per]
        assert max(counts) < 2.0 * max(1, min(counts)), (policy, counts)


def test_affinity_raises_hit_rate(results):
    rr = results["round_robin"][0].cache_stats["hit_rate"]
    af = results["adapter_affinity"][0].cache_stats["hit_rate"]
    assert af > rr


def test_affinity_cuts_link_traffic(results):
    rr = results["round_robin"][0].cache_stats["gb_loaded"]
    af = results["adapter_affinity"][0].cache_stats["gb_loaded"]
    assert af < rr


def test_affinity_best_tail_at_high_load(results):
    p99 = {p: m.p99_ttft() for p, (m, _) in results.items()}
    assert p99["adapter_affinity"] < 0.7 * p99["round_robin"], p99
    assert p99["adapter_affinity"] <= 1.2 * p99["least_loaded"], p99
