"""Smoke tests for the PR-2 control-plane bugfixes (fast; run first in CI).

Three latent §4.1/§4.2 bugs found auditing the seed:

1. ``AdapterCache.acquire``/``prefetch`` dropped ``queued_protect`` on
   the way to ``make_room`` — the second-tier protection of queued
   requests' adapters was computed by the scheduler and then silently
   bypassed on every load.
2. ``ChameleonScheduler.schedule`` Phase 1 only lent a queue's spare
   quota when the queue drained completely, so a queue whose head is
   memory-blocked never redistributed its unused quota (Algorithm 1
   says *all* unused quota flows top-down).
3. ``HistogramPrefetcher.run`` required ``now <= t``, so an adapter
   whose predicted arrival had just slipped past was never warmed even
   though it is the most imminent prediction of all.
"""
import pytest

from repro.core import (AdapterCache, AdapterInfo, ChameleonScheduler,
                        HistogramPrefetcher, MemoryPool,
                        NoisyOraclePredictor, Request)
from repro.core.scheduler import _QueueState


def make_catalog(sizes):
    return {aid: AdapterInfo(adapter_id=aid, rank=8, size_bytes=s,
                             size_tokens=s) for aid, s in sizes.items()}


def make_cache(capacity, sizes):
    pool = MemoryPool(capacity_tokens=capacity)
    return pool, AdapterCache(pool, make_catalog(sizes))


# ------------------------------------------------------------------
# Bugfix 1: queued_protect threads through acquire()/prefetch()
# ------------------------------------------------------------------
class TestAcquireProtectionTiers:
    def _warm_cache(self):
        """Adapter 1 resident and *older* (lowest eviction score), then
        adapter 0 — without protection, 1 is the natural victim."""
        pool, cache = make_cache(100, {0: 40, 1: 40, 2: 40, 3: 80})
        cache.acquire(1, now=1.0); cache.release(1, now=1.0)
        cache.acquire(0, now=5.0); cache.release(0, now=5.0)
        return pool, cache

    def test_unprotected_eviction_takes_the_queued_adapter(self):
        pool, cache = self._warm_cache()
        cache.acquire(2, now=6.0)             # no protect set
        assert not cache.resident(1), "1 is oldest: natural victim"

    def test_acquire_respects_queued_protection(self):
        pool, cache = self._warm_cache()
        # A queued request needs adapter 1: loading 2 must evict 0
        # instead, even though 1 scores lower.
        cache.acquire(2, now=6.0, queued_protect=[1])
        assert cache.resident(1) and cache.resident(2)
        assert not cache.resident(0)

    def test_protection_is_second_tier_under_pressure(self):
        pool, cache = self._warm_cache()
        # Adapter 3 needs 80 tokens: evicting only the unprotected 0
        # leaves 60 free, so the protected 1 must go too (second tier).
        cache.acquire(3, now=6.0, queued_protect=[1])
        assert cache.resident(3)
        assert not cache.resident(0) and not cache.resident(1)

    def test_prefetch_respects_queued_protection(self):
        pool, cache = self._warm_cache()
        assert cache.prefetch(2, now=6.0, queued_protect=[1])
        assert cache.resident(1) and not cache.resident(0)


# ------------------------------------------------------------------
# Bugfix 2: a memory-blocked queue still lends its spare quota
# ------------------------------------------------------------------
class TestBlockedHeadQuotaRedistribution:
    def test_blocked_head_queue_lends_spare_quota(self):
        pool = MemoryPool(capacity_tokens=1000)
        cache = AdapterCache(pool, make_catalog({0: 900, 1: 10}))
        pred = NoisyOraclePredictor(accuracy=1.0, seed=0)
        sched = ChameleonScheduler(pool, cache, cache.catalog, pred)
        # Fill 200 tokens so adapter 0 (900 tokens) can never fit: the
        # head of queue 0 is memory-blocked, not quota-blocked.
        pool.reserve_request(999, 200)
        sched.queues = [
            _QueueState(cutoff_hi=1.0, quota=950),
            _QueueState(cutoff_hi=float("inf"), quota=10),
        ]
        head = Request(input_len=10, output_len=10, adapter_id=0)
        head.predicted_output = 10
        head.queue_idx = 0
        sched.queues[0].reqs.append(head)
        # Queue 1's request charges 20+20+10 = 50 tokens > its quota of
        # 10 — it can only run on quota borrowed from queue 0.
        small = Request(input_len=20, output_len=20, adapter_id=1)
        small.predicted_output = 20
        small.queue_idx = 1
        sched.queues[1].reqs.append(small)

        batch = sched.schedule(now=1.0, running=[])
        assert head not in batch, "adapter 0 cannot fit in memory"
        assert small in batch, (
            "queue 0's unused quota must be lent top-down even though "
            "queue 0 did not drain (its head is memory-blocked)")
        # Quota conservation: every admitted charge is accounted.
        charged = sum(t for r in batch for _, t in r.charges)
        assert sum(q.used for q in sched.queues) == charged

    def test_drained_queue_still_lends(self):
        """The pre-fix behaviour (drained queues lend) is preserved."""
        pool = MemoryPool(capacity_tokens=1000)
        cache = AdapterCache(pool, make_catalog({1: 10}))
        pred = NoisyOraclePredictor(accuracy=1.0, seed=0)
        sched = ChameleonScheduler(pool, cache, cache.catalog, pred)
        sched.queues = [
            _QueueState(cutoff_hi=1.0, quota=500),
            _QueueState(cutoff_hi=float("inf"), quota=10),
        ]
        small = Request(input_len=20, output_len=20, adapter_id=1)
        small.predicted_output = 20
        small.queue_idx = 1
        sched.queues[1].reqs.append(small)
        batch = sched.schedule(now=1.0, running=[])
        assert small in batch


# ------------------------------------------------------------------
# Bugfix 3: overdue predictions prefetch as most-imminent
# ------------------------------------------------------------------
class TestOverduePrefetch:
    def _prefetcher(self, capacity=100):
        pool, cache = make_cache(capacity, {0: 10, 1: 10, 2: 10})
        return pool, cache, HistogramPrefetcher(cache, horizon=3.0)

    def test_overdue_prediction_still_prefetches(self):
        pool, cache, hp = self._prefetcher()
        # Inter-arrivals of 10 s -> modal bucket [8, 16) -> midpoint 12
        # -> next predicted arrival at t = 20 + 12 = 32.
        for t in (0.0, 10.0, 20.0):
            hp.observe_arrival(0, t)
        # The prefetcher tick lands at t = 33: the prediction is one
        # second overdue but well within the horizon — it must warm.
        loaded = hp.run(now=33.0)
        assert 0 in loaded
        assert cache.resident(0)

    def test_overdue_sorts_most_imminent(self):
        pool, cache, hp = self._prefetcher()
        hp.max_per_round = 1
        for t in (0.0, 10.0, 20.0):
            hp.observe_arrival(0, t)          # predicted at 32 (overdue)
        for t in (13.0, 23.0, 33.0):
            hp.observe_arrival(1, t)          # predicted at 45 (future)
        loaded = hp.run(now=33.5)
        assert loaded == [0], "overdue prediction outranks a future one"

    def test_beyond_horizon_not_prefetched(self):
        pool, cache, hp = self._prefetcher()
        for t in (0.0, 100.0, 200.0):         # predicted ~ 200 + 96
            hp.observe_arrival(0, t)
        assert hp.run(now=201.0) == []

    def test_stale_prediction_expires(self):
        """A dead adapter's fixed past prediction must not top-rank
        forever: overdue is imminent only within one horizon."""
        pool, cache, hp = self._prefetcher()
        for t in (0.0, 10.0, 20.0):           # predicted at ~32
            hp.observe_arrival(0, t)
        assert hp.run(now=100.0) == []
