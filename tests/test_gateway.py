"""Multi-tenant gateway (DESIGN §3.3): admission control, weighted-fair
dispatch, SLO-aware overload behavior, decision traces, and the
gauge/doc coverage contract.

Everything runs against the DES tier (pure Python, no JAX device work):
the gateway's behavior is tier-independent by construction — it speaks
only the ``ServingSystem`` verbs — and ``tests/test_serving_api.py``
already proves those verbs are uniform across sim/engine/cluster.

The SLO tests pin the wait model (``calibrate=False`` + explicit
``init_*`` seeds) so the admit/degrade/reject thresholds are exact
arithmetic, not calibration-dependent.
"""
import pathlib

import pytest

from repro.core import Request, RequestState, SamplingParams
from repro.core.request import TERMINAL_STATES
from repro.serving import (GAUGES, Gateway, GatewayConfig, NodeConfig,
                           TenantPolicy, TraceConfig, build_system,
                           synthesize_multitenant)
from repro.serving.handles import RequestHandle, ServingSystem

NCFG = dict(n_adapters=8)


def gated(gcfg=None, **node_kw):
    return build_system("chameleon", tier="sim",
                        node=NodeConfig(**{**NCFG, **node_kw}),
                        gateway=gcfg or GatewayConfig())


def req(out=8, inp=32, adapter=0, **kw):
    return Request(input_len=inp, output_len=out, adapter_id=adapter, **kw)


#: Wait model pinned for exact SLO arithmetic: predicted wait for one
#: queued request = (input 32 + predicted output 128) * 0.05s/tok.
PINNED = dict(init_s_per_tok=0.05, init_ttft_s=0.2,
              service_parallelism=1.0, calibrate=False)


# ------------------------------------------------------------------
# ServingSystem conformance
# ------------------------------------------------------------------
class TestConformance:
    def test_protocol_and_lifecycle(self):
        gw = gated()
        assert isinstance(gw, Gateway)
        assert isinstance(gw, ServingSystem)
        h = gw.submit("acme", req())
        assert isinstance(h, RequestHandle)
        assert gw.busy()
        gw.drain()
        assert not gw.busy()
        assert h.state is RequestState.FINISHED
        assert h.result().n_tokens == 8

    def test_stream_equals_on_token(self):
        gw = gated()
        seen = []
        h = gw.submit("acme", req(out=6, adapter=1), on_token=seen.append)
        streamed = list(h.stream())
        assert len(streamed) == 6
        assert streamed == seen == h.tokens

    def test_submit_shapes_all_tag_tenant(self):
        gw = gated()
        h1 = gw.submit("acme", req())                  # operator shape
        h2 = gw.submit(req(adapter=1), tenant="globex")  # kwarg shape
        r3 = req(adapter=2)
        r3.tenant = "initech"
        h3 = gw.submit(r3)                             # pre-tagged shape
        assert (h1.req.tenant, h2.req.tenant, h3.req.tenant) == \
            ("acme", "globex", "initech")
        gw.drain()
        assert all(h.state is RequestState.FINISHED for h in (h1, h2, h3))
        assert set(gw.gateway_stats()["tenants"]) == \
            {"acme", "globex", "initech"}

    def test_queue_pressure_counts_gateway_backlog(self):
        gw = gated()
        base = gw.queue_pressure()
        for i in range(5):
            gw.submit("acme", req(adapter=i % 4))
        assert gw.queue_pressure() >= base + 5


# ------------------------------------------------------------------
# Per-tenant isolation (weighted-fair dispatch)
# ------------------------------------------------------------------
class TestIsolation:
    def test_light_tenant_not_starved_by_flood(self):
        """30 long requests from one tenant are already queued when a
        light tenant submits one short request: SFQ must dispatch the
        light tenant ahead of the flood's backlog, so its TTFT looks
        like an idle system, not like position 31 in a FIFO."""
        gw = gated()
        flood = [gw.submit("floodcorp", req(out=96, inp=256, adapter=i % 4))
                 for i in range(30)]
        probe = gw.submit("acme", req(out=8, inp=16, adapter=1))
        gw.drain()
        assert probe.state is RequestState.FINISHED
        assert all(h.state is RequestState.FINISHED for h in flood)
        flood_ttfts = sorted(h.req.ttft() for h in flood)
        # The probe beats the median flood request despite arriving last.
        assert probe.req.ttft() < flood_ttfts[len(flood_ttfts) // 2]

    def test_weights_bias_service_order(self):
        """With equal backlogs, the heavier tenant's requests drain
        first in proportion to weight (SFQ finish tags = cost/weight)."""
        gcfg = GatewayConfig(
            default_policy=TenantPolicy(weight=1.0, max_inflight=1),
            tenants={"gold": TenantPolicy(weight=4.0, max_inflight=1)})
        gw = gated(gcfg)
        gold = [gw.submit("gold", req(out=16, adapter=0)) for _ in range(6)]
        iron = [gw.submit("iron", req(out=16, adapter=1)) for _ in range(6)]
        gw.drain()
        gold_done = sum(h.req.finish_time for h in gold)
        iron_done = sum(h.req.finish_time for h in iron)
        assert gold_done < iron_done


# ------------------------------------------------------------------
# Admission limits: reject early, never drop silently
# ------------------------------------------------------------------
class TestLimits:
    def test_tenant_queue_cap_rejects_with_retry_after(self):
        gcfg = GatewayConfig(tenants={
            "bulk": TenantPolicy(max_inflight=1, max_queued=3)})
        gw = gated(gcfg)
        flood = [gw.submit("bulk", req(out=16, adapter=i % 4))
                 for i in range(10)]
        rejected = [h for h in flood if h.state is RequestState.REJECTED]
        assert len(rejected) == 7           # 3 queued, rest refused
        for h in rejected:
            assert h.done                   # REJECTED is terminal
            assert h.retry_after > 0
            assert h.decision.action == "reject"
            assert h.decision.reason == "tenant_queue_full"
            assert h.decision.retry_after_s == h.retry_after
        gw.drain()
        assert sum(h.state is RequestState.FINISHED for h in flood) == 3
        ts = gw.gateway_stats()["tenants"]["bulk"]
        assert (ts["submitted"], ts["rejected"], ts["completed"]) == (10, 7, 3)

    def test_global_queue_cap(self):
        gcfg = GatewayConfig(max_queued_total=2)
        gw = gated(gcfg)
        handles = [gw.submit(f"t{i}", req(adapter=i % 4)) for i in range(5)]
        reasons = [h.decision.reason for h in handles]
        assert reasons.count("ok") == 2
        assert reasons.count("gateway_queue_full") == 3
        gw.drain()
        assert all(h.done for h in handles)

    def test_rejected_never_reaches_inner_tier(self):
        gcfg = GatewayConfig(tenants={
            "bulk": TenantPolicy(max_queued=1)})
        gw = gated(gcfg)
        gw.submit("bulk", req())
        h = gw.submit("bulk", req(adapter=1))
        assert h.state is RequestState.REJECTED
        gw.drain()
        assert h.req.req_id not in gw.inner.outputs


# ------------------------------------------------------------------
# SLO-aware overload: admit / degrade / reject are exact arithmetic
# under a pinned wait model
# ------------------------------------------------------------------
class TestSLO:
    def backlogged(self, n=10):
        """A gateway with ``n`` same-tenant requests queued (no SLO on
        them) under the pinned wait model: predicted wait for 'bulk' is
        n * (32 + 128) * 0.05 = n * 8 seconds."""
        gcfg = GatewayConfig(
            tenants={"bulk": TenantPolicy(max_inflight=1, max_queued=64)},
            **PINNED)
        gw = gated(gcfg)
        for i in range(n):
            gw.submit("bulk", req(adapter=i % 4))
        return gw

    def test_idle_generous_budget_admits_untouched(self):
        gw = gated(GatewayConfig(**PINNED))
        cap = SamplingParams(max_new_tokens=64)
        h = gw.submit("acme", req(out=64), sampling=cap, ttl=10.0)
        assert h.decision.action == "admit"
        assert h.req.sampling.max_new_tokens == 64
        gw.drain()
        assert h.state is RequestState.FINISHED

    def test_wait_alone_busts_budget_rejects(self):
        gw = self.backlogged(10)            # bulk's predicted wait: 80s
        h = gw.submit("bulk", req(adapter=1), ttl=5.0)
        assert h.state is RequestState.REJECTED
        assert h.decision.reason == "predicted_slo_miss"
        # retry_after = projected TTFT overshoot: 80 + 0.2 - 5.
        assert h.retry_after == pytest.approx(75.2)

    def test_full_decode_busts_budget_degrades(self):
        gw = self.backlogged(10)            # projected TTFT: 80.2s
        # Residual budget 1.8s < predicted decode 128 * 0.05 = 6.4s,
        # but allowed = 1.8 / 0.05 * 0.8 = 28 >= floor 16 -> degrade.
        h = gw.submit("bulk", req(out=512, adapter=1), ttl=82.0)
        d = h.decision
        assert d.action == "degrade"
        assert d.reason == "predicted_slo_miss_full_decode"
        assert d.max_new_tokens == 28
        assert h.req.sampling.max_new_tokens == 28
        gw.drain()
        assert h.state is RequestState.FINISHED
        assert len(h.tokens) <= 28

    def test_degrade_floor_rejects_infeasible(self):
        gw = self.backlogged(10)            # projected TTFT: 80.2s
        # Residual budget 0.5s -> allowed = 0.5/0.05*0.8 = 8 < floor 16.
        h = gw.submit("bulk", req(adapter=1), ttl=80.7)
        assert h.state is RequestState.REJECTED
        assert h.decision.reason == "deadline_infeasible"

    def test_other_tenants_flood_does_not_reject_light_tenant(self):
        """The wait model is fair-share-aware: a light tenant with no
        backlog of its own must admit cleanly even while another tenant
        has hours of queue — SFQ guarantees it near-idle service."""
        gw = self.backlogged(50)            # bulk's own wait: 400s
        h = gw.submit("acme", req(adapter=1), ttl=10.0)
        assert h.decision.action == "admit"
        assert h.decision.predicted_wait_s == pytest.approx(0.0)
        # The same budget from the flooding tenant itself is hopeless.
        h2 = gw.submit("bulk", req(adapter=1), ttl=10.0)
        assert h2.decision.reason == "predicted_slo_miss"

    def test_slo_default_arms_deadline(self):
        gw = gated(GatewayConfig(slo_default_s=60.0, **PINNED))
        h = gw.submit("acme", req())
        assert h.req.deadline == pytest.approx(60.0)
        assert h.decision.budget_s == pytest.approx(60.0)


# ------------------------------------------------------------------
# Decision traces: one per submit, on every path to a terminal state
# ------------------------------------------------------------------
class TestDecisionTraces:
    def test_every_outcome_traced_and_terminal(self):
        gcfg = GatewayConfig(
            tenants={"bulk": TenantPolicy(max_inflight=1, max_queued=24)},
            **PINNED)
        gw = gated(gcfg)
        handles = []
        # admitted + finished
        handles += [gw.submit("acme", req(adapter=i % 4)) for i in range(3)]
        # rejected (backlog + hopeless ttl)
        for i in range(10):
            handles.append(gw.submit("bulk", req(adapter=i % 4)))
        handles.append(gw.submit("bulk", req(adapter=1), ttl=1.0))
        # degraded: acme's queued work halves bulk's fair share, so
        # bulk's wait is 10 * 160 tokens / 0.5 * 0.05 = 160s; ttl 165
        # leaves 4.8s residual < the 6.4s predicted full decode.
        handles.append(gw.submit("bulk", req(out=512, adapter=2), ttl=165.0))
        # cancelled while gateway-queued
        victim = gw.submit("bulk", req(adapter=3))
        handles.append(victim)
        assert victim.cancel()
        gw.drain()

        assert all(h.state in TERMINAL_STATES for h in handles)
        assert set(gw.decisions) == {h.req.req_id for h in handles}
        actions = {h.decision.action for h in handles}
        assert actions == {"admit", "degrade", "reject"}
        assert gw.n_submitted == len(handles)
        assert gw.n_cancelled_queued == 1

    def test_queued_past_deadline_expires_not_drops(self):
        """Admission was optimistic (near-zero wait model) but the
        request sits behind a long one past its deadline: the sweep
        must expire it in place, with its admit decision retained."""
        gcfg = GatewayConfig(
            tenants={"bulk": TenantPolicy(max_inflight=1)},
            init_s_per_tok=1e-6, init_ttft_s=1e-6,
            service_parallelism=1.0, calibrate=False)
        gw = gated(gcfg)
        blocker = gw.submit("bulk", req(out=64, inp=256))
        doomed = gw.submit("bulk", req(adapter=1), ttl=0.01)
        assert doomed.decision.action == "admit"
        gw.drain()
        assert blocker.state is RequestState.FINISHED
        assert doomed.state is RequestState.EXPIRED
        assert gw.n_expired_queued == 1
        assert gw.gateway_stats()["tenants"]["bulk"]["expired_queued"] == 1

    def test_cancel_future_held_and_dispatched(self):
        gw = gated()
        held = gw.submit("acme", req(arrival_time=50.0))
        assert held.cancel()
        assert held.state is RequestState.CANCELLED
        live = gw.submit("acme", req(out=32, adapter=1))
        for _ in range(3):                  # get it dispatched
            gw.step()
        assert live.req.req_id in gw._dispatched
        assert live.cancel()                # delegated to the inner tier
        gw.drain()
        assert live.state is RequestState.CANCELLED
        assert gw.n_cancelled_queued == 1   # only the held one


# ------------------------------------------------------------------
# Trace replay (future arrivals) end-to-end
# ------------------------------------------------------------------
class TestTraceReplay:
    def test_multitenant_trace_all_terminal(self):
        from repro.serving import build_node
        _, adapters, _ = build_node("chameleon", NodeConfig(**NCFG))
        trace = synthesize_multitenant(
            TraceConfig(rps=0.4, duration_s=15.0, n_adapters=8, seed=5),
            list(adapters.values()), tenants=("acme", "globex"),
            heavy_hitter="floodcorp", heavy_rps_factor=4.0)
        assert trace.n > 0
        gw = gated(GatewayConfig(slo_default_s=120.0))
        handles = [gw.submit(r.tenant, r) for r in trace.requests]
        assert gw._future                   # held until arrival
        gw.drain()
        assert all(h.state in TERMINAL_STATES for h in handles)
        assert set(gw.decisions) == {h.req.req_id for h in handles}
        # The DES clock crossed every arrival (idle gaps advanced).
        assert gw.inner.now >= trace.requests[-1].arrival_time
        # Decisions deferred to arrival: no admission happened at t=0.
        assert all(gw.decisions[h.req.req_id].t >= h.req.arrival_time - 1e-9
                   for h in handles)


# ------------------------------------------------------------------
# Observability: gauges registered, documented, and exported
# ------------------------------------------------------------------
class TestObservability:
    def run_small(self):
        gw = gated(GatewayConfig(tenants={
            "bulk": TenantPolicy(max_queued=2)}))
        for i in range(6):
            gw.submit("bulk" if i % 2 else "acme", req(adapter=i % 4))
        gw.drain()
        return gw

    def test_metrics_merge_gw_gauges_and_widen_submitted(self):
        gw = self.run_small()
        m = gw.metrics()
        m = m[0] if isinstance(m, tuple) else m
        gw_keys = {k for k in m.sched_stats if k.startswith("gw_")}
        assert gw_keys == {k for k in GAUGES if k.startswith("gw_")}
        # n_submitted counts rejects the inner tier never saw.
        assert m.n_submitted == gw.n_submitted == 6
        assert m.sched_stats["gw_rejected"] == 1
        assert m.sched_stats["gw_reject_rate"] == pytest.approx(1 / 6,
                                                                abs=1e-4)

    def test_live_gauges_all_registered(self):
        """No tier may emit a gauge missing from the GAUGES registry
        (which the operations doc is asserted against below)."""
        gw = self.run_small()
        m = gw.metrics()
        m = m[0] if isinstance(m, tuple) else m
        live = set(m.cache_stats) | set(m.sched_stats)
        unregistered = {k for k in live if k not in GAUGES}
        assert not unregistered, (
            f"gauges emitted but not in serving.metrics.GAUGES "
            f"(add them there and to docs/OPERATIONS.md): {unregistered}")

    def test_operations_doc_covers_every_gauge(self):
        doc = (pathlib.Path(__file__).resolve().parents[1]
               / "docs" / "OPERATIONS.md")
        assert doc.exists(), "docs/OPERATIONS.md is part of the product"
        text = doc.read_text()
        undocumented = [name for name in GAUGES if f"`{name}`" not in text]
        assert not undocumented, (
            f"gauges in serving.metrics.GAUGES missing from "
            f"docs/OPERATIONS.md: {undocumented}")

    def test_gateway_stats_shape(self):
        gw = self.run_small()
        gs = gw.gateway_stats()
        assert gs["n_submitted"] == 6
        assert gs["n_admitted"] + gs["n_rejected"] == 6
        assert set(gs["lane_depths"]) == {"short", "long"}
        for ts in gs["tenants"].values():
            assert ts["submitted"] == ts["admitted"] + ts["rejected"]
