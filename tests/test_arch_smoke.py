"""Per-architecture smoke tests (deliverable f).

Every assigned arch instantiates a REDUCED same-family config and runs
one forward/train step on CPU, asserting output shapes and finiteness.
Dense/MoE/SSM/hybrid/enc-dec also verify prefill+decode consistency
against the full forward pass — the strongest cheap correctness check
for KV-cache/RoPE/state plumbing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_applicable, get_config
from repro.models import api
from repro.models.base import Family, param_shapes

KEY = jax.random.PRNGKey(0)
B, S = 2, 16
PAD = 32


def _inputs(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == Family.ENCDEC:
        kw["frames"] = jax.random.normal(KEY, (B, cfg.enc_ctx, cfg.d_model),
                                         jnp.float32)
    if cfg.mrope:
        kw["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S))
    return tokens, kw


def _pad_kv(kv):
    k, v = kv
    pad = PAD - S
    return (jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))))


@pytest.fixture(scope="module")
def built():
    """Init each reduced arch once per test session."""
    cache = {}

    def get(arch):
        if arch not in cache:
            over = {}
            if get_config(arch).family == Family.MOE:
                over["capacity_factor"] = 8.0   # dropless: exact decode
            cfg = get_config(arch).reduced(**over)
            params = api.init_params(cfg, KEY, dtype=jnp.float32)
            cache[arch] = (cfg, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_loads(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    shapes = param_shapes(cfg)
    assert shapes, "param shapes must be derivable for the full config"
    assert cfg.param_count() > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, built):
    cfg, params = built(arch)
    tokens, kw = _inputs(cfg)
    logits = api.forward(cfg, params, tokens, **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf in logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_loss_finite(arch, built):
    cfg, params = built(arch)
    tokens, kw = _inputs(cfg)
    loss = api.train_loss(cfg, params, tokens, tokens, **kw)
    assert np.isfinite(float(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_matches_forward(arch, built):
    cfg, params = built(arch)
    tokens, kw = _inputs(cfg)
    logits = api.forward(cfg, params, tokens, **kw)
    pkw = dict(kw)
    if cfg.family == Family.HYBRID:
        pkw["kv_max_len"] = PAD
    last, _ = api.prefill(cfg, params, tokens, **pkw)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_matches_forward(arch, built):
    cfg, params = built(arch)
    tokens, kw = _inputs(cfg)
    pkw = dict(kw)
    if cfg.family == Family.HYBRID:
        pkw["kv_max_len"] = PAD
    last, state = api.prefill(cfg, params, tokens, **pkw)
    nxt = jnp.argmax(last, -1)[:, None]
    dkw = {}
    cache_len = jnp.full((B,), S)
    if cfg.family in (Family.DENSE, Family.MOE, Family.VLM):
        state = _pad_kv(state)
    if cfg.family == Family.ENCDEC:
        kv, cross = state
        state = (_pad_kv(kv), cross)
    if cfg.mrope:
        dkw["mrope_pos"] = jnp.broadcast_to(
            jnp.full((1,), S)[None, None], (3, B, 1))
    logits2, _ = api.decode_step(cfg, params, nxt, state, cache_len, **dkw)
    ext = jnp.concatenate([tokens, nxt], 1)
    fkw = dict(kw)
    if cfg.mrope:
        fkw["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(S + 1)[None, None], (3, B, S + 1))
    full = api.forward(cfg, params, ext, **fkw)
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(full[:, -1]),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grad_step_finite(arch, built):
    """One SGD step decreases nothing catastrophic: grads finite."""
    cfg, params = built(arch)
    tokens, kw = _inputs(cfg)

    def loss_fn(p):
        return api.train_loss(cfg, p, tokens, tokens, **kw)
    grads = jax.grad(loss_fn)(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}"


class TestCellGrid:
    def test_40_cells(self):
        cells = [(a, s) for (a, s) in
                 __import__("repro.configs", fromlist=["assigned_cells"]
                            ).assigned_cells()]
        assert len(cells) == 40

    def test_long500k_applicability(self):
        ok, _ = cell_applicable("falcon-mamba-7b", "long_500k")
        assert ok
        ok, _ = cell_applicable("zamba2-1.2b", "long_500k")
        assert ok
        ok, why = cell_applicable("qwen2.5-32b", "long_500k")
        assert not ok and "full-attention" in why

    def test_param_counts_sane(self):
        """Full configs land near their nameplate sizes."""
        expect = {
            "llama4-maverick-400b-a17b": (3.5e11, 4.6e11),
            "qwen3-moe-235b-a22b": (2.0e11, 2.6e11),
            "zamba2-1.2b": (0.9e9, 1.7e9),
            "granite-34b": (3.0e10, 4.0e10),
            "qwen2.5-32b": (2.8e10, 3.7e10),
            "qwen3-14b": (1.2e10, 1.7e10),
            "internlm2-1.8b": (1.5e9, 2.3e9),
            "whisper-base": (5e7, 1.6e8),
            "qwen2-vl-7b": (6e9, 9e9),
            "falcon-mamba-7b": (6e9, 8.5e9),
        }
        for arch, (lo, hi) in expect.items():
            n = get_config(arch).param_count()
            assert lo <= n <= hi, f"{arch}: {n:.3g} not in [{lo:g},{hi:g}]"

    def test_moe_active_params(self):
        cfg = get_config("llama4-maverick-400b-a17b")
        active = cfg.active_param_count()
        assert active < 0.1 * cfg.param_count()
        assert 1.0e10 < active < 2.5e10   # ~17B active
