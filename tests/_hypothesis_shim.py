"""Minimal stand-in for ``hypothesis`` when it is not installed.

The container this repo targets has no network access, so test deps
beyond pytest cannot be assumed. Property tests degrade gracefully:
with real hypothesis installed they run as written (shrinking, example
database, the works); without it, this shim replays each ``@given``
test over a deterministic pseudo-random sample of the strategy space —
boundary values first, then seeded draws — so the invariants still get
exercised on every CI run.

Only the strategy combinators the test-suite uses are implemented:
``integers``, ``floats``, ``lists``, ``tuples``, ``sampled_from``.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

_FALLBACK_EXAMPLES = 8          # draws per test beyond the boundary cases


class _Strategy:
    """A strategy = a function from RNG to a value, plus boundary picks."""

    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self.boundaries = list(boundaries)

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value=0, max_value=1 << 30) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         boundaries=[min_value, max_value])

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         boundaries=[min_value, max_value])

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq),
                         boundaries=seq[:1] + seq[-1:])

    @staticmethod
    def lists(elem: _Strategy, min_size=0, max_size=10) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elem.example(rng) for _ in range(n)]
        lo = [elem.boundaries[0]] * min_size if elem.boundaries else []
        return _Strategy(draw, boundaries=[lo])

    @staticmethod
    def tuples(*elems: _Strategy) -> _Strategy:
        def draw(rng):
            return tuple(e.example(rng) for e in elems)
        bound = (tuple(e.boundaries[0] for e in elems)
                 if all(e.boundaries for e in elems) else None)
        return _Strategy(draw, boundaries=[bound] if bound else [])


st = strategies


def settings(max_examples=None, **_kw):
    """``max_examples`` is honoured (it sizes the shim's random draws);
    everything else (deadline, ...) is accepted and ignored."""
    def deco(fn):
        if max_examples is not None:
            fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    """Run the test over boundary cases + seeded random draws."""
    def deco(fn):
        n_draws = getattr(fn, "_shim_max_examples", _FALLBACK_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # Deterministic per-test seed: stable across runs/machines.
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            names = list(strats)
            cases = []
            # Boundary sweep: k-th boundary of every strategy together.
            n_bounds = max((len(strats[n].boundaries) for n in names),
                           default=0)
            for k in range(n_bounds):
                case = {}
                for n in names:
                    b = strats[n].boundaries
                    case[n] = (b[min(k, len(b) - 1)] if b
                               else strats[n].example(rng))
                cases.append(case)
            for _ in range(n_draws):
                cases.append({n: strats[n].example(rng) for n in names})
            for case in cases:
                fn(*args, **kwargs, **case)
        # Hide the strategy-filled parameters from pytest's fixture
        # resolution (hypothesis does the same).
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for n, p in sig.parameters.items() if n not in strats])
        return wrapper
    return deco
