"""Distribution-layer tests: sharding policy, fit_spec, elastic plans.

These run on the host's single CPU device using small meshes via
sub-device counts where needed; the full 512-device lowering is
exercised by launch/dryrun.py (results in results/dryrun.json).
"""
import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # no network in CI containers: shim it
    from _hypothesis_shim import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.elastic import plan_after_failure
from repro.distributed.sharding import (fit_spec, kv_cache_spec,
                                        param_spec, param_shardings)
from repro.models.base import param_shapes


def fake_mesh(shape, axes):
    """Mesh over repeated host devices — for spec logic only (never used
    to place data)."""
    dev = np.asarray(jax.devices()[:1] * int(np.prod(shape))
                     ).reshape(shape)
    return Mesh(dev, axes)


MESH = fake_mesh((16, 16), ("data", "model"))
MESH3 = fake_mesh((2, 16, 16), ("pod", "data", "model"))


class TestFitSpec:
    def test_divisible_kept(self):
        assert fit_spec((32, 64), P("data", "model"), MESH) \
            == P("data", "model")

    def test_non_divisible_dropped(self):
        assert fit_spec((32, 30), P("data", "model"), MESH) == P("data")
        assert fit_spec((8, 30), P("data", "model"), MESH) == P()

    def test_tuple_trimmed_left_to_right(self):
        s = fit_spec((2, 64), P(("pod", "data"), None), MESH3)
        assert s == P(("pod",))

    def test_batch_one_replicates(self):
        assert fit_spec((1, 100), P(("pod", "data"), None), MESH3) == P()

    @given(dim=st.integers(1, 4096))
    @settings(max_examples=50, deadline=None)
    def test_never_invalid(self, dim):
        spec = fit_spec((dim,), P("model"), MESH)
        if spec == P("model"):
            assert dim % 16 == 0
        else:
            assert spec == P()


class TestKVSpec:
    def test_divisible_heads_sharded(self):
        # 32 kv heads over 16-way model: heads sharded.
        s = kv_cache_spec(MESH, (38, 128, 32768, 32, 64))
        assert s == P(None, "data", None, "model")

    def test_mqa_falls_back_to_sequence(self):
        # 1 kv head (granite): sequence-sharded KV.
        s = kv_cache_spec(MESH, (88, 128, 32768, 1, 128))
        assert s == P(None, "data", "model")

    def test_gqa8_over_16_falls_back_to_sequence(self):
        s = kv_cache_spec(MESH, (64, 128, 32768, 8, 128))
        assert s == P(None, "data", "model")


class TestParamPolicy:
    @pytest.mark.parametrize("arch", ["qwen3-14b", "qwen3-moe-235b-a22b",
                                      "falcon-mamba-7b", "whisper-base",
                                      "zamba2-1.2b"])
    @pytest.mark.parametrize("kind", ["train", "decode"])
    def test_all_params_get_valid_specs(self, arch, kind):
        cfg = get_config(arch)
        shapes = param_shapes(cfg)
        sh = param_shardings(cfg, shapes, MESH, kind)
        for path, s in sh.items():
            spec = s.spec
            shape = shapes[path]
            for dim, entry in zip(shape, list(spec)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                prod = 1
                for a in axes:
                    prod *= MESH.shape[a]
                assert dim % prod == 0, (path, shape, spec)

    def test_train_shards_more_than_decode(self):
        """FSDP: training must shard strictly more parameter bytes."""
        cfg = get_config("qwen3-14b")
        shapes = param_shapes(cfg)

        def sharded_fraction(kind):
            sh = param_shardings(cfg, shapes, MESH, kind)
            tot = shard = 0
            for path, s in sh.items():
                n = int(np.prod(shapes[path]))
                ways = 1
                for entry in s.spec:
                    if entry is None:
                        continue
                    for a in (entry if isinstance(entry, tuple)
                              else (entry,)):
                        ways *= MESH.shape[a]
                tot += n
                shard += n // ways
            return shard / tot
        assert sharded_fraction("train") < sharded_fraction("decode")

    def test_expert_weights_sharded_over_data(self):
        cfg = get_config("qwen3-moe-235b-a22b")
        shapes = param_shapes(cfg)
        # Inference: token-parallel experts — E over data, FFN unsharded
        # (EXPERIMENTS.md §Perf cell B).
        spec = param_shardings(cfg, shapes, MESH, "decode")["moe/w_gate"].spec
        assert spec[1] == "data"
        assert len(spec) == 2 or spec[3] is None
        # Training keeps Fe tensor-parallel over model.
        spec_t = param_shardings(cfg, shapes, MESH, "train")["moe/w_gate"].spec
        assert spec_t[1] == "data"
        assert "model" in (spec_t[3] if isinstance(spec_t[3], tuple)
                           else (spec_t[3],))


class TestElastic:
    def test_shrink_data_axis_keeps_model(self):
        plan = plan_after_failure((16, 16), ("data", "model"),
                                  surviving_devices=224,
                                  global_batch=256)
        assert plan.shape == (14, 16)
        assert plan.global_batch % 14 == 0

    def test_multipod_shrinks_pod_then_data(self):
        plan = plan_after_failure((2, 16, 16), ("pod", "data", "model"),
                                  surviving_devices=300,
                                  global_batch=256)
        assert plan.shape[-1] == 16
        assert plan.n_devices <= 300

    def test_cannot_drop_below_tp(self):
        with pytest.raises(ValueError):
            plan_after_failure((16, 16), ("data", "model"),
                               surviving_devices=8, global_batch=64)

    @given(surv=st.integers(16, 512), batch=st.integers(16, 512))
    @settings(max_examples=50, deadline=None)
    def test_plan_always_valid(self, surv, batch):
        plan = plan_after_failure((16, 16), ("data", "model"), surv,
                                  batch)
        assert plan.shape[-1] == 16
        assert plan.n_devices <= surv
        data_extent = plan.n_devices // 16
        assert plan.global_batch % data_extent == 0
