"""LoRA kernel-dispatch parity + async adapter-load state machine.

Parity contract: with ``lora_backend="kernel"`` (Pallas bgmv/sgmv in
interpret mode on CPU) the full ``prefill`` / ``decode_step`` /
``decode_step_paged`` outputs must be float-close — and the decoded
*tokens* identical — to the einsum reference at mixed adapter ranks.
These are the tests the kernels-interpret CI job runs, so the engine
docstring's "LoRA matmuls route to the Pallas kernels" claim can never
silently rot again.

State machine contract: a LOADING adapter is never placed into a batch
(the request defers, everything else proceeds), and the engine's async
loads eventually complete every request.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AdapterCache, AdapterInfo, AdapterState,
                        ChameleonScheduler, MemoryPool,
                        NoisyOraclePredictor, Request)
from repro.models import api
from repro.models.lm import decode_step, decode_step_paged, prefill
from repro.models.lora_apply import (init_lora_slots, lora_delta,
                                     random_lora_weights,
                                     write_adapter_to_slot)

KEY = jax.random.PRNGKey(11)
R_MAX = 32
MIXED_RANKS = (8, 16, 32)           # zero-padded into one static r_max


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


@pytest.fixture(scope="module")
def lora_slots(small_model):
    """Slot buffers holding adapters of mixed ranks (paper Fig. 2)."""
    cfg, _ = small_model
    slots = init_lora_slots(KEY, len(MIXED_RANKS), cfg.n_layers,
                            cfg.d_model, cfg.q_dim, cfg.kv_dim, R_MAX,
                            dtype=jnp.float32)
    for i, rank in enumerate(MIXED_RANKS):
        w = random_lora_weights(jax.random.PRNGKey(100 + i), rank, R_MAX,
                                cfg.n_layers, cfg.d_model, cfg.q_dim,
                                cfg.kv_dim, dtype=jnp.float32)
        slots = write_adapter_to_slot(slots, w, i)
    return slots


def assert_close(a, b, what):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-4, atol=2e-4, err_msg=what)


class TestDispatchParity:
    """ops-level: einsum oracle vs the bgmv/sgmv kernel routes."""

    @pytest.mark.parametrize("Bt,S", [(4, 1), (1, 1), (3, 12), (2, 16),
                                      (1, 7)])
    def test_lora_delta_backends_match(self, Bt, S):
        ks = jax.random.split(KEY, 4)
        n, din, r, dout = 5, 128, 32, 192
        A = (jax.random.normal(ks[0], (n, din, r)) * 0.05).astype(
            jnp.float32)
        B = (jax.random.normal(ks[1], (n, r, dout)) * 0.05).astype(
            jnp.float32)
        x = jax.random.normal(ks[2], (Bt, S, din), jnp.float32)
        idx = jax.random.randint(ks[3], (Bt,), 0, n)
        y_e = lora_delta(x, (A, B), idx, backend="einsum")
        y_k = lora_delta(x, (A, B), idx, backend="kernel")
        assert_close(y_e, y_k, f"lora_delta Bt={Bt} S={S}")

    def test_rank_padding_zero_rows_are_inert(self):
        """Rank-8 content zero-padded to r_max must equal a pure rank-8
        computation on both backends."""
        ks = jax.random.split(KEY, 3)
        din, dout, r = 128, 128, 8
        A8 = jax.random.normal(ks[0], (2, din, r)) * 0.1
        B8 = jax.random.normal(ks[1], (2, r, dout)) * 0.1
        A = jnp.zeros((2, din, R_MAX)).at[:, :, :r].set(A8)
        B = jnp.zeros((2, R_MAX, dout)).at[:, :r, :].set(B8)
        x = jax.random.normal(ks[2], (2, 4, din))
        idx = jnp.array([0, 1])
        want = lora_delta(x, (A8, B8), idx, backend="einsum")
        got = lora_delta(x, (A, B), idx, backend="kernel")
        assert_close(want, got, "rank padding")


class TestEndToEndParity:
    """Full model entry points, token-identical across backends."""

    def _prefill_io(self, small_model):
        cfg, _ = small_model
        B, S = 3, 12
        tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                                    cfg.vocab_size)
        idx = jnp.array([0, 2, 1])   # mixed ranks in one batch
        last_pos = jnp.array([S - 1, 5, 9])
        return tokens, idx, last_pos

    def test_prefill_parity(self, small_model, lora_slots):
        cfg, params = small_model
        tokens, idx, last_pos = self._prefill_io(small_model)
        outs = {}
        for be in ("einsum", "kernel"):
            logits, (k, v) = prefill(cfg, params, tokens, lora=lora_slots,
                                     adapter_idx=idx, last_pos=last_pos,
                                     lora_backend=be)
            outs[be] = (logits, k, v)
        assert_close(outs["einsum"][0], outs["kernel"][0], "prefill logits")
        assert_close(outs["einsum"][1], outs["kernel"][1], "prefill k")
        assert_close(outs["einsum"][2], outs["kernel"][2], "prefill v")
        assert (jnp.argmax(outs["einsum"][0], -1)
                == jnp.argmax(outs["kernel"][0], -1)).all(), (
            "first decoded token must be identical across backends")

    def test_decode_step_parity(self, small_model, lora_slots):
        cfg, params = small_model
        B, Smax = 3, 32
        kv = api.init_serve_state(cfg, B, Smax, jnp.float32)
        tokens = jax.random.randint(jax.random.PRNGKey(6), (B, 1), 0,
                                    cfg.vocab_size)
        cache_len = jnp.array([4, 9, 0])
        idx = jnp.array([1, 0, 2])
        outs = {}
        for be in ("einsum", "kernel"):
            logits, new_kv = decode_step(cfg, params, tokens, kv,
                                         cache_len, lora=lora_slots,
                                         adapter_idx=idx, lora_backend=be)
            outs[be] = (logits, new_kv)
        assert_close(outs["einsum"][0], outs["kernel"][0], "decode logits")
        assert_close(outs["einsum"][1][0], outs["kernel"][1][0], "decode k")
        assert (jnp.argmax(outs["einsum"][0], -1)
                == jnp.argmax(outs["kernel"][0], -1)).all()

    def test_decode_step_paged_parity(self, small_model, lora_slots):
        cfg, params = small_model
        B, page, P = 3, 8, 4
        n_pages = 1 + B * P          # page 0 is the trash page
        kv_pages = api.init_paged_serve_state(cfg, n_pages, page,
                                              jnp.float32)
        # Fill with noise so parity covers reads of pre-existing KV too.
        kv_pages = tuple(
            jax.random.normal(jax.random.PRNGKey(7 + i), kp.shape,
                              kp.dtype) * 0.1
            for i, kp in enumerate(kv_pages))
        page_table = jnp.arange(1, 1 + B * P).reshape(B, P)
        tokens = jax.random.randint(jax.random.PRNGKey(8), (B, 1), 0,
                                    cfg.vocab_size)
        cache_len = jnp.array([3, 11, 17])
        idx = jnp.array([2, 1, 0])
        outs = {}
        for be in ("einsum", "kernel"):
            logits, new_kv = decode_step_paged(
                cfg, params, tokens, kv_pages, page_table, cache_len,
                lora=lora_slots, adapter_idx=idx, lora_backend=be)
            outs[be] = (logits, new_kv)
        assert_close(outs["einsum"][0], outs["kernel"][0],
                     "paged decode logits")
        assert_close(outs["einsum"][1][0], outs["kernel"][1][0],
                     "paged decode k_pages")
        assert (jnp.argmax(outs["einsum"][0], -1)
                == jnp.argmax(outs["kernel"][0], -1)).all()

    def test_engine_tokens_identical_across_backends(self, small_model):
        """Whole-engine A/B: same trace, einsum vs kernel data plane,
        token-for-token identical outputs (sync loads keep the two
        schedules deterministic)."""
        from repro.serving.engine import ChameleonEngine, EngineConfig
        cfg, params = small_model
        outs = {}
        for be in ("einsum", "kernel"):
            eng = ChameleonEngine(cfg, params, EngineConfig(
                max_slots=4, max_len=64, n_lora_slots=4, n_adapters=4,
                seed=0, lora_backend=be, async_load=False))
            rng = np.random.default_rng(2)
            reqs = [Request(input_len=int(rng.integers(4, 20)),
                            output_len=int(rng.integers(2, 8)),
                            adapter_id=int(rng.integers(0, 4)))
                    for _ in range(6)]
            for r in reqs:
                eng.submit(r)
            eng.run_until_drained()
            outs[be] = [eng.outputs[r.req_id] for r in reqs]
        assert outs["einsum"] == outs["kernel"]


class TestLoadingStateMachine:
    def _control_plane(self, on_load=None):
        infos = {i: AdapterInfo(adapter_id=i, rank=8, size_bytes=1 << 20,
                                size_tokens=8) for i in range(4)}
        pool = MemoryPool(capacity_tokens=4096)
        cache = AdapterCache(pool, infos, on_load=on_load, max_entries=4)
        sched = ChameleonScheduler(pool, cache, infos,
                                   NoisyOraclePredictor(accuracy=1.0),
                                   max_batch_requests=4)
        return pool, cache, sched

    def test_loading_adapter_never_placed(self):
        """The core async-load invariant: while an entry is LOADING the
        scheduler defers the request instead of placing (or stalling
        anything else); once READY it places normally."""
        loading = []
        cache_box = []

        def on_load(info):
            cache_box[0].mark_loading(info.adapter_id)
            loading.append(info.adapter_id)

        pool, cache, sched = self._control_plane(on_load)
        cache_box.append(cache)
        req = Request(input_len=8, output_len=4, adapter_id=1)
        sched.submit(req, 0.0)
        for t in range(3):                     # stays deferred while LOADING
            assert sched.schedule(float(t), []) == []
        assert loading == [1], "exactly one load dispatched"
        assert cache.entries[1].state is AdapterState.LOADING
        assert req.adapter_ref, "pin held across the deferral"
        assert cache.entries[1].ref_count == 1
        # ≥1 per tick (Algorithm 1 may retry the head in both phases).
        assert sched.n_deferred >= 3
        cache.mark_ready(1)
        batch = sched.schedule(3.0, [])
        assert batch == [req]
        assert cache.stats.misses == 1 and cache.stats.hits == 0, (
            "a deferred load is one miss, not a miss plus fake hits")

    def test_loading_entry_not_evictable(self):
        pool, cache, sched = self._control_plane()
        cache.prefetch(0, 0.0)
        cache.mark_loading(0)
        assert cache._evictable() == []
        cache.mark_ready(0)
        assert len(cache._evictable()) == 1

    def test_other_requests_proceed_while_loading(self):
        """A mid-load head must not stall resident-adapter requests:
        the bypass lane fills the batch."""
        loading = []
        cache_box = []

        def on_load(info):
            # Only adapter 1 loads slowly; the rest are instant.
            if info.adapter_id == 1:
                cache_box[0].mark_loading(info.adapter_id)
                loading.append(info.adapter_id)

        pool, cache, sched = self._control_plane(on_load)
        cache_box.append(cache)
        slow = Request(input_len=8, output_len=4, adapter_id=1)
        fast = Request(input_len=8, output_len=4, adapter_id=2)
        sched.submit(slow, 0.0)
        sched.submit(fast, 0.0)
        batch = sched.schedule(0.0, [])
        assert fast in batch and slow not in batch
        cache.mark_ready(1)
        assert slow in sched.schedule(1.0, batch)

    def test_engine_async_loads_complete(self, small_model):
        """Engine-level: modeled H2D latency defers placements but every
        request still completes and every load retires."""
        from repro.serving.engine import ChameleonEngine, EngineConfig
        cfg, params = small_model
        eng = ChameleonEngine(cfg, params, EngineConfig(
            max_slots=4, max_len=64, n_lora_slots=4, n_adapters=8,
            seed=0, async_load=True, h2d_gbps=0.5))
        rng = np.random.default_rng(3)
        reqs = [Request(input_len=int(rng.integers(4, 20)),
                        output_len=int(rng.integers(2, 8)),
                        adapter_id=int(rng.integers(0, 8)))
                for _ in range(8)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        st = eng.stats()
        assert st["completed"] == 8
        assert st["async_loads"] > 0
        assert st["pending_loads"] == 0
        assert not eng.cache.loading_ids()
