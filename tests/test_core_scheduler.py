"""Unit tests: Chameleon multi-queue scheduler (paper §4.2, Algorithm 1)."""
import numpy as np
import pytest

from repro.core import (AdapterCache, AdapterInfo, ChameleonScheduler,
                        FIFOScheduler, MemoryPool, NoisyOraclePredictor,
                        Request, RequestState, SJFScheduler)


def catalog(sizes):
    return {aid: AdapterInfo(adapter_id=aid, rank=8, size_bytes=s,
                             size_tokens=s) for aid, s in sizes.items()}


def make_sched(capacity=2000, sizes=None, **kw):
    sizes = sizes or {i: 10 for i in range(8)}
    pool = MemoryPool(capacity_tokens=capacity)
    cache = AdapterCache(pool, catalog(sizes))
    pred = NoisyOraclePredictor(accuracy=1.0, seed=0)
    sched = ChameleonScheduler(pool, cache, cache.catalog, pred, **kw)
    return pool, cache, sched


def req(inp, out, adapter=0, t=0.0):
    return Request(input_len=inp, output_len=out, adapter_id=adapter,
                   arrival_time=t)


class TestAdmission:
    def test_simple_admission(self):
        pool, cache, sched = make_sched()
        r = req(10, 20)
        sched.submit(r, now=0.0)
        batch = sched.schedule(now=0.0, running=[])
        assert batch == [r]
        assert r.state == RequestState.RUNNING
        assert pool.used_requests == 30          # input + predicted output
        assert cache.resident(0)

    def test_quota_charge_includes_adapter(self):
        pool, cache, sched = make_sched(sizes={0: 50})
        r = req(10, 20, adapter=0)
        sched.submit(r, now=0.0)
        sched.schedule(now=0.0, running=[])
        assert sched.queues[0].used == 10 + 20 + 50

    def test_finish_returns_quota_and_memory(self):
        pool, cache, sched = make_sched()
        r = req(10, 20)
        sched.submit(r, now=0.0)
        sched.schedule(now=0.0, running=[])
        sched.on_finish(r, now=1.0)
        assert sched.queues[0].used == 0
        assert pool.used_requests == 0
        assert cache.resident(0)   # Chameleon keeps the adapter cached

    def test_batch_slot_limit(self):
        pool, cache, sched = make_sched(max_batch_requests=2)
        rs = [req(1, 1, adapter=i % 4) for i in range(5)]
        for r in rs:
            sched.submit(r, now=0.0)
        batch = sched.schedule(now=0.0, running=[])
        assert len(batch) == 2


class TestMultiQueue:
    def _heterogeneous_sched(self):
        pool, cache, sched = make_sched(capacity=5000, t_refresh=0.0,
                                        refresh_min_samples=8)
        rng = np.random.default_rng(0)
        # Bimodal WRS population: small and large requests.
        for i in range(40):
            if i % 2 == 0:
                r = req(8, 8, adapter=i % 4, t=0.0)
            else:
                r = req(400, 400, adapter=i % 4, t=0.0)
            sched.submit(r, now=0.0)
        sched.refresh(now=1.0)
        return pool, cache, sched

    def test_kmeans_splits_bimodal_into_queues(self):
        _, _, sched = self._heterogeneous_sched()
        assert len(sched.queues) >= 2
        lens = [len(q.reqs) for q in sched.queues]
        assert sum(lens) == 40
        assert all(l > 0 for l in (lens[0], lens[-1]))

    def test_small_requests_ride_express_lane(self):
        _, _, sched = self._heterogeneous_sched()
        batch = sched.schedule(now=1.0, running=[])
        small = [r for r in batch if r.input_len == 8]
        assert small, "express lane must admit small requests"

    def test_all_queues_represented_no_starvation(self):
        _, _, sched = self._heterogeneous_sched()
        batch = sched.schedule(now=1.0, running=[])
        sizes = {r.input_len for r in batch}
        assert sizes >= {8, 400}, (
            "paper: every iteration admits from all queues")

    def test_quota_totals_cover_pool(self):
        _, _, sched = self._heterogeneous_sched()
        assert sum(q.quota for q in sched.queues) == sched.pool.capacity_tokens


class TestSpareRedistribution:
    def test_phase2_lends_leftover_tokens(self):
        # One queue empty -> its quota must be lendable to a loaded queue.
        pool, cache, sched = make_sched(capacity=1000, t_refresh=0.0,
                                        refresh_min_samples=4)
        for i in range(8):
            sched.submit(req(10, 10, adapter=i % 4), now=0.0)
        sched.refresh(now=0.5)
        # Drain everything; then construct a state where queue 0 is empty
        # and queue with big requests needs more than its own quota.
        batch = sched.schedule(now=1.0, running=[])
        assert batch, "phase 1 + 2 should admit"
        total_used = sum(q.used for q in sched.queues)
        charged = sum(t for r in batch for _, t in r.charges)
        assert total_used == charged


class TestBypass:
    def test_bypass_on_adapter_blockage(self):
        # Head request's adapter cannot fit; a younger small request whose
        # adapter is resident must bypass.
        sizes = {0: 900, 1: 10}
        pool, cache, sched = make_sched(capacity=1000, sizes=sizes)
        # Make adapter 1 resident.
        cache.acquire(1, now=0.0); cache.release(1, now=0.0)
        # Fill pool so adapter 0 (900 tokens) can't fit: reserve 200.
        pool.reserve_request(999, 200)
        running = [req(10, 50, adapter=1)]
        running[0].generated = 0
        head = req(10, 10, adapter=0, t=0.0)     # blocked on adapter memory
        young = req(10, 10, adapter=1, t=0.1)    # adapter resident
        sched.submit(head, now=0.1)
        sched.submit(young, now=0.1)
        batch = sched.schedule(now=0.2, running=running)
        assert young in batch and head not in batch
        assert young.bypassed
        assert sched.n_bypassed == 1

    def test_bypass_respects_head_wait_bound(self):
        sizes = {0: 900, 1: 10}
        pool, cache, sched = make_sched(capacity=1000, sizes=sizes)
        cache.acquire(1, now=0.0); cache.release(1, now=0.0)
        pool.reserve_request(999, 200)
        # Running request finishes in 5 predicted tokens; bypasser would
        # need 500 -> must NOT bypass.
        run = req(10, 5, adapter=1)
        run.predicted_output = 5
        head = req(10, 10, adapter=0)
        young = req(10, 500, adapter=1)
        sched.submit(head, now=0.1)
        sched.submit(young, now=0.1)
        batch = sched.schedule(now=0.2, running=[run])
        assert young not in batch

    def test_squash_requeues_and_counts(self):
        pool, cache, sched = make_sched()
        r = req(10, 20)
        sched.submit(r, now=0.0)
        sched.schedule(now=0.0, running=[])
        r.bypassed = True
        r.generated = 25   # exceeded prediction of 20
        sched.on_squash(r, now=1.0)
        assert sched.n_squashed == 1
        assert r.state == RequestState.QUEUED
        assert pool.used_requests == 0
        assert sched.pending_count() == 1


class TestBaselines:
    def test_fifo_preserves_order(self):
        pool = MemoryPool(capacity_tokens=1000)
        cache = AdapterCache(pool, catalog({i: 10 for i in range(4)}),
                             enabled=False)
        pred = NoisyOraclePredictor(accuracy=1.0)
        sched = FIFOScheduler(pool, cache, cache.catalog, pred)
        rs = [req(10, 10, adapter=i, t=float(i)) for i in range(4)]
        for r in rs:
            sched.submit(r, now=r.arrival_time)
        batch = sched.schedule(now=5.0, running=[])
        assert batch == rs

    def test_fifo_head_of_line_blocks(self):
        pool = MemoryPool(capacity_tokens=100)
        cache = AdapterCache(pool, catalog({0: 10, 1: 10}), enabled=False)
        pred = NoisyOraclePredictor(accuracy=1.0)
        sched = FIFOScheduler(pool, cache, cache.catalog, pred)
        big = req(80, 80, adapter=0)     # cannot fit (needs 160+10)
        small = req(5, 5, adapter=1)
        sched.submit(big, now=0.0)
        sched.submit(small, now=0.0)
        batch = sched.schedule(now=0.0, running=[])
        assert batch == []               # HoL blocking, by design

    def test_sjf_prefers_short_predicted(self):
        pool = MemoryPool(capacity_tokens=10000)
        cache = AdapterCache(pool, catalog({0: 10, 1: 10}), enabled=False)
        pred = NoisyOraclePredictor(accuracy=1.0)
        sched = SJFScheduler(pool, cache, cache.catalog, pred,
                             max_batch_requests=1, aging_rate=0.0)
        long_r = req(10, 500, adapter=0, t=0.0)
        short_r = req(10, 5, adapter=1, t=1.0)
        sched.submit(long_r, now=0.0)
        sched.submit(short_r, now=1.0)
        batch = sched.schedule(now=1.0, running=[])
        assert batch == [short_r]

    def test_sjf_aging_eventually_promotes_long(self):
        pool = MemoryPool(capacity_tokens=10000)
        cache = AdapterCache(pool, catalog({0: 10, 1: 10}), enabled=False)
        pred = NoisyOraclePredictor(accuracy=1.0)
        sched = SJFScheduler(pool, cache, cache.catalog, pred,
                             max_batch_requests=1, aging_rate=10.0)
        long_r = req(10, 500, adapter=0, t=0.0)
        sched.submit(long_r, now=0.0)
        short_r = req(10, 5, adapter=1, t=100.0)
        sched.submit(short_r, now=100.0)
        batch = sched.schedule(now=100.0, running=[])
        assert batch == [long_r], "aged long request outranks fresh short"
