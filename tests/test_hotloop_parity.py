"""Fused device-resident decode hot loop: whole-engine token parity.

The fused loop (DESIGN §2) changes *how* tokens are produced — one
donated-buffer jit dispatch fuses decode + sampling + cache_len
advance, and an adaptive K-step micro-horizon syncs K tokens at a time
— but must never change *which* tokens are produced. This suite A/Bs
the fused loop against the seed two-dispatch loop across paged/dense,
greedy/sampled, mid-stream squash (page preemption) and mid-horizon
finish, plus the satellite regressions: the virtual-clock idle wait
and the batch-epoch-cached device state.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Request, RequestState, SamplingParams
from repro.models import api
from repro.serving.engine import ChameleonEngine, EngineConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


BASE = dict(max_slots=4, max_len=128, n_lora_slots=4, n_adapters=8,
            seed=0)


def make_engine(small_model, fused, **kw):
    cfg, params = small_model
    return ChameleonEngine(cfg, params, EngineConfig(
        **{**BASE, **kw, "fused_hotloop": fused}))


def run_to_completion(eng, specs, sampling=None, max_steps=20_000):
    reqs = [Request(input_len=i, output_len=o, adapter_id=a)
            for i, o, a in specs]
    handles = [eng.submit(r, sampling=sampling) for r in reqs]
    steps = 0
    while eng.busy() and steps < max_steps:
        eng.step()
        eng.pool.check_invariants()
        steps += 1
    assert not eng.busy(), "engine failed to drain"
    return reqs, handles


def fixed_trace(n=10, seed=3, adapters=8):
    rng = np.random.default_rng(seed)
    return [(int(rng.integers(4, 30)), int(rng.integers(2, 40)),
             int(rng.integers(0, adapters))) for _ in range(n)]


class TestFusedSeedParity:
    @pytest.mark.parametrize("paged", (False, True))
    def test_greedy_token_parity(self, small_model, paged):
        """Fused == seed, token for token, both KV layouts, and the
        handle streams match the internal record."""
        specs = fixed_trace()
        outs = {}
        for fused in (False, True):
            eng = make_engine(small_model, fused, paged=paged)
            reqs, handles = run_to_completion(eng, specs)
            assert eng.stats()["completed"] == len(specs)
            streamed = [h.tokens for h in handles]
            assert streamed == [eng.outputs[r.req_id] for r in reqs]
            outs[fused] = streamed
        assert outs[True] == outs[False], (
            "fused hot loop changed decoded tokens")

    @pytest.mark.parametrize("paged", (False, True))
    def test_sampled_token_parity(self, small_model, paged):
        """Stochastic sampling is keyed on (seed, position), so the
        fused scan must resample the identical stream."""
        sp = SamplingParams(temperature=0.8, top_k=12, top_p=0.9,
                            seed=1234)
        specs = fixed_trace(n=6, seed=5)
        outs = {}
        for fused in (False, True):
            eng = make_engine(small_model, fused, paged=paged)
            _, handles = run_to_completion(eng, specs, sampling=sp)
            outs[fused] = [h.tokens for h in handles]
        assert outs[True] == outs[False], (
            "fused hot loop changed sampled tokens")

    def test_mid_stream_squash_parity(self, small_model):
        """Page preemption mid-decode: the fused run must preempt,
        preserve the streamed prefix, and finish with exactly the
        seed run's tokens (squash continuation is re-executed
        deterministically)."""
        spec = dict(input_len=8, output_len=40, adapter_id=0)
        ref_eng = make_engine(small_model, fused=False)
        ref = ref_eng.submit(Request(**spec)).result().tokens

        eng = make_engine(small_model, fused=True)
        h = eng.submit(Request(**spec))
        it = h.stream()
        for _ in range(4):
            next(it)
        prefix = list(h.tokens)
        stolen, eng.free_pages = eng.free_pages, []
        for _ in range(30):
            eng.step()
            if eng.n_preempted:
                break
        assert eng.n_preempted >= 1, "steal must force a preemption"
        assert h.tokens[:len(prefix)] == prefix, "stream rewound"
        eng.free_pages = stolen
        eng.drain()
        assert h.state is RequestState.FINISHED
        assert h.tokens == ref, "squash continuation diverged from seed"
        assert h.req.squash_count >= 1

    def test_mid_horizon_finish_no_post_eos_tokens(self, small_model):
        """A request hitting its end *inside* a K-step scan must not
        emit tokens past it: the short request's handle gets exactly
        output_len tokens while a long co-batched request keeps the
        batch (and its horizons) running."""
        eng = make_engine(small_model, fused=True)
        short = eng.submit(Request(input_len=8, output_len=5,
                                   adapter_id=0))
        long = eng.submit(Request(input_len=8, output_len=50,
                                  adapter_id=1))
        eng.drain()
        assert short.state is RequestState.FINISHED
        assert len(short.tokens) == 5, (
            f"post-EOS tokens leaked from the horizon: {short.tokens}")
        assert len(long.tokens) == 50
        # And the same pair on the seed loop decodes identically.
        ref = make_engine(small_model, fused=False)
        s2 = ref.submit(Request(input_len=8, output_len=5, adapter_id=0))
        l2 = ref.submit(Request(input_len=8, output_len=50,
                                adapter_id=1))
        ref.drain()
        assert short.tokens == s2.tokens and long.tokens == l2.tokens

    def test_stop_token_mid_horizon(self, small_model):
        """A SamplingParams stop id sampled inside a horizon ends the
        stream on that token (kept, vLLM-style), identically to the
        seed loop."""
        ref_eng = make_engine(small_model, fused=False)
        ref = ref_eng.submit(Request(input_len=8, output_len=30,
                                     adapter_id=1)).result().tokens
        # A token whose *first* occurrence is a few steps in, so the
        # stop lands inside a K-step horizon, not at its boundary.
        stop, cut = next((t, i) for i, t in enumerate(ref)
                         if i >= 4 and ref.index(t) == i)
        outs = {}
        for fused in (False, True):
            eng = make_engine(small_model, fused)
            res = eng.submit(
                Request(input_len=8, output_len=30, adapter_id=1),
                sampling=SamplingParams(stop_token_ids=(stop,))
            ).result()
            assert res.finished
            outs[fused] = res.tokens
        assert outs[True] == outs[False] == ref[:cut + 1]

    def test_seed_loop_still_selectable(self, small_model):
        eng = make_engine(small_model, fused=False)
        assert not eng.fused
        eng2 = make_engine(small_model, fused=True)
        assert eng2.fused

    def test_moe_family_fused_parity(self):
        """`api.supports_fused` claims MoE: the fused loop must decode
        an MoE engine token-identically to the seed loop (dense KV —
        MoE has no paged decode)."""
        cfg = get_config("qwen3-moe-235b-a22b").reduced()
        assert api.supports_fused(cfg) and not api.supports_paged(cfg)
        params = api.init_params(cfg, jax.random.PRNGKey(0),
                                 jnp.float32)
        outs = {}
        for fused in (False, True):
            eng = ChameleonEngine(cfg, params, EngineConfig(
                max_slots=2, max_len=64, n_lora_slots=2, n_adapters=2,
                seed=0, fused_hotloop=fused))
            hs = [eng.submit(Request(input_len=8, output_len=12,
                                     adapter_id=i)) for i in range(2)]
            eng.drain()
            outs[fused] = [h.tokens for h in hs]
        assert outs[True] == outs[False]
        assert all(len(t) == 12 for t in outs[True])


class TestHotloopSatellites:
    def test_virtual_clock_idle_wait_does_not_sleep(self, small_model):
        """Regression (engine.py idle wait): with an injected clock the
        modeled load-ready time is *virtual*, so the idle step must not
        ``time.sleep`` real wall time for it. The seed behaviour slept
        up to 50 ms per step — 100 idle steps took seconds."""
        cfg, params = small_model
        vnow = [0.0]
        eng = ChameleonEngine(
            cfg, params,
            EngineConfig(**BASE, h2d_gbps=1e-6),   # ~minutes of modeled load
            clock=lambda: vnow[0])
        eng.submit(Request(input_len=8, output_len=4, adapter_id=0))
        for _ in range(5):      # dispatch the load; request defers
            eng.step()
        assert eng._pending_loads, "load should be modeled in flight"
        t0 = time.monotonic()
        for _ in range(100):
            eng.step()          # idle: nothing active, load not ready
        elapsed = time.monotonic() - t0
        assert elapsed < 1.0, (
            f"idle steps slept wall time under a virtual clock "
            f"({elapsed:.2f}s for 100 steps)")
        # Advancing the virtual clock retires the load and the request.
        vnow[0] = 1e9
        eng.drain()
        assert eng.stats()["completed"] == 1

    def test_wall_clock_idle_wait_still_sleeps(self, small_model):
        """Without an injected clock the idle wait must still back off
        instead of busy-spinning."""
        eng = make_engine(small_model, fused=True, h2d_gbps=1e-6)
        eng.submit(Request(input_len=8, output_len=4, adapter_id=0))
        for _ in range(5):
            eng.step()
        assert eng._pending_loads
        t0 = time.monotonic()
        for _ in range(3):
            eng.step()
        assert time.monotonic() - t0 > 1e-4
        eng.flush_loads()
        eng.drain()

    def test_batch_epoch_only_moves_at_boundaries(self, small_model):
        """Satellite: ``_all_greedy`` + sampling arrays are cached on
        the batch epoch — pure decode steps must not bump it (the seed
        loop rebuilt them from Python requests every step)."""
        eng = make_engine(small_model, fused=True)
        h = eng.submit(Request(input_len=8, output_len=60, adapter_id=0))
        while not eng.active.any():
            eng.step()
        e0 = eng.stats()["batch_epoch"]
        assert e0 > 0, "placement must bump the epoch"
        for _ in range(3):      # decode-only steps: stable batch
            eng.step()
        assert eng.stats()["batch_epoch"] == e0, (
            "pure decode steps must not invalidate device batch state")
        eng.drain()
        assert eng.stats()["batch_epoch"] > e0, (
            "finish must bump the epoch")
        assert h.state is RequestState.FINISHED

    def test_cancel_during_horizon(self, small_model):
        """cancel() against a running fused engine lands at the next
        step boundary even with a dispatched-but-unsynced horizon, and
        the handle receives no tokens after cancel() returns (tokens
        already in flight on device are dropped at the handle)."""
        eng = make_engine(small_model, fused=True)
        h = eng.submit(Request(input_len=8, output_len=100, adapter_id=0))
        next(h.stream())
        n_at_cancel = len(h.tokens)
        assert h.cancel()
        eng.drain()
        assert h.state is RequestState.CANCELLED
        assert len(h.tokens) == n_at_cancel, (
            "post-cancel tokens leaked to the handle")
        eng.pool.check_invariants()
        assert eng.pool.used_requests == 0

    def test_page_accounting_holds_every_fused_step(self, small_model):
        """Pool invariants and page/table consistency hold at every
        step boundary of the fused loop (horizons allocate nothing
        mid-scan)."""
        eng = make_engine(small_model, fused=True)
        reqs = [Request(input_len=i, output_len=o, adapter_id=a)
                for i, o, a in fixed_trace(8, seed=7)]
        for r in reqs:
            eng.submit(r)
        ps = eng.pool.page_size
        total = eng.n_pages - 1
        steps = 0
        while eng.busy() and steps < 10_000:
            eng.step()
            eng.pool.check_invariants(free_page_ids=eng.free_pages)
            # Prefix sharing splits a slot's pages into private (charged
            # to the request ledger) and shared (charged once to the
            # tree, possibly mapped by several slots).
            shared = set(eng.pool.shared_page_ids())
            priv = sum(1 for plist in eng.slot_pages
                       for p in plist if p not in shared)
            assert eng.pool.used_requests == priv * ps
            assert len(eng.free_pages) + priv + len(shared) == total
            steps += 1
        assert eng.stats()["completed"] == len(reqs)
