"""Query-chunked attention == single-block attention (exactness), and
HLO collective parser unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.models.layers as L
from repro.roofline.hlo_stats import (collective_bytes_from_text,
                                      scaled_collective_bytes)


class TestBlockedAttention:
    def test_chunked_equals_unchunked_causal(self, monkeypatch):
        monkeypatch.setattr(L, "Q_CHUNK_THRESHOLD", 64)
        monkeypatch.setattr(L, "Q_CHUNK", 64)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        B, S, H, Kh, dh = 2, 256, 4, 2, 32
        q = jax.random.normal(ks[0], (B, S, H, dh))
        k = jax.random.normal(ks[1], (B, S, Kh, dh))
        v = jax.random.normal(ks[2], (B, S, Kh, dh))
        y_chunked = L.gqa_attention(q, k, v, causal=True)
        monkeypatch.setattr(L, "Q_CHUNK_THRESHOLD", 10**9)
        y_full = L.gqa_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(y_chunked),
                                   np.asarray(y_full),
                                   rtol=1e-5, atol=1e-5)

    def test_chunked_with_kv_len_mask(self, monkeypatch):
        monkeypatch.setattr(L, "Q_CHUNK_THRESHOLD", 64)
        monkeypatch.setattr(L, "Q_CHUNK", 64)
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        B, S, H, dh = 2, 128, 2, 32
        q = jax.random.normal(ks[0], (B, S, H, dh))
        k = jax.random.normal(ks[1], (B, S, H, dh))
        v = jax.random.normal(ks[2], (B, S, H, dh))
        kv_len = jnp.array([40, 128])
        y_c = L.gqa_attention(q, k, v, causal=False, kv_len=kv_len)
        monkeypatch.setattr(L, "Q_CHUNK_THRESHOLD", 10**9)
        y_f = L.gqa_attention(q, k, v, causal=False, kv_len=kv_len)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_f),
                                   rtol=1e-5, atol=1e-5)


HLO_SAMPLE = """
HloModule test
%body (p: f32[8]) -> f32[8] {
  %ar = f32[4,128]{1,0} all-reduce(%x), channel_id=1, replica_groups=[4,4]<=[16], to_apply=%add
}
%wide.body2 (p: f32[8]) -> f32[8] {
  %ag = bf16[64,32]{1,0} all-gather(%y), channel_id=2, replica_groups=[8,2]<=[16], dimensions={0}
}
ENTRY %main (a: f32[4]) -> f32[4] {
  %w = f32[4] while(%a), condition=%cond, body=%body
  %w2 = f32[4] while(%w), condition=%cond2, body=%wide.body2
  %cp = f32[2,64]{1,0} collective-permute(%z), channel_id=3
}
"""


class TestHloStats:
    def test_parses_ops_and_loop_attribution(self):
        out = collective_bytes_from_text(HLO_SAMPLE, 16)
        # all-reduce in a loop body: 2·b·(k-1)/k with b=4·128·4, k=4.
        assert abs(out["per_op"]["all-reduce@loop"]
                   - 2 * 2048 * 3 / 4) < 1e-6
        # all-gather in the second loop body: b·(k-1)/k, b=64·32·2, k=2.
        assert abs(out["per_op"]["all-gather@loop"] - 4096 * 0.5) < 1e-6
        # permute at entry: full result bytes.
        assert out["per_op"]["collective-permute"] == 2 * 64 * 4

    def test_loop_scaling(self):
        out = collective_bytes_from_text(HLO_SAMPLE, 16)
        total = scaled_collective_bytes(out, n_layers=10)
        expect = (2 * 2048 * 3 / 4) * 10 + (4096 * 0.5) * 10 + 512
        assert abs(total - expect) < 1e-6
