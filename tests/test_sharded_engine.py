"""Mesh-sharded serving data plane: whole-engine token parity + units.

Threading a ``jax.sharding.Mesh`` through the engine changes *where*
tensors live — weights and LoRA slots over "model", KV pages and
batch-state vectors over "data" — but must never change *which* tokens
are produced (DESIGN §4: exact-reductions mode keeps every FP
reduction in single-device order). This suite A/Bs ``mesh_shape=None``
against (1,1)/(2,1)/(1,2)/(2,2) across paged/dense, fused on/off,
greedy/sampled, and the prefix cache with shared-page refcounts; plus
unit tests for ``make_serving_mesh`` validation, ``fit_spec``
warn-once, per-shard telemetry, ``EngineCluster`` device budgeting and
``build_system(mesh_shape=...)``.

Mesh cases needing N devices skip unless the host exposes them — CI's
sharded-smoke job runs with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Request, SamplingParams
from repro.launch.mesh import make_serving_mesh
from repro.models import api
from repro.serving.engine import ChameleonEngine, EngineConfig


def needs(n):
    return pytest.mark.skipif(
        len(jax.devices()) < n,
        reason=f"needs {n} devices (set "
               f"XLA_FLAGS=--xla_force_host_platform_device_count=4)")


MESHES = [pytest.param((1, 1), marks=needs(1)),
          pytest.param((2, 1), marks=needs(2)),
          pytest.param((1, 2), marks=needs(2)),
          pytest.param((2, 2), marks=needs(4))]


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


BASE = dict(max_slots=4, max_len=128, n_lora_slots=4, n_adapters=8,
            seed=0)


def make_engine(small_model, mesh_shape, **kw):
    cfg, params = small_model
    return ChameleonEngine(cfg, params, EngineConfig(
        **{**BASE, **kw, "mesh_shape": mesh_shape}))


def run_trace(eng, n=8, seed=0, sample_every=3, max_steps=20_000):
    """Mixed greedy/sampled trace; outputs keyed by *submission order*
    (req_ids are globally monotonic across engine instances, so they
    differ between the A and B arm of a parity test)."""
    rng = np.random.default_rng(seed)
    handles = []
    for i in range(n):
        r = Request(input_len=int(rng.integers(8, 40)),
                    output_len=int(rng.integers(4, 12)),
                    adapter_id=int(rng.integers(0, 8)))
        sp = (SamplingParams(temperature=0.8, top_k=8, seed=i)
              if sample_every and i % sample_every == 2 else None)
        handles.append(eng.submit(r, sampling=sp))
    steps = 0
    while eng.busy() and steps < max_steps:
        eng.step()
        eng.pool.check_invariants(
            free_page_ids=getattr(eng, "free_pages", None))
        steps += 1
    assert not eng.busy(), "engine failed to drain"
    return [h.tokens for h in handles]


def shared_prefix_prompts(n=8, prefix_len=40, n_prefixes=2, seed=11,
                          vocab=256):
    rng = np.random.default_rng(seed)
    pres = [rng.integers(3, vocab, size=prefix_len).tolist()
            for _ in range(n_prefixes)]
    return [pres[i % n_prefixes]
            + rng.integers(3, vocab, size=int(rng.integers(4, 13))).tolist()
            for i in range(n)]


def run_prompts(eng, prompts, adapters, out_len=8, max_steps=20_000):
    handles = [eng.submit(Request(input_len=len(p), output_len=out_len,
                                  adapter_id=a, prompt=list(p)))
               for p, a in zip(prompts, adapters)]
    steps = 0
    while eng.busy() and steps < max_steps:
        eng.step()
        eng.pool.check_invariants(
            free_page_ids=getattr(eng, "free_pages", None))
        steps += 1
    assert not eng.busy(), "engine failed to drain"
    return [h.tokens for h in handles]


# --------------------------------------------------------- token parity
class TestShardedParity:
    @pytest.mark.parametrize("mesh_shape", MESHES)
    @pytest.mark.parametrize("paged", (False, True))
    def test_token_parity_fused(self, small_model, paged, mesh_shape):
        """mesh == no-mesh, token for token, both KV layouts, fused
        hot loop, mixed greedy/sampled traffic."""
        base = run_trace(make_engine(small_model, None, paged=paged,
                                     fused_hotloop=True))
        got = run_trace(make_engine(small_model, mesh_shape, paged=paged,
                                    fused_hotloop=True))
        assert got == base, "mesh sharding changed decoded tokens"

    @pytest.mark.parametrize("mesh_shape", MESHES)
    def test_token_parity_unfused(self, small_model, mesh_shape):
        """The seed two-dispatch loop (decode jit + host sample) must
        hold parity too — it exercises the non-fused logits path."""
        base = run_trace(make_engine(small_model, None, paged=True,
                                     fused_hotloop=False))
        got = run_trace(make_engine(small_model, mesh_shape, paged=True,
                                    fused_hotloop=False))
        assert got == base

    @pytest.mark.parametrize("mesh_shape", MESHES)
    def test_prefix_cache_parity_and_refcounts(self, small_model,
                                               mesh_shape):
        """Prefix cache on a sharded pool: parity vs the no-mesh
        prefix-on engine, pages actually shared (refcounts observed),
        and every refcount back to the tree's own after drain."""
        prompts = shared_prefix_prompts(n=8)
        adapters = [i % 2 for i in range(8)]
        base_eng = make_engine(small_model, None, paged=True,
                               fused_hotloop=True, prefix_cache=True)
        base = run_prompts(base_eng, prompts, adapters)
        eng = make_engine(small_model, mesh_shape, paged=True,
                          fused_hotloop=True, prefix_cache=True)
        got = run_prompts(eng, prompts, adapters)
        assert got == base, "sharded prefix cache changed tokens"
        assert eng.prefix_hit_tokens > 0, "no pages were reused"
        shared = eng.pool.shared_page_ids()
        assert shared, "prefix tree retained no pages"
        assert all(eng.pool.shared_refcount(p) == 1 for p in shared)
        eng.pool.check_invariants(free_page_ids=eng.free_pages)


# ------------------------------------------------------------ telemetry
class TestShardTelemetry:
    def test_no_mesh_no_shard_stats(self, small_model):
        eng = make_engine(small_model, None)
        assert eng.shard_stats() == {}
        assert "mesh_shape" not in eng.stats()

    @pytest.mark.parametrize("mesh_shape", MESHES[1:])
    def test_shard_stats_surface(self, small_model, mesh_shape):
        eng = make_engine(small_model, mesh_shape, paged=True)
        run_trace(eng, n=4)
        s = eng.shard_stats()
        d, m = mesh_shape
        assert tuple(s["mesh_shape"]) == mesh_shape
        assert s["n_devices"] == d * m
        assert len(s["per_shard_pages_used"]) == d
        assert s["per_shard_pages_total"] * d == eng.n_pages
        assert s["per_shard_lora_slot_bytes"] > 0
        if d * m > 1:
            assert s["collective_dispatches"] > 0
            assert 0.0 <= s["collective_frac"] <= 1.0
        # Gauges flow into the metrics surface for cluster merging.
        assert eng.metrics().sched_stats["n_devices"] == d * m

    @pytest.mark.parametrize("mesh_shape", MESHES[1:])
    def test_pool_accounting_mesh_invariant(self, small_model,
                                            mesh_shape):
        """Global page/slot accounting must not depend on the mesh —
        only the per-shard view divides by the data-axis size."""
        a = make_engine(small_model, None, paged=True)
        b = make_engine(small_model, mesh_shape, paged=True)
        assert b.pool.snapshot()["capacity"] == \
            a.pool.snapshot()["capacity"]
        # Pool telemetry sizes per *device* (mesh.size), while pages
        # physically shard over the data axis only.
        assert b.pool.n_shards == mesh_shape[0] * mesh_shape[1]
        # Physical pages round up to the data axis; logical capacity
        # (hence every control-plane decision) stays mesh-invariant.
        assert b.n_pages % mesh_shape[0] == 0


# ------------------------------------------------------ mesh construction
class TestMakeServingMesh:
    @needs(2)
    def test_shapes_and_axes(self):
        mesh = make_serving_mesh(2, 1)
        assert mesh.axis_names == ("data", "model")
        assert dict(mesh.shape) == {"data": 2, "model": 1}
        mesh = make_serving_mesh(2, 2)
        assert dict(mesh.shape) == {"data": 1, "model": 2}

    def test_rejects_bad_arguments(self):
        with pytest.raises(ValueError, match="positive"):
            make_serving_mesh(0, 1)
        with pytest.raises(ValueError, match="positive"):
            make_serving_mesh(2, 0)
        with pytest.raises(ValueError, match="divide"):
            make_serving_mesh(3, 2)

    def test_rejects_too_many_devices(self):
        n = len(jax.devices())
        with pytest.raises(ValueError, match="XLA_FLAGS"):
            make_serving_mesh(2 * n, 1)


# ------------------------------------------------------------- fit_spec
class TestFitSpecWarnOnce:
    @needs(2)
    def test_warns_once_per_tensor(self):
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import fit_spec
        mesh = make_serving_mesh(2, 2)
        shape, spec = (3, 8), P("model", None)   # 3 % 2 != 0 -> dropped
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            first = fit_spec(shape, spec, mesh, warn_label="w_odd")
            again = fit_spec(shape, spec, mesh, warn_label="w_odd")
        assert first == P() and again == P()
        msgs = [str(x.message) for x in w if "w_odd" in str(x.message)]
        assert len(msgs) == 1, "fit_spec should warn once per tensor"
        assert "replicated" in msgs[0]

    @needs(2)
    def test_silent_without_label(self):
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import fit_spec
        mesh = make_serving_mesh(2, 2)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            fit_spec((5, 8), P("model", None), mesh)
        assert not [x for x in w if "fit_spec" in str(x.message)]


# ----------------------------------------------------- cluster / systems
class TestClusterAndSystems:
    def test_cluster_rejects_overcommitted_devices(self, small_model):
        from repro.serving.cluster import EngineCluster, \
            EngineClusterConfig
        cfg, params = small_model
        n = len(jax.devices())
        with pytest.raises(ValueError, match="devices"):
            EngineCluster(cfg, params,
                          ecfg=EngineConfig(**BASE, mesh_shape=(2, 1)),
                          ccfg=EngineClusterConfig(n_engines=n))

    @needs(2)
    def test_cluster_of_sharded_engines(self, small_model):
        from repro.serving.cluster import EngineCluster, \
            EngineClusterConfig
        cfg, params = small_model
        cluster = EngineCluster(cfg, params,
                                ecfg=EngineConfig(**BASE,
                                                  mesh_shape=(1, 2)),
                                ccfg=EngineClusterConfig(n_engines=1))
        eng = cluster.engines[0]
        assert eng.mesh is not None and eng.mesh.size == 2

    @needs(2)
    def test_build_system_threads_mesh_shape(self, small_model):
        from repro.serving.systems import build_system
        cfg, params = small_model
        eng = build_system("chameleon", "engine", model_cfg=cfg,
                           params=params, ecfg=EngineConfig(**BASE),
                           mesh_shape=(1, 2))
        assert dict(eng.mesh.shape) == {"data": 1, "model": 2}

    def test_build_system_rejects_mesh_on_sim_tier(self):
        from repro.serving.systems import build_system
        with pytest.raises(ValueError, match="mesh"):
            build_system("chameleon", "sim", mesh_shape=(1, 2))
