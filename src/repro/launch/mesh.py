"""Production meshes (MULTI-POD DRY-RUN step 1).

A function, not a module constant — importing this module must never
touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_serving_mesh(n_devices: int, model_parallel: int = 1):
    """The serving data plane's ("data", "model") mesh.

    ``EngineConfig.mesh_shape = (d, m)`` resolves through here (the
    single factory — the engine never calls jax.make_mesh itself):
    d*m devices, batch/KV-pages over "data", weights/LoRA-slot dout
    over "model". CPU CI gets its devices from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    if n_devices < 1 or model_parallel < 1:
        raise ValueError(
            f"mesh shape must be positive, got n_devices={n_devices} "
            f"model_parallel={model_parallel}")
    if n_devices % model_parallel != 0:
        raise ValueError(
            f"model_parallel={model_parallel} must divide "
            f"n_devices={n_devices}")
    avail = len(jax.devices())
    if n_devices > avail:
        raise ValueError(
            f"mesh wants {n_devices} devices but only {avail} are "
            f"available (CPU CI: set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_devices})")
    return jax.make_mesh((n_devices // model_parallel, model_parallel),
                         ("data", "model"))
