"""Production meshes (MULTI-POD DRY-RUN step 1).

A function, not a module constant — importing this module must never
touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
