import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run (deliverable e).

For every assigned (architecture × input shape) cell, lower + compile
the appropriate step (train_step / prefill / serve decode) on the
production meshes — 16×16 single-pod and 2×16×16 multi-pod — and
record memory_analysis / cost_analysis / collective-byte totals.

The XLA_FLAGS line above MUST run before any other jax-touching import
(jax locks the device count at first init), which is why it precedes
the module docstring's imports. Do not import this module from tests.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch qwen3-14b] [--shape decode_32k] [--mesh single|multi|both]
        [--out results/dryrun.json]
Results append incrementally so a crashed sweep resumes.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import (ARCH_IDS, SHAPES, assigned_cells,
                           cell_applicable)
from repro.configs import get_config
from repro.distributed.act_sharding import activation_sharding
from repro.launch.cases import build_case
from repro.launch.mesh import make_production_mesh
from repro.roofline.hlo_stats import (collective_bytes_from_text,
                                      scaled_collective_bytes)


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args_sds, in_sh = build_case(arch, shape_name, mesh)
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    from repro.configs import SHAPE_BY_NAME
    kind = SHAPE_BY_NAME[shape_name].kind
    seq_shard = kind == "train"
    # Donation mirrors production: trainers donate (params, opt) and
    # serving engines update KV caches in place — without it the dry-run
    # double-counts those buffers (16 GB of phantom temp on decode_32k).
    donate = (0, 1) if kind == "train" else ((2,) if kind == "decode"
                                             else ())
    t0 = time.time()
    with mesh, activation_sharding(batch_axes, model_size=16,
                                   seq_shard_boundary=seq_shard,
                                   moe_token_parallel=kind != "train",
                                   mesh=mesh):
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
        lowered = jitted.lower(*args_sds)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo_text = compiled.as_text()
    n_dev = mesh.devices.size
    coll = collective_bytes_from_text(hlo_text, n_devices=n_dev)
    coll["total_bytes_scaled"] = scaled_collective_bytes(
        coll, get_config(arch).n_layers)
    mem_d = {}
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem_d[f] = int(getattr(mem, f, 0) or 0)
    cost_d = {}
    if cost:
        for k in ("flops", "bytes accessed", "transcendentals"):
            if k in cost:
                cost_d[k.replace(" ", "_")] = float(cost[k])
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_d, "cost": cost_d, "collectives": coll,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=("single", "multi", "both"))
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--skip-done", action="store_true", default=True)
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    cells = assigned_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch, shape in cells:
        for multi in meshes:
            key = f"{arch}|{shape}|{'multi' if multi else 'single'}"
            if args.skip_done and key in results and results[key].get("ok"):
                continue
            ok, why = cell_applicable(arch, shape)
            if not ok:
                results[key] = {"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if multi else "16x16",
                                "ok": False, "skipped": True,
                                "reason": why}
                print(f"[skip] {key}: {why}", flush=True)
            else:
                print(f"[run ] {key} ...", flush=True)
                try:
                    results[key] = run_cell(arch, shape, multi)
                    r = results[key]
                    print(f"       ok lower={r['lower_s']}s "
                          f"compile={r['compile_s']}s "
                          f"flops={r['cost'].get('flops', 0):.3e} "
                          f"coll={r['collectives']['total_bytes']:.3e}B",
                          flush=True)
                except Exception as e:            # noqa: BLE001
                    results[key] = {"arch": arch, "shape": shape,
                                    "ok": False,
                                    "error": f"{type(e).__name__}: {e}",
                                    "trace": traceback.format_exc()[-2000:]}
                    print(f"       FAIL {type(e).__name__}: {e}",
                          flush=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results.values() if r.get("ok"))
    n_skip = sum(1 for r in results.values() if r.get("skipped"))
    n_fail = sum(1 for r in results.values()
                 if not r.get("ok") and not r.get("skipped"))
    print(f"done: {n_ok} ok, {n_skip} skipped-by-design, {n_fail} FAILED")


if __name__ == "__main__":
    main()
