"""Serving launcher: trace-driven Chameleon node.

Two backends:
- ``--backend sim``    calibrated DES at production scale (default);
- ``--backend engine`` real JAX engine on a reduced model (CPU-safe).

    PYTHONPATH=src python -m repro.launch.serve --system chameleon --rps 12
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.serving import (NodeConfig, SYSTEM_NAMES, TraceConfig,
                           build_node, synthesize)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--system", default="chameleon", choices=SYSTEM_NAMES)
    ap.add_argument("--backend", default="sim", choices=("sim", "engine"))
    ap.add_argument("--rps", type=float, default=10.0)
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--n-adapters", type=int, default=100)
    ap.add_argument("--hw", default="a40")
    ap.add_argument("--model", default="llama-7b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    if args.backend == "engine":
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.core import Request
        from repro.models import api
        from repro.serving.engine import ChameleonEngine, EngineConfig
        cfg = get_config("chameleon-llama-7b").reduced()
        params = api.init_params(cfg, jax.random.PRNGKey(args.seed),
                                 jnp.float32)
        eng = ChameleonEngine(cfg, params, EngineConfig(
            max_slots=6, max_len=128, n_lora_slots=4, n_adapters=12))
        rng = np.random.default_rng(args.seed)
        for _ in range(24):
            eng.submit(Request(input_len=int(rng.integers(4, 40)),
                               output_len=int(rng.integers(4, 30)),
                               adapter_id=int(rng.integers(0, 12))))
        eng.run_until_drained()
        ttfts = sorted(r.ttft() for r in eng.completed)
        print(f"completed {len(eng.completed)}; "
              f"p50 TTFT {ttfts[len(ttfts)//2]:.3f}s "
              f"p99 TTFT {ttfts[-1]:.3f}s")
        print("cache:", eng.stats()["cache"])
        return

    cfg = NodeConfig(hw=args.hw, model=args.model,
                     n_adapters=args.n_adapters, seed=args.seed)
    sim, adapters, cost = build_node(args.system, cfg)
    trace = synthesize(
        TraceConfig(rps=args.rps, duration_s=args.duration,
                    n_adapters=args.n_adapters, seed=args.seed),
        list(adapters.values()))
    m = sim.run(trace)
    summary = m.summary()
    if args.json:
        print(json.dumps(summary, indent=1, default=float))
    else:
        for k, v in summary.items():
            print(f"{k:>22}: {v if not isinstance(v, float) else round(v, 4)}")


if __name__ == "__main__":
    main()
