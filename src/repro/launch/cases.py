"""Dry-run case construction: (arch × shape × mesh) → jit-able closure
plus fully-sharded ShapeDtypeStruct inputs (no allocation anywhere).

``build_case`` returns:
    fn            — function to jit
    args_sds      — tuple of ShapeDtypeStructs (pytrees)
    in_shardings  — matching pytree of NamedShardings
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPE_BY_NAME, get_config
from repro.distributed import sharding as shp
from repro.models import api
from repro.models.base import Family, ModelConfig, param_shapes
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import make_train_step

LORA_SLOTS = 8
LORA_RMAX = 64

# Gradient-accumulation factors for train_4k: MoE all-to-all receive
# buffers scale with per-step tokens; microbatching is how the big MoE
# cells fit 16 GB/chip (EXPERIMENTS.md §Dry-run).
MICROBATCHES = {
    "qwen3-moe-235b-a22b": 8,
    "llama4-maverick-400b-a17b": 4,
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _named(mesh, spec):
    return NamedSharding(mesh, spec)


def _param_sds(cfg: ModelConfig, dtype=jnp.bfloat16):
    out = {}
    for path, shape in param_shapes(cfg).items():
        leaf = path.split("/")[-1]
        dt = jnp.float32 if leaf in ("A_log", "ssm_D") else dtype
        out[path] = _sds(shape, dt)
    return out


def _opt_sds(params_sds, moment_dtype=jnp.bfloat16):
    out = {"step": _sds((), jnp.int32)}
    for k, v in params_sds.items():
        out[f"m/{k}"] = _sds(v.shape, moment_dtype)
        out[f"v/{k}"] = _sds(v.shape, moment_dtype)
    return out


def _batch_sds(cfg: ModelConfig, B: int, S: int):
    batch = {"tokens": _sds((B, S), jnp.int32),
             "labels": _sds((B, S), jnp.int32)}
    if cfg.family == Family.ENCDEC:
        batch["frames"] = _sds((B, cfg.enc_ctx, cfg.d_model), jnp.bfloat16)
    if cfg.mrope:
        batch["mrope_pos"] = _sds((3, B, S), jnp.int32)
    return batch


def _batch_shardings(cfg, mesh, B_axes, B, S):
    fit = shp.fit_spec
    sh = {"tokens": _named(mesh, fit((B, S), P(B_axes, None), mesh)),
          "labels": _named(mesh, fit((B, S), P(B_axes, None), mesh))}
    if cfg.family == Family.ENCDEC:
        sh["frames"] = _named(mesh, fit(
            (B, cfg.enc_ctx, cfg.d_model), P(B_axes, None, None), mesh))
    if cfg.mrope:
        sh["mrope_pos"] = _named(mesh, fit(
            (3, B, S), P(None, B_axes, None), mesh))
    return sh


def _lora_sds(cfg: ModelConfig, n_stack: int):
    def pair(din, dout):
        return (_sds((n_stack, LORA_SLOTS, din, LORA_RMAX), jnp.bfloat16),
                _sds((n_stack, LORA_SLOTS, LORA_RMAX, dout), jnp.bfloat16))
    return {"q": pair(cfg.d_model, cfg.q_dim),
            "k": pair(cfg.d_model, cfg.kv_dim),
            "v": pair(cfg.d_model, cfg.kv_dim),
            "o": pair(cfg.q_dim, cfg.d_model)}


def _lora_shardings(cfg, mesh):
    pod, data, model = shp._axes(mesh)
    dims = {"q": (cfg.d_model, cfg.q_dim), "k": (cfg.d_model, cfg.kv_dim),
            "v": (cfg.d_model, cfg.kv_dim), "o": (cfg.q_dim, cfg.d_model)}
    out = {}
    for proj, (din, dout) in dims.items():
        a_spec = shp.fit_spec((cfg.n_layers, LORA_SLOTS, din, LORA_RMAX),
                              P(None, None, model, None), mesh)
        b_desired = (P(None, None, None, None) if proj == "o"
                     else P(None, None, None, model))
        b_spec = shp.fit_spec((cfg.n_layers, LORA_SLOTS, LORA_RMAX, dout),
                              b_desired, mesh)
        out[proj] = (_named(mesh, a_spec), _named(mesh, b_spec))
    return out


def _b_axes(mesh):
    pod, data, model = shp._axes(mesh)
    return pod + (data,)


# ----------------------------------------------------------------- cases
def build_case(arch: str, shape_name: str, mesh: Mesh,
               batch_override: int | None = None):
    cfg = get_config(arch)
    spec = SHAPE_BY_NAME[shape_name]
    B = batch_override or spec.global_batch
    S = spec.seq_len
    B_axes = _b_axes(mesh)
    kind = spec.kind

    params_sds = _param_sds(cfg)
    params_sh = shp.param_shardings(
        cfg, {k: v.shape for k, v in params_sds.items()}, mesh, kind)

    if kind == "train":
        opt_cfg = AdamWConfig(moment_dtype="bfloat16")
        step = make_train_step(cfg, opt_cfg,
                               microbatches=MICROBATCHES.get(arch, 1))
        opt_sds = _opt_sds(params_sds)
        opt_sh = shp.opt_shardings(params_sh, mesh)
        batch_sds = _batch_sds(cfg, B, S)
        batch_sh = _batch_shardings(cfg, mesh, B_axes, B, S)
        return (step,
                (params_sds, opt_sds, batch_sds),
                (params_sh, opt_sh, batch_sh))

    use_lora = cfg.family in (Family.DENSE, Family.MOE, Family.VLM,
                              Family.HYBRID)
    if cfg.family == Family.HYBRID:
        from repro.models.hybrid import attn_sites
        lora_stack_n = len(attn_sites(cfg))
    else:
        lora_stack_n = cfg.n_layers

    if kind == "prefill":
        # Keep chunk batch divisible by the data extent (16/32) — a
        # sub-extent chunk loses batch sharding and replicates.
        n_mb = min(MICROBATCHES.get(arch, 1), max(1, B // 16))

        def fn(params, tokens, lora, adapter_idx, extra):
            kw = dict(extra)
            if cfg.family == Family.HYBRID:
                kw["kv_max_len"] = S

            def one(tb, idx_b):
                kw_i = dict(kw)
                if use_lora:
                    kw_i.update(lora=lora, adapter_idx=idx_b)
                return api.prefill(cfg, params, tb, **kw_i)

            if n_mb == 1 or B % n_mb != 0:
                return one(tokens, adapter_idx if use_lora else None)
            # Batch-chunked prefill: the MoE all-to-all receive buffers
            # scale with tokens-per-invocation; chunking the request
            # batch bounds them (serving engines chunk prefill anyway).
            Bc = B // n_mb
            toks = tokens.reshape(n_mb, Bc, S)
            idxs = (adapter_idx.reshape(n_mb, Bc) if use_lora
                    else jnp.zeros((n_mb, Bc), jnp.int32))

            def body(_, inp):
                return None, one(inp[0], inp[1])

            _, (logits, kv) = jax.lax.scan(body, None, (toks, idxs))
            logits = logits.reshape(B, -1)
            kv = jax.tree_util.tree_map(
                lambda a: jnp.moveaxis(a, 0, 2).reshape(
                    a.shape[1], B, *a.shape[3:]), kv)
            return logits, kv

        tokens = _sds((B, S), jnp.int32)
        tok_sh = _named(mesh, shp.fit_spec((B, S), P(B_axes, None), mesh))
        extra_sds, extra_sh = {}, {}
        if cfg.family == Family.ENCDEC:
            extra_sds["frames"] = _sds((B, cfg.enc_ctx, cfg.d_model),
                                       jnp.bfloat16)
            extra_sh["frames"] = _named(mesh, shp.fit_spec(
                (B, cfg.enc_ctx, cfg.d_model), P(B_axes, None, None), mesh))
        if cfg.mrope:
            extra_sds["mrope_pos"] = _sds((3, B, S), jnp.int32)
            extra_sh["mrope_pos"] = _named(mesh, shp.fit_spec(
                (3, B, S), P(None, B_axes, None), mesh))
        lora_sds = _lora_sds(cfg, lora_stack_n) if use_lora else ()
        lora_sh = _lora_shardings(cfg, mesh) if use_lora else ()
        idx_sds = _sds((B,), jnp.int32) if use_lora else ()
        idx_sh = (_named(mesh, shp.fit_spec((B,), P(B_axes), mesh))
                  if use_lora else ())
        return (fn,
                (params_sds, tokens, lora_sds, idx_sds, extra_sds),
                (params_sh, tok_sh, lora_sh, idx_sh, extra_sh))

    # ---- decode -------------------------------------------------------
    state_sds, state_sh = _serve_state(cfg, mesh, B, S, B_axes)

    def fn(params, tokens, state, cache_len, lora, adapter_idx, extra):
        kw = dict(extra)
        if use_lora:
            kw.update(lora=lora, adapter_idx=adapter_idx)
        return api.decode_step(cfg, params, tokens, state, cache_len, **kw)

    tokens = _sds((B, 1), jnp.int32)
    tok_sh = _named(mesh, shp.fit_spec((B, 1), P(B_axes, None), mesh))
    clen_sds = _sds((B,), jnp.int32)
    clen_sh = _named(mesh, shp.fit_spec((B,), P(B_axes), mesh))
    extra_sds, extra_sh = {}, {}
    if cfg.mrope:
        extra_sds["mrope_pos"] = _sds((3, B, 1), jnp.int32)
        extra_sh["mrope_pos"] = _named(mesh, shp.fit_spec(
            (3, B, 1), P(None, B_axes, None), mesh))
    lora_sds = _lora_sds(cfg, lora_stack_n) if use_lora else ()
    lora_sh = _lora_shardings(cfg, mesh) if use_lora else ()
    idx_sds = _sds((B,), jnp.int32) if use_lora else ()
    idx_sh = (_named(mesh, shp.fit_spec((B,), P(B_axes), mesh))
              if use_lora else ())
    return (fn,
            (params_sds, tokens, state_sds, clen_sds, lora_sds, idx_sds,
             extra_sds),
            (params_sh, tok_sh, state_sh, clen_sh, lora_sh, idx_sh,
             extra_sh))


def _serve_state(cfg: ModelConfig, mesh: Mesh, B: int, S: int, B_axes):
    pod, data, model = shp._axes(mesh)
    if cfg.family in (Family.DENSE, Family.MOE, Family.VLM):
        shape = (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim)
        kv_sp = _named(mesh, shp.kv_cache_spec(mesh, shape))
        return ((_sds(shape, jnp.bfloat16), _sds(shape, jnp.bfloat16)),
                (kv_sp, kv_sp))
    if cfg.family == Family.SSM:
        sshape = (cfg.n_layers, B, cfg.d_inner, cfg.d_state)
        cshape = (cfg.n_layers, B, cfg.d_conv - 1, cfg.d_inner)
        ssm = _sds(sshape, jnp.float32)
        conv = _sds(cshape, jnp.bfloat16)
        return ((ssm, conv),
                (_named(mesh, shp.ssm_state_spec(mesh, sshape)),
                 _named(mesh, shp.conv_state_spec(mesh, cshape))))
    if cfg.family == Family.HYBRID:
        from repro.models.hybrid import attn_sites
        n_sites = len(attn_sites(cfg))
        conv_dim = cfg.d_inner + 2 * cfg.d_state
        sshape = (cfg.n_layers, B, cfg.n_ssm_heads, cfg.ssm_head_dim,
                  cfg.d_state)
        cshape = (cfg.n_layers, B, cfg.d_conv - 1, conv_dim)
        kshape = (n_sites, B, S, cfg.n_kv_heads, cfg.head_dim)
        ssm_sp = _named(mesh, shp.fit_spec(
            sshape, P(None, B_axes, model, None, None), mesh))
        conv_sp = _named(mesh, shp.fit_spec(
            cshape, P(None, B_axes, None, model), mesh))
        kv_sp2 = _named(mesh, shp.kv_cache_spec(mesh, kshape))
        return ((_sds(sshape, jnp.float32), _sds(cshape, jnp.bfloat16),
                 (_sds(kshape, jnp.bfloat16), _sds(kshape, jnp.bfloat16))),
                (ssm_sp, conv_sp, (kv_sp2, kv_sp2)))
    if cfg.family == Family.ENCDEC:
        shape = (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim)
        xshape = (cfg.n_layers, B, cfg.enc_ctx, cfg.n_kv_heads,
                  cfg.head_dim)
        kv_sp = _named(mesh, shp.kv_cache_spec(mesh, shape))
        kvx_sp = _named(mesh, shp.kv_cache_spec(mesh, xshape))
        return (((_sds(shape, jnp.bfloat16), _sds(shape, jnp.bfloat16)),
                 (_sds(xshape, jnp.bfloat16), _sds(xshape, jnp.bfloat16))),
                ((kv_sp, kv_sp), (kvx_sp, kvx_sp)))
    raise ValueError(cfg.family)
