"""Training launcher: --arch <id> on the host's devices or a forced mesh.

Production path (TPU pod): the same code under
``--devices production`` builds the 16×16 / 2×16×16 mesh (requires the
real chips or the dry-run's XLA_FLAGS override). For CPU smoke use a
reduced config: ``--reduced``.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed.act_sharding import activation_sharding
from repro.distributed.sharding import (batch_spec, fit_spec, opt_shardings,
                                        param_shardings)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.training import (AdamWConfig, AsyncCheckpointer, DataConfig,
                            SyntheticLM, init_train_state, latest_step,
                            make_train_step, restore_checkpoint)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--devices", choices=("host", "production",
                                          "production-multipod"),
                    default="host")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.devices == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(
            multi_pod=args.devices == "production-multipod")
    batch_axes = tuple(a for a in mesh.axis_names if a != "model")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps)
    key = jax.random.PRNGKey(0)
    params, opt = init_train_state(cfg, opt_cfg, key, jnp.float32)
    p_sh = param_shardings(cfg, params, mesh, "train")
    o_sh = opt_shardings(p_sh, mesh)
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(opt, o_sh)

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq,
                                  global_batch=args.batch))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg),
                      donate_argnums=(0, 1))
    ck = (AsyncCheckpointer(args.checkpoint_dir)
          if args.checkpoint_dir else None)
    start = 0
    if ck and latest_step(args.checkpoint_dir) is not None:
        start, trees = restore_checkpoint(args.checkpoint_dir)
        params, opt = trees["params"], trees["opt"]
        print(f"restored checkpoint at step {start}")

    with mesh, activation_sharding(batch_axes):
        t0 = time.time()
        for step in range(start, args.steps):
            b = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
            params, opt, m = step_fn(params, opt, b)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} "
                      f"({(time.time()-t0)/(step-start+1):.2f}s/step)")
            if ck and (step + 1) % args.checkpoint_every == 0:
                ck.save(step + 1, {"params": params, "opt": opt})
        if ck:
            ck.save(args.steps, {"params": params, "opt": opt})
            ck.wait()
    print("done")


if __name__ == "__main__":
    main()
