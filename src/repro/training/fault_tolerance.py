"""Fault tolerance & straggler mitigation (training control loop).

Design for 1000+ nodes (DESIGN §5), exercised here with process-local
fault injection:

- ``Heartbeat`` — per-host liveness watermarks; a coordinator marks a
  host dead after ``timeout`` missed beats.
- ``StragglerDetector`` — EMA of per-step durations; a host persistently
  slower than ``threshold``× the fleet median is flagged for the same
  re-mesh path as a failure (slow == gone at scale).
- ``run_with_recovery`` — the restartable training driver: on a step
  exception OR an injected node failure it (1) waits for the async
  checkpointer, (2) shrinks the data axis (elastic re-mesh plan from
  repro.distributed.elastic), (3) restores the latest checkpoint onto
  the new topology, (4) continues. Data is deterministic in (seed,
  step), so no data-state beyond the step counter is needed.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class Heartbeat:
    n_hosts: int
    timeout: float = 30.0
    last_beat: dict = field(default_factory=dict)

    def beat(self, host: int, now: float) -> None:
        self.last_beat[host] = now

    def dead_hosts(self, now: float) -> list[int]:
        return [h for h in range(self.n_hosts)
                if now - self.last_beat.get(h, now) > self.timeout]


@dataclass
class StragglerDetector:
    n_hosts: int
    threshold: float = 1.5
    ema: dict = field(default_factory=dict)
    alpha: float = 0.2
    min_samples: int = 5
    _count: dict = field(default_factory=dict)

    def observe(self, host: int, step_time: float) -> None:
        prev = self.ema.get(host, step_time)
        self.ema[host] = (1 - self.alpha) * prev + self.alpha * step_time
        self._count[host] = self._count.get(host, 0) + 1

    def stragglers(self) -> list[int]:
        ready = {h: t for h, t in self.ema.items()
                 if self._count.get(h, 0) >= self.min_samples}
        if len(ready) < 2:
            return []
        med = float(np.median(list(ready.values())))
        return [h for h, t in ready.items() if t > self.threshold * med]


class NodeFailure(RuntimeError):
    def __init__(self, host: int):
        super().__init__(f"node {host} failed")
        self.host = host


def run_with_recovery(train_one_step: Callable[[int], dict],
                      save_fn: Callable[[int], None],
                      restore_fn: Callable[[], int],
                      n_steps: int,
                      checkpoint_every: int = 50,
                      max_recoveries: int = 8,
                      on_recover: Optional[Callable[[int], None]] = None,
                      ) -> dict:
    """Drive training with checkpoint/restart recovery.

    train_one_step(step) -> metrics (may raise NodeFailure);
    save_fn(step) checkpoints; restore_fn() -> restored step.
    Returns summary {steps_done, recoveries, metrics_last}.
    """
    recoveries = 0
    step = restore_fn()
    metrics = {}
    while step < n_steps:
        try:
            metrics = train_one_step(step)
            step += 1
            if step % checkpoint_every == 0:
                save_fn(step)
        except NodeFailure as e:
            recoveries += 1
            if recoveries > max_recoveries:
                raise RuntimeError("recovery budget exhausted") from e
            if on_recover:
                on_recover(e.host)
            step = restore_fn()
    save_fn(step)
    return {"steps_done": step, "recoveries": recoveries,
            "metrics_last": metrics}
