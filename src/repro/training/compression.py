"""Gradient compression: int8 quantised all-reduce with error feedback.

Optional distributed-optimization trick (off by default). Per-tensor
symmetric int8 quantisation before the data-parallel all-reduce cuts
gradient collective bytes 4× (bf16→int8 would be 2×; we quantise from
the fp32 grads, 4×). Error feedback accumulates the quantisation
residual locally and re-injects it next step, preserving convergence
(Seide et al., 1-bit SGD lineage).

Used inside shard_map/pjit: quantise → psum → dequantise. The §Perf log
evaluates its effect on the collective roofline term for train_4k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads: dict, errors: dict | None,
                           ) -> tuple[dict, dict, dict]:
    """Returns (quantised {path: (q, scale)}, dequantised grads,
    new error feedback). ``errors`` is the running residual dict."""
    errors = errors or {k: jnp.zeros_like(g, jnp.float32)
                        for k, g in grads.items()}
    qs, deq, new_err = {}, {}, {}
    for k, g in grads.items():
        g32 = g.astype(jnp.float32) + errors[k]
        q, scale = quantize_int8(g32)
        d = dequantize_int8(q, scale)
        qs[k] = (q, scale)
        deq[k] = d.astype(g.dtype)
        new_err[k] = g32 - d
    return qs, deq, new_err


def compressed_psum(grads: dict, axis_name: str,
                    errors: dict | None = None) -> tuple[dict, dict]:
    """int8 all-reduce with error feedback, inside shard_map."""
    qs, _, new_err = compress_with_feedback(grads, errors)
    out = {}
    for k, (q, scale) in qs.items():
        # Sum int8 payloads in int32 (exact), scales in fp32.
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        # Average of per-host dequantised grads: approximate shared
        # scale by the psum of scales / n (per-host scales differ).
        scale_sum = jax.lax.psum(scale, axis_name)
        out[k] = (summed.astype(jnp.float32) * (scale_sum / n) / n
                  ).astype(grads[k].dtype)
    return out, new_err
