"""Training substrate: optimizer, step builder, checkpointing, FT."""
from .checkpoint import (AsyncCheckpointer, latest_step, prune_checkpoints,
                         restore_checkpoint, save_checkpoint)
from .compression import compress_with_feedback, compressed_psum
from .data import DataConfig, SyntheticLM
from .fault_tolerance import (Heartbeat, NodeFailure, StragglerDetector,
                              run_with_recovery)
from .optimizer import AdamWConfig, adamw_update, init_opt_state, schedule
from .train_step import init_train_state, make_train_step
