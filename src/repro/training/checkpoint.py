"""Sharded checkpointing with elastic restore (no orbax offline).

Layout: <dir>/step_<N>/
    manifest.json      — step, mesh shape/axes, leaf index {path: file,
                         shape, dtype}, framework version
    <leaf-hash>.npy    — one file per leaf (full array; on multi-host
                         each host writes its owned shards — single-host
                         here, noted)

Restore is *elastic*: arrays are rebuilt with
``jax.make_array_from_callback`` against whatever mesh/sharding the new
job provides — the checkpoint stores logical arrays, not device
layouts, so a 256-chip checkpoint restores onto 192 chips after a node
failure (DESIGN §5).

``AsyncCheckpointer`` moves serialisation off the step path: save() on
a worker thread, ``wait()`` joins before the next save or exit.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading

import jax
import numpy as np


def _leaf_file(path: str) -> str:
    return hashlib.md5(path.encode()).hexdigest()[:16] + ".npy"


def save_checkpoint(directory: str, step: int, trees: dict[str, dict],
                    extra: dict | None = None) -> str:
    """trees: {"params": flat dict, "opt": flat dict, ...}."""
    out = os.path.join(directory, f"step_{step:08d}")
    tmp = out + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    index = {}
    for tree_name, tree in trees.items():
        for path, leaf in tree.items():
            arr = np.asarray(jax.device_get(leaf))
            key = f"{tree_name}/{path}"
            fname = _leaf_file(key)
            np.save(os.path.join(tmp, fname), arr)
            index[key] = {"file": fname, "shape": list(arr.shape),
                          "dtype": str(arr.dtype)}
    manifest = {"step": step, "index": index, "extra": extra or {},
                "format_version": 1}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(out):
        shutil.rmtree(out)
    os.rename(tmp, out)       # atomic publish
    return out


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int | None = None,
                       shardings: dict[str, dict] | None = None,
                       ) -> tuple[int, dict[str, dict]]:
    """Returns (step, trees). ``shardings`` optionally maps
    tree/path -> jax.sharding.Sharding for elastic device placement."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    trees: dict[str, dict] = {}
    for key, meta in manifest["index"].items():
        tree_name, path = key.split("/", 1)
        arr = np.load(os.path.join(src, meta["file"]))
        sh = (shardings or {}).get(tree_name, {}).get(path)
        if sh is not None:
            leaf = jax.make_array_from_callback(
                arr.shape, sh, lambda idx: arr[idx])
        else:
            leaf = jax.numpy.asarray(arr)
        trees.setdefault(tree_name, {})[path] = leaf
    return manifest["step"], trees


def prune_checkpoints(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Off-step-path checkpointing: device_get happens on call (cheap,
    async dispatch), file IO on a worker thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def save(self, step: int, trees: dict[str, dict],
             extra: dict | None = None) -> None:
        self.wait()
        host_trees = {name: {k: np.asarray(jax.device_get(v))
                             for k, v in tree.items()}
                      for name, tree in trees.items()}

        def work():
            self.last_path = save_checkpoint(self.directory, step,
                                             host_trees, extra)
            prune_checkpoints(self.directory, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
