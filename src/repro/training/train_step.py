"""Train-step builder shared by the launcher, dry-run, and examples."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.base import Family, ModelConfig

from .optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt, metrics).

    ``batch`` carries tokens/labels (+ frames for enc-dec, mrope_pos for
    VLM) as produced by launch.input_specs / training.data.

    ``microbatches`` > 1 runs gradient accumulation: the global batch is
    split on axis 0 and scanned sequentially, dividing peak activation
    memory by the factor (how the top-8 MoE train cells fit 16 GB HBM —
    their all-to-all receive buffers scale with per-step tokens).
    """

    def loss_fn(params, batch):
        kw = {}
        if cfg.family == Family.ENCDEC:
            kw["frames"] = batch["frames"]
        if cfg.mrope:
            kw["mrope_pos"] = batch["mrope_pos"]
        return api.train_loss(cfg, params, batch["tokens"],
                              batch["labels"], **kw)

    grad_fn = jax.value_and_grad(loss_fn)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def split(v):
                mb = v.shape[0] // microbatches
                return v.reshape((microbatches, mb) + v.shape[1:])
            mb_batch = {k: split(v) for k, v in batch.items()
                        if k != "mrope_pos"}
            if "mrope_pos" in batch:   # (3, B, S): batch is axis 1
                m = batch["mrope_pos"]
                mb = m.shape[1] // microbatches
                mb_batch["mrope_pos"] = jnp.moveaxis(
                    m.reshape(3, microbatches, mb, m.shape[-1]), 1, 0)

            def acc(carry, mb_i):
                loss_sum, grads_sum = carry
                loss_i, grads_i = grad_fn(params, mb_i)
                grads_sum = jax.tree_util.tree_map(
                    lambda a, b: a + b, grads_sum, grads_i)
                return (loss_sum + loss_i, grads_sum), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0), zeros),
                                            mb_batch)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches,
                                           grads)
        params, opt_state, metrics = adamw_update(params, grads,
                                                  opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, key,
                     dtype=jnp.bfloat16):
    params = api.init_params(cfg, key, dtype)
    return params, init_opt_state(params, opt_cfg)
