"""AdamW from scratch (no optax offline) with production knobs.

- moment dtype is configurable: fp32 (default) or bf16 — bf16 moments
  halve optimizer HBM, which is what lets llama4-400B train on one
  16 GB-per-chip pod (DESIGN §4); update math always runs in fp32.
- global-norm gradient clipping;
- decoupled weight decay (skipped for norms/biases/1-D params);
- linear warmup + cosine decay schedule.

State is a flat dict mirroring the param dict: {path: (m, v)} plus a
scalar step — trivially shardable with the same PartitionSpecs as the
parameters (ZeRO-style sharding is applied by the distribution layer).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"       # "float32" | "bfloat16"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params: dict, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    state = {"step": jnp.zeros((), jnp.int32)}
    for k, p in params.items():
        state[f"m/{k}"] = jnp.zeros(p.shape, dt)
        state[f"v/{k}"] = jnp.zeros(p.shape, dt)
    return state


def _decay_mask(path: str, p: jax.Array) -> bool:
    leaf = path.split("/")[-1]
    return p.ndim >= 2 and "norm" not in leaf and not leaf.endswith("bias")


def global_norm(grads: dict) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in grads.values()))


def adamw_update(params: dict, grads: dict, state: dict,
                 cfg: AdamWConfig) -> tuple[dict, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    new_params, new_state = {}, {"step": step}
    for k, p in params.items():
        g = grads[k].astype(jnp.float32) * scale
        m = state[f"m/{k}"].astype(jnp.float32)
        v = state[f"v/{k}"].astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay and _decay_mask(k, p):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_params[k] = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        dt = jnp.dtype(cfg.moment_dtype)
        new_state[f"m/{k}"] = m.astype(dt)
        new_state[f"v/{k}"] = v.astype(dt)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
