"""Deterministic synthetic data pipeline (training substrate).

Language-model batches synthesised from a seeded Markov-ish token
process — deterministic in (seed, step), so restarts reproduce the
exact byte stream without any data-state checkpointing beyond the step
counter (the property elastic restart relies on). Per-host sharding:
each data-parallel host materialises only its slice.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    """Zipf-distributed tokens with local correlations — enough signal
    that the training loss demonstrably falls (quickstart example)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / (1.0 / ranks).sum()

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Returns {"tokens": (local_B, S), "labels": (local_B, S)}."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + cfg.host_id)
        B, S = self.local_batch, cfg.seq_len
        base = rng.choice(cfg.vocab_size, size=(B, S + 1), p=self._probs)
        # Local correlation: with p=0.5 repeat the previous token + 1.
        rep = rng.random((B, S + 1)) < 0.5
        for t in range(1, S + 1):
            base[:, t] = np.where(rep[:, t],
                                  (base[:, t - 1] + 1) % cfg.vocab_size,
                                  base[:, t])
        return {"tokens": base[:, :-1].astype(np.int32),
                "labels": base[:, 1:].astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
