"""falcon-mamba-7b [ssm]: 64L d4096 attention-free Mamba1, d_state=16,
expand=2 (d_inner 8192), V=65024. [arXiv:2410.05355; unverified]"""
from repro.models.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family=Family.SSM,
    n_layers=64, d_model=4096, vocab_size=65024,
    ssm_version=1, d_state=16, expand=2, d_conv=4)
