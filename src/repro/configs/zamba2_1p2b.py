"""zamba2-1.2b [hybrid]: 38 Mamba2 layers d2048, one shared attention
block (32H kv=32, d_head 64) + shared MLP ff8192 applied every 6 layers,
ssm_state=64. V=32000. [arXiv:2411.15242; hf]"""
from repro.models.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family=Family.HYBRID,
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_version=2, d_state=64, expand=2, ssm_head_dim=64, d_conv=4,
    attn_every=6, rope_theta=1e4, scan_layers=False)
