"""internlm2-1.8b [dense]: 24L d2048 16H/8kv ff8192 V=92544.
[arXiv:2403.17297; hf]"""
from repro.models.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", family=Family.DENSE,
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92544, rope_theta=1e6)
