"""qwen2.5-32b [dense]: 64L d5120 40H/8kv ff27648 V=152064, QKV bias.
[hf:Qwen/Qwen2.5; hf]"""
from repro.models.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family=Family.DENSE,
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=27648, vocab_size=152064, qkv_bias=True, rope_theta=1e6)
