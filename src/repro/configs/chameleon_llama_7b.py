"""The paper's own evaluation model: Llama-7B (32L d4096 32H MHA ff11008
V=32000) — used by the serving engine example and paper-figure
benchmarks. [arXiv:2307.09288; hf]"""
from repro.models.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-llama-7b", family=Family.DENSE,
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=32000, rope_theta=1e4)
