"""qwen2-vl-7b [vlm]: 28L d3584 28H/4kv ff18944 V=152064, M-RoPE
(t/h/w sections 16/24/24 of head_dim/2=64); vision frontend STUBBED
(input_specs provides token ids + 3-axis position ids).
[arXiv:2409.12191; hf]"""
from repro.models.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family=Family.VLM,
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064, mrope=True, mrope_sections=(16, 24, 24),
    rope_theta=1e6)
