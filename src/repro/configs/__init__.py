"""Architecture registry: ``--arch <id>`` resolution + input shape sets.

Every assigned architecture is a selectable config; each LM arch pairs
with four shapes (train_4k / prefill_32k / decode_32k / long_500k).
``long_500k`` requires sub-quadratic attention and is skipped for pure
full-attention archs (recorded, not silently dropped); it runs for the
SSM and hybrid families. See DESIGN.md §3.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.models.base import Family, ModelConfig

ARCH_IDS = (
    "llama4-maverick-400b-a17b",
    "qwen3-moe-235b-a22b",
    "zamba2-1.2b",
    "granite-34b",
    "qwen2.5-32b",
    "qwen3-14b",
    "internlm2-1.8b",
    "whisper-base",
    "qwen2-vl-7b",
    "falcon-mamba-7b",
    "chameleon-llama-7b",          # the paper's own model (extra)
)

_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "zamba2-1.2b": "zamba2_1p2b",
    "granite-34b": "granite_34b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen3-14b": "qwen3_14b",
    "internlm2-1.8b": "internlm2_1p8b",
    "whisper-base": "whisper_base",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "chameleon-llama-7b": "chameleon_llama_7b",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; one of {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}

# Sub-quadratic-attention requirement: long_500k runs only for families
# whose decode state does not force a full 500k KV scan per layer
# (SSM: O(1) state; hybrid: SSM layers O(1) + a handful of shared-attn
# sites). Pure full-attention archs skip the cell (DESIGN.md §3).
LONG_CTX_FAMILIES = (Family.SSM, Family.HYBRID)


def cell_applicable(arch_id: str, shape_name: str) -> tuple[bool, str]:
    cfg = get_config(arch_id)
    if shape_name == "long_500k" and cfg.family not in LONG_CTX_FAMILIES:
        return False, "full-attention arch: 500k decode KV infeasible " \
                      "(sub-quadratic attention required; see DESIGN.md)"
    return True, ""


def assigned_cells(include_paper_model: bool = False):
    """All (arch, shape) cells — the 40-cell dry-run/roofline grid."""
    out = []
    for a in ARCH_IDS:
        if a == "chameleon-llama-7b" and not include_paper_model:
            continue
        for s in SHAPES:
            out.append((a, s.name))
    return out
