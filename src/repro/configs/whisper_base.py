"""whisper-base [audio]: 6L encoder + 6L decoder, d512 8H ff2048
V=51865; conv/mel frontend STUBBED (input_specs provides 1500 frame
embeddings). [arXiv:2212.04356; unverified]"""
from repro.models.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family=Family.ENCDEC,
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab_size=51865, enc_ctx=1500,
    max_seq_len=32769)
