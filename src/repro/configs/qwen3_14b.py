"""qwen3-14b [dense]: 40L d5120 40H/8kv ff17408 V=151936, qk_norm.
[hf:Qwen/Qwen3-8B; hf]"""
from repro.models.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family=Family.DENSE,
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936, qk_norm=True, rope_theta=1e6)
