"""granite-34b [dense]: 88L d6144 48H MQA(kv=1) ff24576 V=49152 —
GPT-BigCode-arch code model (MQA, non-gated GELU MLP). [arXiv:2405.04324; hf]"""
from repro.models.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family=Family.DENSE,
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152, rope_theta=1e5,
    gated_mlp=False)
