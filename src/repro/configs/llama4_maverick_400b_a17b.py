"""llama4-maverick-400b-a17b [moe]: 48L d5120 40H/8kv ff8192 V=202048,
MoE 128e top-1, interleaved (MoE every 2nd layer) + shared expert —
matches ~400B total / ~17B active. Early-fusion multimodal frontend is
out of backbone scope (text path only). [hf:meta-llama/Llama-4; unverified]
"""
from repro.models.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family=Family.MOE,
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    n_experts=128, top_k=1, d_ff_expert=8192, shared_expert_ff=8192,
    moe_every=2, rope_theta=5e5)
