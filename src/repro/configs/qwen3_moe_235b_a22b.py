"""qwen3-moe-235b-a22b [moe]: 94L d4096 64H/4kv (head_dim 128) ff_expert
1536 V=151936, 128 experts top-8, qk_norm. [hf:Qwen/Qwen3-*; hf]"""
from repro.models.base import Family, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family=Family.MOE,
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab_size=151936, qk_norm=True,
    n_experts=128, top_k=8, d_ff_expert=1536, moe_every=1,
    rope_theta=1e6)
