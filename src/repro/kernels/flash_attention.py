"""flash_attention — prefill attention, online softmax (Pallas TPU).

Classic FlashAttention blocking adapted to TPU: grid (B, H, n_q_blocks,
n_kv_blocks) with the KV axis iterated sequentially per (q-block); the
running (m, l, acc) state lives in VMEM scratch across KV steps (TPU
grids execute the last axis in order — the role CUDA's per-CTA loop
plays). GQA maps query head h to kv head h // G in the BlockSpec
index_map, so KV streams once per group without duplication.

Causal masking is positional (block-level skipping is a §Perf
refinement). Blocks: q (Qb, dh), k/v (Kb, dh) — Qb = Kb = 128 keeps
VMEM ≈ 200 kB and the MXU shapes 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  q_block: int, kv_block: int, causal: bool, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (Qb, dh)
    k = k_ref[0, 0].astype(jnp.float32)                  # (Kb, dh)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (Qb, Kb)
    if causal:
        qpos = qi * q_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 0)
        kpos = ki * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_block",
                                             "kv_block", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, q_block: int = 128,
                    kv_block: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, dh); k/v: (B, S, Kh, dh) -> (B, S, H, dh).

    S must divide by the block sizes (pad at the caller; ops.py wrapper
    handles ragged shapes)."""
    B, S, H, dh = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qt = q.transpose(0, 2, 1, 3)      # (B, H, S, dh)
    kt = k.transpose(0, 2, 1, 3)      # (B, Kh, S, dh)
    vt = v.transpose(0, 2, 1, 3)
    assert S % q_block == 0 and S % kv_block == 0
    grid = (B, H, S // q_block, S // kv_block)

    kernel = functools.partial(_flash_kernel, q_block=q_block,
                               kv_block=kv_block, causal=causal,
                               scale=dh ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, dh),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, kv_block, dh),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, kv_block, dh),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, dh), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
