"""Pallas TPU kernels for the serving hot spots + jnp oracles.

bgmv  — decode-time batched-gather LoRA (Punica/S-LoRA BGMV, TPU-native)
sgmv  — prefill-time segmented LoRA matmul
paged_attention — decode attention over the paged KV pool
"""
from .ops import lora_bgmv, lora_sgmv, paged_attention
from . import ref
