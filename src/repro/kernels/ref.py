"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel in this package must match its oracle here to float
tolerance across the shape/dtype sweep in tests/test_kernels_*.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bgmv_ref(x: jax.Array, A: jax.Array, B: jax.Array,
             idx: jax.Array) -> jax.Array:
    """Batched-gather LoRA: y[b] = x[b] @ A[idx[b]] @ B[idx[b]].

    x: (Bt, din); A: (n_slots, din, r); B: (n_slots, r, dout); idx: (Bt,).
    """
    A_sel = jnp.take(A, idx, axis=0)
    B_sel = jnp.take(B, idx, axis=0)
    t = jnp.einsum("bd,bdr->br", x, A_sel,
                   preferred_element_type=jnp.float32)
    y = jnp.einsum("br,bro->bo", t.astype(x.dtype), B_sel,
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def sgmv_ref(x: jax.Array, A: jax.Array, B: jax.Array,
             tile_slot: jax.Array, tile: int) -> jax.Array:
    """Segmented LoRA: tile t of ``tile`` tokens uses adapter tile_slot[t].

    x: (T, din) with T % tile == 0, tokens pre-grouped so that each tile
    maps to exactly one adapter; tile_slot: (T/tile,).
    """
    T, din = x.shape
    n_tiles = T // tile
    xt = x.reshape(n_tiles, tile, din)
    A_sel = jnp.take(A, tile_slot, axis=0)          # (n_tiles, din, r)
    B_sel = jnp.take(B, tile_slot, axis=0)
    t = jnp.einsum("ntd,ndr->ntr", xt, A_sel,
                   preferred_element_type=jnp.float32)
    y = jnp.einsum("ntr,nro->nto", t.astype(x.dtype), B_sel,
                   preferred_element_type=jnp.float32)
    return y.reshape(T, -1).astype(x.dtype)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, page_table: jax.Array,
                        lengths: jax.Array) -> jax.Array:
    """Decode attention over paged KV.

    q: (B, Kh, G, dh) — grouped queries; k_pages/v_pages:
    (n_pages, page, Kh, dh); page_table: (B, pages_per_seq);
    lengths: (B,) valid tokens. Returns (B, Kh, G, dh).
    """
    B, Kh, G, dh = q.shape
    n_pages, page, _, _ = k_pages.shape
    P = page_table.shape[1]
    # Gather each sequence's pages: (B, P, page, Kh, dh).
    k = jnp.take(k_pages, page_table, axis=0)
    v = jnp.take(v_pages, page_table, axis=0)
    k = k.transpose(0, 3, 1, 2, 4).reshape(B, Kh, P * page, dh)
    v = v.transpose(0, 3, 1, 2, 4).reshape(B, Kh, P * page, dh)
    scores = jnp.einsum("bkgd,bksd->bkgs", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (dh ** -0.5)
    valid = jnp.arange(P * page)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Plain attention oracle. q: (B,S,H,dh); k,v: (B,S,Kh,dh)."""
    from repro.models.layers import gqa_attention
    return gqa_attention(q, k, v, causal=causal)
