"""sgmv — prefill-time segmented LoRA matmul (Pallas TPU).

Prefill batches contain contiguous token runs per request. The ops.py
wrapper sorts/pads tokens so every tile of ``tile`` tokens belongs to
exactly one adapter (``tile_slot[t]``); the kernel then runs, per tile,

    y_tile = (x_tile @ A[slot]) @ B[slot]

as two MXU matmuls with the adapter chosen by scalar-prefetch — the TPU
equivalent of S-LoRA's SGMV segment GEMMs (no warp-level machinery; the
segment → tile alignment plays the role of the CUDA segment offsets).

Grid: (n_tiles, dout_tiles). VMEM at tile=128, din=6144, r=128,
T_out=512: x 1.5 MB + A 1.5 MB + B .13 MB + y .13 MB ≈ 3.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sgmv_kernel(slot_ref, x_ref, a_ref, b_ref, o_ref):
    # x: (tile, din); a: (1, din, r); b: (1, r, T_out); o: (tile, T_out)
    t = jnp.dot(x_ref[...], a_ref[0],
                preferred_element_type=jnp.float32)       # (tile, r)
    o_ref[...] = jnp.dot(t.astype(b_ref.dtype), b_ref[0],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile", "out_tile", "interpret"))
def sgmv(x: jax.Array, A: jax.Array, B: jax.Array, tile_slot: jax.Array,
         tile: int = 128, out_tile: int = 512,
         interpret: bool = False) -> jax.Array:
    """x: (T, din), T % tile == 0; tile_slot: (T/tile,) adapter slots."""
    T, din = x.shape
    n, _, r = A.shape
    dout = B.shape[-1]
    out_tile = min(out_tile, dout)
    assert T % tile == 0 and dout % out_tile == 0
    n_tiles = T // tile
    grid = (n_tiles, dout // out_tile)

    return pl.pallas_call(
        _sgmv_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile, din), lambda t, j, s: (t, 0)),
                pl.BlockSpec((1, din, r), lambda t, j, s: (s[t], 0, 0)),
                pl.BlockSpec((1, r, out_tile), lambda t, j, s: (s[t], 0, j)),
            ],
            out_specs=pl.BlockSpec((tile, out_tile),
                                   lambda t, j, s: (t, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, dout), x.dtype),
        interpret=interpret,
    )(tile_slot.astype(jnp.int32), x, A, B)


def pack_segments(seq_lens, adapter_slots, tile: int = 128):
    """Host-side packing: per-request segment → tile-aligned layout.

    Returns (perm, tile_slot, padded_T): ``perm[i]`` gives the source
    row of packed row i (or -1 for padding). Tokens of each request are
    padded up to a tile multiple so no tile spans two adapters.
    """
    import numpy as np
    perm, tile_slot = [], []
    src = 0
    for L, slot in zip(seq_lens, adapter_slots):
        pad = (-L) % tile
        perm.extend(range(src, src + L))
        perm.extend([-1] * pad)
        tile_slot.extend([slot] * ((L + pad) // tile))
        src += L
    return (np.asarray(perm, np.int32),
            np.asarray(tile_slot, np.int32),
            len(perm))
