"""jit'd public wrappers for the Pallas kernels + the LoRA dispatch layer.

``use_kernel`` resolution: on TPU backends the Pallas path runs
natively; elsewhere (this CPU container) it runs in interpret mode when
``interpret_ok`` — tests force that; the serving engine on CPU prefers
the jnp reference path for speed. Wrappers also handle padding to the
kernels' tile-alignment requirements so callers stay shape-agnostic.

The LoRA *dispatch layer* (``resolve_lora_backend`` /
``lora_delta_kernel``) is what the model data plane calls
(models/lora_apply.py): decode steps (S == 1) route per-token LoRA
through the bgmv kernel, batched prefill (S > 1, one contiguous run of
S tokens per request) through the sgmv kernel with tile-aligned
segments, and the pure-jnp einsum stays available as the CPU/oracle
fallback. Backend choice is a static Python string resolved once per
engine, so jit caches stay coherent.
"""
from __future__ import annotations

import contextlib
import functools
import time

import jax
import jax.numpy as jnp

from . import ref
from .bgmv import bgmv as _bgmv_pallas
from .paged_attention import paged_attention as _paged_pallas
from .sgmv import pack_segments, sgmv as _sgmv_pallas

LORA_BACKENDS = ("auto", "einsum", "kernel")


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    # Memoised: the serving hot loop asks per dispatch and the backend
    # cannot change after the first device op anyway.
    return jax.default_backend() == "tpu"


class DispatchMeter:
    """Hot-loop observability: jit dispatches and host-sync wall time.

    The serving engine ticks the meter once per device dispatch it
    launches on the decode path and wraps its device→host token reads
    in ``sync()``. ``benchmarks/decode_hotloop.py`` reads the meter to
    report dispatches/token and the host-sync fraction — the two
    numbers the fused device-resident loop exists to shrink. A plain
    counter + accumulator: the per-step cost is one int add, so the
    meter stays on in production paths.

    Speculative decoding splits device work into two phases the meter
    counts separately on top of ``dispatches``: ``draft_dispatches``
    (draft-model forward passes — the chained proposal steps plus the
    draft prefill/catch-up calls) and ``verify_dispatches`` (multi-token
    target verify passes). ``dispatches`` still counts *jit dispatches
    launched*, so one fused speculative round ticks ``tick(1)`` plus
    the per-phase counts of the forwards folded inside it.
    """

    def __init__(self) -> None:
        self.dispatches = 0
        self.draft_dispatches = 0
        self.verify_dispatches = 0
        self.sync_seconds = 0.0

    def reset(self) -> None:
        self.dispatches = 0
        self.draft_dispatches = 0
        self.verify_dispatches = 0
        self.sync_seconds = 0.0

    def tick(self, n: int = 1) -> None:
        self.dispatches += n

    def tick_draft(self, n: int = 1) -> None:
        self.draft_dispatches += n

    def tick_verify(self, n: int = 1) -> None:
        self.verify_dispatches += n

    @contextlib.contextmanager
    def sync(self):
        """Time a blocking device→host readback (e.g. ``np.asarray`` on
        a decode result)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.sync_seconds += time.perf_counter() - t0


#: Process-wide meter the engine step loops tick (reset by benchmarks).
DISPATCH_METER = DispatchMeter()


class CollectiveMeter(DispatchMeter):
    """DISPATCH_METER-style probe for the sharded data plane.

    A mesh>1 engine ticks this once per sharded dispatch and wraps the
    blocking completion of that dispatch in ``sync()`` — on a sharded
    program the dominant cost of that wait beyond single-device compute
    is the GSPMD collectives (psum after row-parallel matmuls, page
    all-gathers), so ``frac()`` reports the collective/wall time
    fraction the per-device gauges in ``serving/metrics.py`` export.
    ``reset()`` also restarts the wall clock the fraction is taken
    over.
    """

    def __init__(self) -> None:
        super().__init__()
        self._t0 = time.perf_counter()

    def reset(self) -> None:
        super().reset()
        self._t0 = time.perf_counter()

    def frac(self) -> float:
        wall = time.perf_counter() - self._t0
        return (self.sync_seconds / wall) if wall > 0 else 0.0


#: Process-wide probe for sharded (mesh>1) engine dispatches.
COLLECTIVE_METER = CollectiveMeter()


def resolve_lora_backend(backend: str | None) -> str:
    """Resolve a ``EngineConfig.lora_backend`` knob to a concrete path.

    ``auto`` (or None) picks the Pallas kernels on TPU and the einsum
    reference elsewhere; ``kernel`` forces the Pallas path (interpret
    mode off-TPU — what the CI parity jobs run); ``einsum`` forces the
    reference path.
    """
    if backend in (None, "auto"):
        return "kernel" if on_tpu() else "einsum"
    if backend not in ("einsum", "kernel"):
        raise ValueError(
            f"lora_backend must be one of {LORA_BACKENDS}, got {backend!r}")
    return backend


def _pad_axis(a, axis, mult):
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a, size
    width = [(0, 0)] * a.ndim
    width[axis] = (0, pad)
    return jnp.pad(a, width), size


def lora_bgmv(x, A, B, idx, *, prefer_kernel: bool | None = None,
              interpret: bool | None = None):
    """Decode LoRA delta. x: (Bt, din) -> (Bt, dout)."""
    use_kernel = on_tpu() if prefer_kernel is None else prefer_kernel
    if not use_kernel:
        return ref.bgmv_ref(x, A, B, idx)
    interpret = (not on_tpu()) if interpret is None else interpret
    Bp, dout0 = B, B.shape[-1]
    Bp, _ = _pad_axis(B, 2, 128)
    y = _bgmv_pallas(x, A, Bp, idx, interpret=interpret)
    return y[:, :dout0]


def lora_sgmv(x, A, B, seq_lens, adapter_slots, *, tile: int = 128,
              prefer_kernel: bool | None = None,
              interpret: bool | None = None):
    """Prefill LoRA delta over concatenated sequences.

    x: (T, din) tokens concatenated per request (seq_lens[i] each),
    adapter_slots[i] the adapter of request i. Returns (T, dout).
    """
    use_kernel = on_tpu() if prefer_kernel is None else prefer_kernel
    perm, tile_slot, padded_T = pack_segments(seq_lens, adapter_slots,
                                              tile)
    perm_j = jnp.asarray(perm)
    gathered = jnp.where(perm_j[:, None] >= 0,
                         x[jnp.maximum(perm_j, 0)], 0).astype(x.dtype)
    if not use_kernel:
        y = ref.sgmv_ref(gathered, A, B, jnp.asarray(tile_slot), tile)
    else:
        interpret = (not on_tpu()) if interpret is None else interpret
        Bp, dout0 = _pad_axis(B, 2, 128)
        y = _sgmv_pallas(gathered, A, Bp, jnp.asarray(tile_slot),
                         tile=tile, interpret=interpret)[:, :dout0]
    # Scatter back to original token order.
    out = jnp.zeros((x.shape[0], y.shape[-1]), y.dtype)
    valid = perm_j >= 0
    return out.at[jnp.maximum(perm_j, 0)].add(
        jnp.where(valid[:, None], y, 0))


def _lora_bgmv_tokens(x, A, B, idx, interpret):
    """(T, din) tokens, one adapter index per token, via the bgmv kernel."""
    x, din0 = _pad_axis(x, 1, 128)
    A, _ = _pad_axis(A, 1, 128)
    Bp, dout0 = _pad_axis(B, 2, 128)
    y = _bgmv_pallas(x, A, Bp, idx, out_tile=128, interpret=interpret)
    return y[:, :dout0]


def _lora_sgmv_uniform(x, A, B, idx, tile, interpret):
    """Prefill LoRA via sgmv for *uniform* segments (jit-traceable).

    x: (Bt, S, din) — request b's tokens are the contiguous run x[b],
    all runs the same (static) length S, adapter idx[b] per run. This is
    the batched-prefill layout the engine produces (right-padded (B, S)
    buckets), so no host-side ``pack_segments`` permutation is needed:
    S is padded up to a tile multiple and ``tile_slot`` is idx repeated
    per tile. The ragged path (`lora_sgmv`) keeps pack_segments for
    host-driven concatenated layouts.
    """
    Bt, S, din = x.shape
    tile = min(tile, -(-S // 8) * 8)          # small-S: shrink the tile
    S_pad = -(-S // tile) * tile
    if S_pad != S:
        x = jnp.pad(x, ((0, 0), (0, S_pad - S), (0, 0)))
    xt = x.reshape(Bt * S_pad, din)
    xt, _ = _pad_axis(xt, 1, 128)
    A, _ = _pad_axis(A, 1, 128)
    Bp, dout0 = _pad_axis(B, 2, 128)
    tile_slot = jnp.repeat(idx.astype(jnp.int32), S_pad // tile)
    y = _sgmv_pallas(xt, A, Bp, tile_slot, tile=tile, out_tile=128,
                     interpret=interpret)
    return y.reshape(Bt, S_pad, -1)[:, :S, :dout0]


def lora_delta_kernel(x, A, B, idx, *, scale: float = 1.0,
                      tile: int = 128, interpret: bool | None = None):
    """Multi-adapter LoRA delta through the Pallas kernels.

    x: (Bt, S, din); A: (n_slots, din, r); B: (n_slots, r, dout);
    idx: (Bt,). Decode (S == 1) routes through bgmv (one gathered
    adapter per token); prefill (S > 1) routes each request's
    contiguous token run through sgmv tiles. Returns (Bt, S, dout).
    """
    interpret = (not on_tpu()) if interpret is None else interpret
    if x.shape[1] == 1:
        y = _lora_bgmv_tokens(x[:, 0], A, B, idx, interpret)[:, None]
    else:
        y = _lora_sgmv_uniform(x, A, B, idx, tile, interpret)
    return (scale * y).astype(x.dtype)


def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    prefer_kernel: bool | None = None,
                    interpret: bool | None = None):
    """Decode attention over paged KV; see paged_attention.py."""
    use_kernel = on_tpu() if prefer_kernel is None else prefer_kernel
    if not use_kernel:
        return ref.paged_attention_ref(q, k_pages, v_pages, page_table,
                                       lengths)
    interpret = (not on_tpu()) if interpret is None else interpret
    return _paged_pallas(q, k_pages, v_pages, page_table, lengths,
                         interpret=interpret)
