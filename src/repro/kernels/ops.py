"""jit'd public wrappers for the Pallas kernels.

``use_kernel`` resolution: on TPU backends the Pallas path runs
natively; elsewhere (this CPU container) it runs in interpret mode when
``interpret_ok`` — tests force that; the serving engine on CPU prefers
the jnp reference path for speed. Wrappers also handle padding to the
kernels' tile-alignment requirements so callers stay shape-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .bgmv import bgmv as _bgmv_pallas
from .paged_attention import paged_attention as _paged_pallas
from .sgmv import pack_segments, sgmv as _sgmv_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_axis(a, axis, mult):
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a, size
    width = [(0, 0)] * a.ndim
    width[axis] = (0, pad)
    return jnp.pad(a, width), size


def lora_bgmv(x, A, B, idx, *, prefer_kernel: bool | None = None,
              interpret: bool | None = None):
    """Decode LoRA delta. x: (Bt, din) -> (Bt, dout)."""
    use_kernel = on_tpu() if prefer_kernel is None else prefer_kernel
    if not use_kernel:
        return ref.bgmv_ref(x, A, B, idx)
    interpret = (not on_tpu()) if interpret is None else interpret
    Bp, dout0 = B, B.shape[-1]
    Bp, _ = _pad_axis(B, 2, 128)
    y = _bgmv_pallas(x, A, Bp, idx, interpret=interpret)
    return y[:, :dout0]


def lora_sgmv(x, A, B, seq_lens, adapter_slots, *, tile: int = 128,
              prefer_kernel: bool | None = None,
              interpret: bool | None = None):
    """Prefill LoRA delta over concatenated sequences.

    x: (T, din) tokens concatenated per request (seq_lens[i] each),
    adapter_slots[i] the adapter of request i. Returns (T, dout).
    """
    use_kernel = on_tpu() if prefer_kernel is None else prefer_kernel
    perm, tile_slot, padded_T = pack_segments(seq_lens, adapter_slots,
                                              tile)
    perm_j = jnp.asarray(perm)
    gathered = jnp.where(perm_j[:, None] >= 0,
                         x[jnp.maximum(perm_j, 0)], 0).astype(x.dtype)
    if not use_kernel:
        y = ref.sgmv_ref(gathered, A, B, jnp.asarray(tile_slot), tile)
    else:
        interpret = (not on_tpu()) if interpret is None else interpret
        Bp, dout0 = _pad_axis(B, 2, 128)
        y = _sgmv_pallas(gathered, A, Bp, jnp.asarray(tile_slot),
                         tile=tile, interpret=interpret)[:, :dout0]
    # Scatter back to original token order.
    out = jnp.zeros((x.shape[0], y.shape[-1]), y.dtype)
    valid = perm_j >= 0
    return out.at[jnp.maximum(perm_j, 0)].add(
        jnp.where(valid[:, None], y, 0))


def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    prefer_kernel: bool | None = None,
                    interpret: bool | None = None):
    """Decode attention over paged KV; see paged_attention.py."""
    use_kernel = on_tpu() if prefer_kernel is None else prefer_kernel
    if not use_kernel:
        return ref.paged_attention_ref(q, k_pages, v_pages, page_table,
                                       lengths)
    interpret = (not on_tpu()) if interpret is None else interpret
    return _paged_pallas(q, k_pages, v_pages, page_table, lengths,
                         interpret=interpret)
