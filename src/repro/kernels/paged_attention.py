"""paged_attention — decode attention over a paged KV pool (Pallas TPU).

The serving engine stores KV in fixed-size pages; request b's pages are
listed in ``page_table[b]``. The kernel computes, per (batch, kv-head),
flash-style online softmax over that request's pages:

    out[b,h] = softmax(q[b,h] · K[pages(b)]) · V[pages(b)]

TPU adaptation of vLLM's PagedAttention CUDA kernel: the page
indirection is a scalar-prefetch index_map (pages stream HBM→VMEM in
page-table order), and the online-softmax accumulator lives in VMEM
scratch, carried across the sequential last grid axis — TPU grids
iterate in order, which replaces the CUDA block reduction.

Grid: (B, Kh, n_page_steps). Blocks: q (1,1,G,dh) resident; k/v page
(1, page, dh). Scratch: acc (G, dh) f32 + m/l (G,) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                       acc_ref, m_ref, l_ref, *, page: int, dh: int):
    b = pl.program_id(0)
    p = pl.program_id(2)
    n_steps = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * (dh ** -0.5)   # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)                  # (page, dh)
    v = v_ref[0, 0].astype(jnp.float32)

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G,page)
    pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
    valid = pos < len_ref[b]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...]                                   # (G, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    probs = jnp.exp(scores - m_new)                       # (G, page)
    l_ref[...] = l_ref[...] * alpha + probs.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        probs, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == n_steps - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, lengths: jax.Array,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Kh, G, dh); k/v_pages: (n_pages, page, Kh, dh);
    page_table: (B, P) int32; lengths: (B,) int32 -> (B, Kh, G, dh)."""
    B, Kh, G, dh = q.shape
    n_pages, page, _, _ = k_pages.shape
    P = page_table.shape[1]

    # Layout: bring Kh forward so a (page, dh) block slices cleanly.
    kp = k_pages.transpose(2, 0, 1, 3)     # (Kh, n_pages, page, dh)
    vp = v_pages.transpose(2, 0, 1, 3)

    grid = (B, Kh, P)

    def q_map(b, h, p, pt, ln):
        return b, h, 0, 0

    def kv_map(b, h, p, pt, ln):
        return h, pt[b, p], 0, 0

    def o_map(b, h, p, pt, ln):
        return b, h, 0, 0

    kernel = functools.partial(_paged_attn_kernel, page=page, dh=dh)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, dh), q_map),
                pl.BlockSpec((1, 1, page, dh), kv_map),
                pl.BlockSpec((1, 1, page, dh), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, dh), o_map),
            scratch_shapes=[
                pltpu.VMEM((G, dh), jnp.float32),   # acc
                pltpu.VMEM((G, 1), jnp.float32),    # running max
                pltpu.VMEM((G, 1), jnp.float32),    # running denom
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Kh, G, dh), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32), q, kp, vp)
