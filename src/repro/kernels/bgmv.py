"""bgmv — decode-time batched-gather LoRA (Pallas TPU).

One token per request; request b applies adapter ``idx[b]``:

    y[b] = (x[b] @ A[idx[b]]) @ B[idx[b]]

TPU adaptation of Punica/S-LoRA's CUDA BGMV (DESIGN §2): the per-token
adapter gather becomes a *scalar-prefetch* index — ``idx`` is carried in
SMEM and the A/B BlockSpec index_maps select the adapter slot per grid
step, so the weights stream HBM→VMEM for exactly the adapters used, no
materialised (B, din, r) gather. The shrink and expand matmuls fuse in
one kernel invocation (the rank-r intermediate never leaves VMEM).

Grid: (B, dout_tiles). Blocks: x (1, din), A (din, r), B (r, T_out),
y (1, T_out). VMEM at din=6144, r=128, T_out=512: ~3.3 MB — comfortably
under the ~16 MB/core budget; din and dout tiles are multiples of 128
for MXU alignment (pad at the ops.py wrapper if needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bgmv_kernel(idx_ref, x_ref, a_ref, b_ref, o_ref):
    # x: (1, din); a: (1, din, r); b: (1, r, T_out); o: (1, T_out)
    t = jnp.dot(x_ref[...], a_ref[0],
                preferred_element_type=jnp.float32)      # (1, r)
    o_ref[...] = jnp.dot(t.astype(b_ref.dtype), b_ref[0],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("out_tile", "interpret"))
def bgmv(x: jax.Array, A: jax.Array, B: jax.Array, idx: jax.Array,
         out_tile: int = 512, interpret: bool = False) -> jax.Array:
    """x: (Bt, din); A: (n, din, r); B: (n, r, dout); idx: (Bt,) int32."""
    Bt, din = x.shape
    n, _, r = A.shape
    dout = B.shape[-1]
    out_tile = min(out_tile, dout)
    assert dout % out_tile == 0, (dout, out_tile)
    grid = (Bt, dout // out_tile)

    def x_map(b, j, idx_ref):
        return b, 0

    def a_map(b, j, idx_ref):
        return idx_ref[b], 0, 0

    def b_map(b, j, idx_ref):
        return idx_ref[b], 0, j

    def o_map(b, j, idx_ref):
        return b, j

    return pl.pallas_call(
        _bgmv_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, din), x_map),
                pl.BlockSpec((1, din, r), a_map),
                pl.BlockSpec((1, r, out_tile), b_map),
            ],
            out_specs=pl.BlockSpec((1, out_tile), o_map),
        ),
        out_shape=jax.ShapeDtypeStruct((Bt, dout), x.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), x, A, B)
