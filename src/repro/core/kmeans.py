"""1-D K-means for queue-count selection and cutoffs (paper §4.2).

The scheduler clusters the recent WRS distribution for K = 1..K_max and
picks K by WCSS. Read literally, "minimal WCSS" always selects K_max
(WCSS is monotone non-increasing in K); we implement the standard elbow
reading: the smallest K whose marginal WCSS improvement falls below
``min_gain`` (default 20 %). With heterogeneous workloads this lands on
3–4 queues, matching the paper's examples; with homogeneous load it
collapses to 1 queue — exactly the adaptivity §4.2 argues for.

Cutoffs are midpoints between consecutive sorted centroids.
"""
from __future__ import annotations

import numpy as np


def kmeans_1d(values: np.ndarray, k: int, n_iter: int = 50,
              seed: int = 0) -> tuple[np.ndarray, float]:
    """Lloyd's algorithm specialised for 1-D. Returns (centroids, wcss)."""
    v = np.asarray(values, dtype=np.float64).ravel()
    if len(v) == 0:
        return np.zeros(k), 0.0
    if k >= len(np.unique(v)):
        c = np.unique(v)
        pad = np.full(max(0, k - len(c)), c[-1])
        c = np.concatenate([c, pad])[:k]
    else:
        # Quantile init: deterministic and robust for 1-D.
        qs = (np.arange(k) + 0.5) / k
        c = np.quantile(v, qs)
    for _ in range(n_iter):
        d = np.abs(v[:, None] - c[None, :])
        assign = d.argmin(axis=1)
        new_c = c.copy()
        for j in range(k):
            sel = v[assign == j]
            if len(sel):
                new_c[j] = sel.mean()
        if np.allclose(new_c, c):
            c = new_c
            break
        c = new_c
    d = np.abs(v[:, None] - c[None, :])
    wcss = float((d.min(axis=1) ** 2).sum())
    return np.sort(c), wcss


def choose_queues(values: np.ndarray, k_max: int = 4,
                  min_gain: float = 0.2, cv_min: float = 0.05,
                  seed: int = 0) -> tuple[int, np.ndarray, np.ndarray]:
    """Pick the queue count and cutoffs from a WRS sample.

    Returns (k, centroids, cutoffs). ``cutoffs`` has length k-1 and is the
    midpoints between consecutive centroids; queue i takes requests with
    cutoffs[i-1] <= WRS < cutoffs[i].

    ``cv_min`` guards the homogeneous case: K-means WCSS drops sharply
    with K even on unimodal noise, so the elbow alone never returns K=1;
    when the coefficient of variation of the sample is below ``cv_min``
    the requests are effectively the same size and one queue suffices
    (the paper's "too many queues → fragmentation" argument).
    """
    v = np.asarray(values, dtype=np.float64).ravel()
    if len(v) < 2 or np.ptp(v) < 1e-12:
        return 1, np.array([v.mean() if len(v) else 0.0]), np.array([])
    mean = abs(v.mean())
    if mean > 1e-12 and v.std() / mean < cv_min:
        return 1, np.array([v.mean()]), np.array([])
    results = {}
    for k in range(1, k_max + 1):
        results[k] = kmeans_1d(v, k, seed=seed)
    best_k = 1
    prev_wcss = results[1][1]
    for k in range(2, k_max + 1):
        wcss = results[k][1]
        if prev_wcss <= 1e-12:
            break
        gain = (prev_wcss - wcss) / prev_wcss
        if gain < min_gain:
            break
        best_k = k
        prev_wcss = wcss
    centroids = results[best_k][0]
    cutoffs = (centroids[:-1] + centroids[1:]) / 2.0
    return best_k, centroids, cutoffs


def queue_index(wrs: float, cutoffs: np.ndarray) -> int:
    """Queue for a WRS value: 0 = smallest requests (highest priority)."""
    return int(np.searchsorted(cutoffs, wrs, side="right"))
