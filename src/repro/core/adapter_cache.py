"""Chameleon Adapter Cache (paper §4.1).

A software-managed, dynamically-sized cache of LoRA adapters in device
HBM. Backed by the unified MemoryPool: the cache owns whatever tokens
requests are not using, and shrinks on demand when the scheduler needs
memory for a new batch.

Per-entry metadata (paper list): adapter id, rank/size, last-used
timestamp, usage frequency (decayed count within a window), reference
counter. Eviction applies only to RC == 0 entries; entries needed by
*queued* requests are second-tier protected (evicted only under
pressure, paper §4.1 last paragraph).

Cost-aware eviction score (keep-value — lowest evicted):

    Score = F·Frequency + R·Recency + S·Size      F,R,S = 0.45, 0.10, 0.45

Each factor is min-max normalised over the current eviction candidates:
frequency (decayed use count, higher = keep), recency (newer = keep),
size (bigger = costlier to reload = keep).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

from .lora import AdapterInfo


class AdapterState(enum.Enum):
    """Residency sub-state of a cache entry (async load state machine).

    Entries are READY by default (synchronous loads, the simulator's
    charged-latency loads). An engine whose ``on_load`` hook only
    *dispatches* the host→device slot write marks the entry LOADING and
    flips it to READY once the transfer completes; schedulers refuse to
    place a LOADING adapter into a batch (the request defers, the rest
    of the batch proceeds) and eviction never selects a mid-flight
    entry.
    """

    LOADING = "loading"
    READY = "ready"


@dataclass
class CacheEntry:
    info: AdapterInfo
    last_used: float = 0.0
    frequency: float = 0.0
    ref_count: int = 0
    state: AdapterState = AdapterState.READY

    @property
    def size_tokens(self) -> int:
        return self.info.size_tokens


@dataclass
class EvictionWeights:
    frequency: float = 0.45
    recency: float = 0.10
    size: float = 0.45


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_loaded: int = 0
    bytes_evicted: int = 0
    shrink_events: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class EvictionPolicy:
    """Base: subclass and override ``scores``. Lowest score is evicted."""

    name = "base"

    def scores(self, entries: list[CacheEntry], now: float) -> list[float]:
        raise NotImplementedError


class CostAwareEviction(EvictionPolicy):
    """The paper's compound policy (F=0.45, R=0.10, S=0.45)."""

    name = "chameleon"

    def __init__(self, weights: EvictionWeights | None = None):
        self.w = weights or EvictionWeights()

    @staticmethod
    def _norm(vals: list[float]) -> list[float]:
        """Max-normalise non-negative factors.

        Min-max normalisation is wrong here: with near-identical factor
        values it amplifies noise to full [0,1] scale and can dominate
        the compound score. Dividing by the max preserves relative
        magnitudes instead.
        """
        hi = max(vals)
        if hi < 1e-12:
            return [0.0] * len(vals)
        return [v / hi for v in vals]

    def scores(self, entries: list[CacheEntry], now: float) -> list[float]:
        freq = self._norm([e.frequency for e in entries])
        # Recency as 1/(1+age): newer = keep, bounded and positive.
        rec = self._norm([1.0 / (1.0 + max(0.0, now - e.last_used))
                          for e in entries])
        size = self._norm([float(e.size_tokens) for e in entries])
        return [self.w.frequency * f + self.w.recency * r + self.w.size * s
                for f, r, s in zip(freq, rec, size)]


class FairShareEviction(CostAwareEviction):
    """Equal weights over the same three factors (paper Fig. 14)."""

    name = "fairshare"

    def __init__(self):
        third = 1.0 / 3.0
        super().__init__(EvictionWeights(third, third, third))


class LRUEviction(EvictionPolicy):
    """Plain recency (paper Fig. 14 baseline)."""

    name = "lru"

    def scores(self, entries: list[CacheEntry], now: float) -> list[float]:
        return [-(now - e.last_used) for e in entries]


class AdapterCache:
    """Cache manager: residency, reference counts, cost-aware eviction.

    ``on_load(info)`` / ``on_evict(info)`` hooks let the engine perform
    (or the simulator charge for) the actual H2D transfer; the cache
    itself only manages metadata + pool accounting.
    """

    def __init__(self, pool, adapters: dict[int, AdapterInfo],
                 policy: EvictionPolicy | None = None,
                 freq_decay: float = 0.999,
                 on_load: Optional[Callable[[AdapterInfo], None]] = None,
                 on_evict: Optional[Callable[[AdapterInfo], None]] = None,
                 enabled: bool = True,
                 max_entries: Optional[int] = None):
        self.pool = pool
        self.catalog = adapters
        self.policy = policy or CostAwareEviction()
        self.freq_decay = freq_decay
        self.entries: dict[int, CacheEntry] = {}
        self.stats = CacheStats()
        self.on_load = on_load
        self.on_evict = on_evict
        # Hard cap on resident adapters (device slot buffers in the
        # engine are a fixed array; None = token accounting only).
        self.max_entries = max_entries
        # enabled=False reproduces the S-LoRA baseline: adapters are
        # dropped as soon as their last request finishes.
        self.enabled = enabled

    # -- residency -------------------------------------------------------
    def resident(self, adapter_id: int) -> bool:
        return adapter_id in self.entries

    def resident_ids(self) -> set[int]:
        return set(self.entries)

    def resident_tokens(self) -> int:
        return sum(e.size_tokens for e in self.entries.values())

    # -- async load state machine ------------------------------------------
    def mark_loading(self, adapter_id: int) -> None:
        """Entry's device bytes are in flight (engine ``on_load`` hooks
        that dispatch the H2D write without blocking call this)."""
        self.entries[adapter_id].state = AdapterState.LOADING

    def mark_ready(self, adapter_id: int) -> None:
        """Transfer completed; the adapter may now be placed in batches."""
        entry = self.entries.get(adapter_id)
        if entry is not None:
            entry.state = AdapterState.READY

    def is_ready(self, adapter_id: int) -> bool:
        """Resident *and* usable in a batch (not mid-load)."""
        entry = self.entries.get(adapter_id)
        return entry is not None and entry.state is AdapterState.READY

    def loading_ids(self) -> set[int]:
        return {aid for aid, e in self.entries.items()
                if e.state is AdapterState.LOADING}

    def _decay_all(self) -> None:
        for e in self.entries.values():
            e.frequency *= self.freq_decay

    # -- acquire / release -------------------------------------------------
    def acquire(self, adapter_id: int, now: float,
                queued_protect: Iterable[int] = ()) -> bool:
        """Pin an adapter for a running request.

        Returns True on a cache hit; False when the adapter had to be
        loaded (caller charges the load latency). Raises PoolError if it
        cannot fit even after evicting every unpinned adapter.

        ``queued_protect`` (adapter ids of queued requests) flows through
        to eviction so the §4.1 second-tier protection holds on the load
        path too — without it, loading a cold adapter would happily evict
        an adapter the very next admission is about to need.
        """
        self._decay_all()
        entry = self.entries.get(adapter_id)
        if entry is not None:
            entry.ref_count += 1
            entry.last_used = now
            entry.frequency += 1.0
            self.stats.hits += 1
            return True
        info = self.catalog[adapter_id]
        self._ensure_slot_capacity(now, queued_protect)
        self.make_room(info.size_tokens, now, queued_protect)
        self.pool.hold_adapter(adapter_id, info.size_tokens)
        entry = CacheEntry(info=info, last_used=now, frequency=1.0,
                           ref_count=1)
        self.entries[adapter_id] = entry
        self.stats.misses += 1
        self.stats.bytes_loaded += info.size_bytes
        if self.on_load:
            self.on_load(info)
        return False

    def release(self, adapter_id: int, now: float) -> None:
        entry = self.entries.get(adapter_id)
        if entry is None:
            return
        entry.ref_count = max(0, entry.ref_count - 1)
        entry.last_used = now
        if entry.ref_count == 0 and not self.enabled \
                and entry.state is AdapterState.READY:
            # S-LoRA baseline: discard immediately once unused (never a
            # mid-load entry — its slot write is still in flight).
            self._evict(adapter_id)

    # -- prefetch ----------------------------------------------------------
    def prefetch(self, adapter_id: int, now: float,
                 queued_protect: Iterable[int] = ()) -> bool:
        """Load without pinning (for queued requests). True if loaded.

        ``queued_protect`` keeps the §4.1 second-tier protection live on
        this load path too (see ``acquire``).
        """
        if adapter_id in self.entries:
            return False
        info = self.catalog[adapter_id]
        if info.size_tokens > self._evictable_tokens() + self.pool.free_tokens:
            return False
        if (self.max_entries is not None
                and len(self.entries) >= self.max_entries
                and not self._evictable()):
            return False
        self._ensure_slot_capacity(now, queued_protect)
        self.make_room(info.size_tokens, now, queued_protect)
        self.pool.hold_adapter(adapter_id, info.size_tokens)
        self.entries[adapter_id] = CacheEntry(info=info, last_used=now,
                                              frequency=0.5, ref_count=0)
        self.stats.bytes_loaded += info.size_bytes
        if self.on_load:
            self.on_load(info)
        return True

    def _ensure_slot_capacity(self, now: float,
                              queued_protect: Iterable[int] = ()) -> None:
        """Evict (lowest score first) until an entry slot is free.

        Same two protection tiers as ``make_room``: protected (queued)
        adapters go only when no unprotected candidate remains.
        """
        if self.max_entries is None:
            return
        while len(self.entries) >= self.max_entries:
            cands = self._evictable(queued_protect) or self._evictable()
            if not cands:
                from .memory_pool import PoolError
                raise PoolError("all adapter slots pinned")
            scores = self.policy.scores(cands, now)
            self._evict(cands[scores.index(min(scores))].info.adapter_id)

    # -- eviction ----------------------------------------------------------
    def _evictable(self, protect: Iterable[int] = ()) -> list[CacheEntry]:
        """RC == 0, unprotected, and not mid-load.

        A LOADING entry is never an eviction candidate: its H2D write is
        in flight and would land in a slot the engine had already handed
        to someone else. Loads complete within an iteration or two, so
        the protection is short-lived.
        """
        protect = set(protect)
        return [e for aid, e in self.entries.items()
                if e.ref_count == 0 and aid not in protect
                and e.state is AdapterState.READY]

    def _evictable_tokens(self, protect: Iterable[int] = ()) -> int:
        return sum(e.size_tokens for e in self._evictable(protect))

    def _evict(self, adapter_id: int) -> int:
        entry = self.entries.pop(adapter_id)
        tokens = self.pool.drop_adapter(adapter_id)
        self.stats.evictions += 1
        self.stats.bytes_evicted += entry.info.size_bytes
        if self.on_evict:
            self.on_evict(entry.info)
        return tokens

    def make_room(self, tokens_needed: int, now: float,
                  queued_protect: Iterable[int] = ()) -> int:
        """Evict lowest-score adapters until ``tokens_needed`` fit.

        Two protection tiers (paper §4.1): running adapters (RC>0) are
        untouchable; adapters of queued requests are evicted only if
        unprotected candidates do not suffice.
        """
        freed = 0
        for protect in (queued_protect, ()):
            while self.pool.free_tokens < tokens_needed:
                cands = self._evictable(protect)
                if not cands:
                    break
                scores = self.policy.scores(cands, now)
                victim = cands[scores.index(min(scores))]
                freed += self._evict(victim.info.adapter_id)
            if self.pool.free_tokens >= tokens_needed:
                return freed
        if self.pool.free_tokens < tokens_needed:
            from .memory_pool import PoolError
            raise PoolError(
                f"cannot free {tokens_needed} tokens "
                f"(free={self.pool.free_tokens}, "
                f"evictable={self._evictable_tokens()})")
        return freed

    def shrink_for_requests(self, tokens_needed: int, now: float,
                            queued_protect: Iterable[int] = ()) -> bool:
        """Dynamic downsizing: make room for a batch's memory demand.

        Returns False when the demand cannot be met even after evicting
        everything evictable (the scheduler then admits fewer requests).
        """
        if self.pool.free_tokens >= tokens_needed:
            return True
        available = (self.pool.free_tokens
                     + self._evictable_tokens())
        if available < tokens_needed:
            return False
        self.stats.shrink_events += 1
        self.make_room(tokens_needed, now, queued_protect)
        return True
