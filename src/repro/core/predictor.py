"""Output-length prediction.

The paper uses an open-source BERT-proxy predictor (µServe [44]) with
~80 % accuracy, and sweeps 100/80/60 % in Fig. 16. Accuracy is defined at
*bucket* granularity: a prediction is correct when it lands in the true
length's power-of-two bucket (the scheduler only needs coarse classes).

Two predictors ship:

- ``NoisyOraclePredictor`` — knows the truth, degrades it to a target
  accuracy. This is the evaluation instrument for Fig. 16-style sweeps.
- ``HistogramPredictor`` — a deployable predictor: per-adapter decayed
  histogram over buckets, predicts the median bucket's representative
  length. Mirrors the observation that output length is task-(adapter-)
  correlated.
"""
from __future__ import annotations

import math
from collections import defaultdict

import numpy as np


def bucket_of(length: int) -> int:
    """Power-of-two bucket index (1..)."""
    return max(0, int(math.ceil(math.log2(max(1, length)))))


def bucket_repr(bucket: int) -> int:
    """Representative length for a bucket: its geometric midpoint."""
    lo = 1 if bucket == 0 else 2 ** (bucket - 1)
    hi = 2 ** bucket
    return max(1, int(round(math.sqrt(lo * hi))))


class NoisyOraclePredictor:
    """Returns the true length with prob=accuracy, else a wrong bucket.

    Errors move the bucket by ±1..3 (geometric), matching how proxy-model
    misclassifications concentrate near the decision boundary.
    """

    def __init__(self, accuracy: float = 0.8, seed: int = 0):
        if not 0.0 <= accuracy <= 1.0:
            raise ValueError("accuracy must be in [0,1]")
        self.accuracy = accuracy
        self.rng = np.random.default_rng(seed)

    def predict(self, input_len: int, adapter_id: int, true_output: int) -> int:
        if self.rng.random() < self.accuracy:
            return max(1, true_output)
        b = bucket_of(true_output)
        # Proxy-model misclassifications concentrate near the boundary;
        # cap the walk at 3 buckets (an uncapped geometric step once
        # produced a 185k-token prediction whose quota charge could
        # never be admitted — found by a starved request in the DES).
        step = min(int(self.rng.geometric(0.6)), 3)
        sign = 1 if self.rng.random() < 0.5 else -1
        wrong = max(0, b + sign * step)
        if wrong == b:
            wrong = b + step
        return bucket_repr(wrong)

    def observe(self, adapter_id: int, true_output: int) -> None:  # no-op
        pass


class HistogramPredictor:
    """Per-adapter decayed bucket histogram; predicts the weighted median.

    ``decay`` is applied on every observation so that the histogram tracks
    non-stationary workloads (the paper's T_refresh-style adaptivity).
    A global histogram backs off cold adapters.
    """

    def __init__(self, decay: float = 0.98, default_output: int = 128):
        self.decay = decay
        self.default_output = default_output
        self._hist: dict[int, defaultdict[int, float]] = {}
        self._global: defaultdict[int, float] = defaultdict(float)

    def observe(self, adapter_id: int, true_output: int) -> None:
        b = bucket_of(true_output)
        h = self._hist.setdefault(adapter_id, defaultdict(float))
        for k in list(h):
            h[k] *= self.decay
        h[b] += 1.0
        for k in list(self._global):
            self._global[k] *= self.decay
        self._global[b] += 1.0

    @staticmethod
    def _median_bucket(h) -> int | None:
        total = sum(h.values())
        if total <= 0:
            return None
        acc = 0.0
        for b in sorted(h):
            acc += h[b]
            if acc >= total / 2:
                return b
        return None

    def predict(self, input_len: int, adapter_id: int,
                true_output: int | None = None) -> int:
        h = self._hist.get(adapter_id)
        b = self._median_bucket(h) if h else None
        if b is None:
            b = self._median_bucket(self._global)
        if b is None:
            return self.default_output
        return bucket_repr(b)


def predict_request(predictor, req, max_predicted: int = 4096) -> int:
    """Fill ``req.predicted_output`` from ``predictor`` — once.

    The single length-prediction hook shared by every consumer of a
    prediction: schedulers call it at queue admission, the gateway calls
    it earlier (lane classification + SLO wait estimates). Idempotent —
    an already-predicted request keeps its value, so whichever layer
    sees the request first decides and every later layer agrees (the
    gateway's lane choice and the scheduler's WRS are computed from the
    same number). Returns the (clamped, >=1) prediction.
    """
    if req.predicted_output <= 0:
        req.predicted_output = max(1, int(predictor.predict(
            req.input_len, req.adapter_id, req.output_len)))
    req.predicted_output = min(req.predicted_output, max_predicted)
    return req.predicted_output


def measure_accuracy(predictor, pairs) -> float:
    """Fraction of (input, adapter, truth) triples predicted in-bucket."""
    ok = 0
    for input_len, adapter_id, truth in pairs:
        p = predictor.predict(input_len, adapter_id, truth)
        ok += bucket_of(p) == bucket_of(truth)
    return ok / max(1, len(pairs))
