"""Per-request sampling parameters for the serving surface.

Pure-Python control-plane object (no jax import): requests carry a
``SamplingParams`` through every tier; only the real engine turns it
into device work via the batched sampler in ``repro.models.lm``
(``sample_tokens``). The default is greedy decoding, which reproduces
the pre-SamplingParams engine token-for-token (argmax over logits).

Seeding: sampling randomness is keyed per *(seed, token position)* —
never per batch slot or step — so a request decodes the same tokens
regardless of batch composition, KV layout (dense/paged), LoRA backend
(einsum/kernel), or a squash/requeue that re-executes its prefix.
``seed=None`` with ``temperature > 0`` derives the seed from the
request id, which keeps runs reproducible without forcing callers to
thread seeds.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class SamplingParams:
    """How to turn logits into the next token, per request.

    temperature  <= 0 means greedy (argmax); > 0 scales logits.
    top_k        0 disables; else sample only among the k best logits.
    top_p        1.0 disables; else nucleus sampling (smallest prefix of
                 the sorted distribution with cumulative prob >= top_p;
                 the best token is always kept).
    seed         per-request RNG seed; None derives it from the req_id.
    max_new_tokens  caps decode length below the workload's output_len
                 (None = no cap).
    stop_token_ids  generation finishes early when one is sampled (the
                 stop token itself is kept, vLLM-style).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int | None = None
    max_new_tokens: int | None = None
    stop_token_ids: tuple[int, ...] = field(default_factory=tuple)

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new_tokens is not None and self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # Normalise for hashing/equality (callers pass lists too).
        object.__setattr__(self, "stop_token_ids",
                           tuple(self.stop_token_ids))

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def seed_for(self, req_id: int) -> int:
        """The effective RNG seed for a request (masked to uint32)."""
        s = self.seed if self.seed is not None else req_id
        return int(s) & 0xFFFFFFFF


GREEDY = SamplingParams()
