"""Per-request sampling parameters for the serving surface.

Pure-Python control-plane object (no jax import): requests carry a
``SamplingParams`` through every tier; only the real engine turns it
into device work via the batched sampler in ``repro.models.lm``
(``sample_tokens``). The default is greedy decoding, which reproduces
the pre-SamplingParams engine token-for-token (argmax over logits).

Seeding: sampling randomness is keyed per *(seed, token position)* —
never per batch slot or step — so a request decodes the same tokens
regardless of batch composition, KV layout (dense/paged), LoRA backend
(einsum/kernel), or a squash/requeue that re-executes its prefix.
``seed=None`` with ``temperature > 0`` derives the seed from the
request id, which keeps runs reproducible without forcing callers to
thread seeds.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class SamplingParams:
    """How to turn logits into the next token, per request.

    temperature  <= 0 means greedy (argmax); > 0 scales logits.
    top_k        0 disables; else sample only among the k best logits.
    top_p        1.0 disables; else nucleus sampling (smallest prefix of
                 the sorted distribution with cumulative prob >= top_p;
                 the best token is always kept).
    seed         per-request RNG seed; None derives it from the req_id.
    max_new_tokens  caps decode length below the workload's output_len
                 (None = no cap).
    stop_token_ids  generation finishes early when one is sampled (the
                 stop token itself is kept, vLLM-style).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int | None = None
    max_new_tokens: int | None = None
    stop_token_ids: tuple[int, ...] = field(default_factory=tuple)

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new_tokens is not None and self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # Normalise for hashing/equality (callers pass lists too).
        object.__setattr__(self, "stop_token_ids",
                           tuple(self.stop_token_ids))

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def seed_for(self, req_id: int) -> int:
        """The effective RNG seed for a request (masked to uint32)."""
        s = self.seed if self.seed is not None else req_id
        return int(s) & 0xFFFFFFFF


GREEDY = SamplingParams()


# ------------------------------------------- speculative decoding rule
#
# Draft–verify speculation must stay deterministic under the same
# contract as ``sample_tokens``: every random draw is keyed on
# *(seed, token position)* only, so a squash/requeue that re-executes a
# request's prefix regenerates bit-identical tokens even though the
# draft/verify *round boundaries* land differently on the second run.
# Each position therefore derives one base key
# ``fold_in(PRNGKey(seed), position)`` (the non-spec sampler's key) and
# splits it into independent streams by folding in a stream tag:
#
#   SPEC_DRAFT_FOLD     Gumbel noise for the draft model's proposal
#   SPEC_ACCEPT_FOLD    the uniform for the rejection-sampling accept
#   SPEC_RESIDUAL_FOLD  Gumbel noise for the residual resample on reject
#
# The *bonus* token (all drafts accepted) is drawn from the base key
# with no fold — i.e. by ``sample_tokens`` itself — so a fully-accepted
# round ends with exactly the token the non-speculative loop would have
# sampled at that position. Greedy rows (temperature <= 0) never touch
# these streams: acceptance is an argmax comparison against the target
# logits, which makes greedy speculation bit-identical by construction.
SPEC_DRAFT_FOLD = 1
SPEC_ACCEPT_FOLD = 2
SPEC_RESIDUAL_FOLD = 3


def spec_residual_reference(p, q):
    """Reference residual distribution for rejection sampling.

    Pure-Python/numpy-friendly oracle the spec-decode tests check the
    device rule against: after a draft token from ``q`` is rejected
    against target probs ``p`` (accept prob ``min(1, p[d]/q[d])``), the
    replacement is drawn from ``normalize(max(p - q, 0))`` — the unique
    choice that makes the emitted token exactly ``p``-distributed.
    Degenerate case ``p == q`` (residual mass 0) falls back to ``p``;
    the accept probability is 1 there, so the branch is never taken on
    device.
    """
    r = [max(pi - qi, 0.0) for pi, qi in zip(p, q)]
    s = sum(r)
    if s <= 0.0:
        return list(p)
    return [ri / s for ri in r]
