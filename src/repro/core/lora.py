"""LoRA adapter descriptors and pool construction.

The paper's workload model (§5.1): ``N_a`` adapters, five ranks
{8, 16, 32, 64, 128} with ``N_a/5`` adapters per rank; a request picks a
*rank* by a power-law (smaller ranks more popular) and then an adapter
uniformly within the rank.

Adapter memory: LoRA adds two matrices (A: d×r, B: r×d) per adapted
projection. For a model with ``n_layers`` and ``n_proj`` adapted
projections of width ``d_model``, bytes = n_layers · n_proj · 2 · d · r ·
dtype_bytes. We express sizes in *pool tokens* (see memory_pool.py) so the
cache and the KV allocator share one currency.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

PAPER_RANKS: tuple[int, ...] = (8, 16, 32, 64, 128)


@dataclass(frozen=True)
class AdapterInfo:
    adapter_id: int
    rank: int
    size_bytes: int
    size_tokens: int      # bytes expressed in memory-pool token units

    @property
    def size(self) -> int:
        return self.size_tokens


def adapter_bytes(rank: int, d_model: int, n_layers: int,
                  n_proj: int = 4, dtype_bytes: int = 2) -> int:
    """Size of one adapter's weights (A and B for each adapted projection)."""
    return n_layers * n_proj * 2 * d_model * rank * dtype_bytes


def build_adapter_pool(n_adapters: int, d_model: int, n_layers: int,
                       token_bytes: int, ranks: Sequence[int] = PAPER_RANKS,
                       n_proj: int = 4, dtype_bytes: int = 2,
                       ) -> list[AdapterInfo]:
    """The paper's pool: equal count per rank, ranks ascending."""
    pool: list[AdapterInfo] = []
    per_rank = max(1, n_adapters // len(ranks))
    aid = 0
    for rank in ranks:
        for _ in range(per_rank):
            nbytes = adapter_bytes(rank, d_model, n_layers, n_proj, dtype_bytes)
            pool.append(AdapterInfo(
                adapter_id=aid, rank=rank, size_bytes=nbytes,
                size_tokens=max(1, -(-nbytes // token_bytes))))
            aid += 1
    return pool


def powerlaw_rank_sampler(ranks: Sequence[int] = PAPER_RANKS,
                          alpha: float = 1.0) -> np.ndarray:
    """P(rank_i) ∝ (1/rank_i)^alpha — smaller adapters more popular (§5.1)."""
    w = np.array([1.0 / (r ** alpha) for r in ranks], dtype=np.float64)
    return w / w.sum()


def assign_adapters(n_requests: int, pool: Sequence[AdapterInfo],
                    rng: np.random.Generator, alpha: float = 1.0) -> np.ndarray:
    """Draw an adapter id per request: power-law over ranks, uniform within."""
    ranks = sorted({a.rank for a in pool})
    p_rank = powerlaw_rank_sampler(ranks, alpha)
    by_rank = {r: [a.adapter_id for a in pool if a.rank == r] for r in ranks}
    rank_choice = rng.choice(len(ranks), size=n_requests, p=p_rank)
    out = np.empty(n_requests, dtype=np.int64)
    for i, rc in enumerate(rank_choice):
        out[i] = rng.choice(by_rank[ranks[rc]])
    return out
