"""Unified device-memory pool in token units (TPU adaptation, DESIGN §2).

The paper reuses "idle GPU memory" for the adapter cache. On TPU, XLA
owns HBM, so idleness must be made explicit: the serving engine
pre-allocates one pool and accounts *everything* in token units:

    1 token  =  bytes of one KV-cache token slot
               (2 · n_kv_heads · head_dim · n_layers · dtype_bytes)

- Running requests hold KV tokens. Dense engines reserve the predicted
  worst case (input + predicted output) up front; the paged engine holds
  exactly its allocated KV pages and grows page by page, so ``free``
  tracks *actual* HBM occupancy, not a prediction.
- Resident adapters occupy ceil(adapter_bytes / token_bytes) tokens.
- free = capacity − requests − adapters. The Chameleon cache *is* the
  adapter region; "dynamic cache resizing" = this watermark moving.

``page_size > 1`` switches the pool to page currency for requests
(S-LoRA-style unified paging): every request hold must be a whole
number of pages, enforced by ``check_invariants``. Adapter holds stay
token-granular — adapters are contiguous slot buffers, not paged.

The pool is deliberately policy-free: eviction choices live in
adapter_cache.py, admission choices in scheduler.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field


class PoolError(RuntimeError):
    pass


@dataclass
class MemoryPool:
    capacity_tokens: int
    page_size: int = 1                # tokens per KV page (1 = dense mode)
    used_requests: int = 0
    used_adapters: int = 0
    _request_holds: dict = field(default_factory=dict)   # req_id -> tokens
    _adapter_holds: dict = field(default_factory=dict)   # adapter_id -> tokens

    # ------------------------------------------------------------------
    @property
    def free_tokens(self) -> int:
        return self.capacity_tokens - self.used_requests - self.used_adapters

    @property
    def cache_tokens(self) -> int:
        """Current adapter-cache capacity = resident adapters + free HBM."""
        return self.capacity_tokens - self.used_requests

    def request_headroom(self) -> int:
        """Tokens available to requests without evicting any adapter."""
        return self.free_tokens

    # Pages -------------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` KV entries."""
        return -(-tokens // self.page_size)

    @property
    def free_pages(self) -> int:
        return self.free_tokens // self.page_size

    def request_pages(self, req_id: int) -> int:
        return self._request_holds.get(req_id, 0) // self.page_size

    def reserve_request_pages(self, req_id: int, n_pages: int) -> None:
        """Page-granular hold (the paged engine's allocation unit)."""
        self.reserve_request(req_id, n_pages * self.page_size)

    # Requests ----------------------------------------------------------
    def reserve_request(self, req_id: int, tokens: int) -> None:
        if tokens < 0:
            raise PoolError("negative reservation")
        if tokens > self.free_tokens:
            raise PoolError(
                f"reserve_request({tokens}) exceeds free {self.free_tokens}")
        if self.page_size > 1 and tokens % self.page_size:
            raise PoolError(
                f"paged pool: hold of {tokens} tokens is not a multiple "
                f"of page_size={self.page_size}")
        self._request_holds[req_id] = self._request_holds.get(req_id, 0) + tokens
        self.used_requests += tokens

    def grow_request(self, req_id: int, tokens: int) -> None:
        self.reserve_request(req_id, tokens)

    def release_request(self, req_id: int) -> int:
        tokens = self._request_holds.pop(req_id, 0)
        self.used_requests -= tokens
        return tokens

    def shrink_request(self, req_id: int, tokens: int) -> None:
        """Give back part of a hold (paged engine: per-page reclaim)."""
        held = self._request_holds.get(req_id, 0)
        if tokens < 0 or tokens > held:
            raise PoolError(
                f"shrink_request({tokens}) exceeds hold {held}")
        if self.page_size > 1 and tokens % self.page_size:
            raise PoolError(
                f"paged pool: shrink of {tokens} tokens is not a "
                f"multiple of page_size={self.page_size}")
        if tokens == held:
            self._request_holds.pop(req_id, None)
        else:
            self._request_holds[req_id] = held - tokens
        self.used_requests -= tokens

    # Adapters ----------------------------------------------------------
    def hold_adapter(self, adapter_id: int, tokens: int) -> None:
        if adapter_id in self._adapter_holds:
            return
        if tokens > self.free_tokens:
            raise PoolError(
                f"hold_adapter({tokens}) exceeds free {self.free_tokens}")
        self._adapter_holds[adapter_id] = tokens
        self.used_adapters += tokens

    def drop_adapter(self, adapter_id: int) -> int:
        tokens = self._adapter_holds.pop(adapter_id, 0)
        self.used_adapters -= tokens
        return tokens

    def adapter_resident(self, adapter_id: int) -> bool:
        return adapter_id in self._adapter_holds

    # Introspection -------------------------------------------------------
    def check_invariants(self) -> None:
        assert self.used_requests == sum(self._request_holds.values())
        assert self.used_adapters == sum(self._adapter_holds.values())
        assert 0 <= self.used_requests
        assert 0 <= self.used_adapters
        assert self.used_requests + self.used_adapters <= self.capacity_tokens
        if self.page_size > 1:
            for req_id, tokens in self._request_holds.items():
                assert tokens % self.page_size == 0, (
                    f"request {req_id} holds {tokens} tokens, not a "
                    f"multiple of page_size={self.page_size}")

    def snapshot(self) -> dict:
        snap = {
            "capacity": self.capacity_tokens,
            "requests": self.used_requests,
            "adapters": self.used_adapters,
            "free": self.free_tokens,
        }
        if self.page_size > 1:
            snap["page_size"] = self.page_size
            snap["pages_used"] = self.used_requests // self.page_size
            snap["pages_free"] = self.free_pages
        return snap


def kv_token_bytes(n_layers: int, n_kv_heads: int, head_dim: int,
                   dtype_bytes: int = 2) -> int:
    """Bytes of one token's KV across all layers (the pool's currency)."""
    return 2 * n_layers * n_kv_heads * head_dim * dtype_bytes
