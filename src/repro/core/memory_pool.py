"""Unified device-memory pool in token units (TPU adaptation, DESIGN §2).

The paper reuses "idle GPU memory" for the adapter cache. On TPU, XLA
owns HBM, so idleness must be made explicit: the serving engine
pre-allocates one pool and accounts *everything* in token units:

    1 token  =  bytes of one KV-cache token slot
               (2 · n_kv_heads · head_dim · n_layers · dtype_bytes)

- Running requests reserve input+output+KV tokens.
- Resident adapters occupy ceil(adapter_bytes / token_bytes) tokens.
- free = capacity − requests − adapters. The Chameleon cache *is* the
  adapter region; "dynamic cache resizing" = this watermark moving.

The pool is deliberately policy-free: eviction choices live in
adapter_cache.py, admission choices in scheduler.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field


class PoolError(RuntimeError):
    pass


@dataclass
class MemoryPool:
    capacity_tokens: int
    used_requests: int = 0
    used_adapters: int = 0
    _request_holds: dict = field(default_factory=dict)   # req_id -> tokens
    _adapter_holds: dict = field(default_factory=dict)   # adapter_id -> tokens

    # ------------------------------------------------------------------
    @property
    def free_tokens(self) -> int:
        return self.capacity_tokens - self.used_requests - self.used_adapters

    @property
    def cache_tokens(self) -> int:
        """Current adapter-cache capacity = resident adapters + free HBM."""
        return self.capacity_tokens - self.used_requests

    def request_headroom(self) -> int:
        """Tokens available to requests without evicting any adapter."""
        return self.free_tokens

    # Requests ----------------------------------------------------------
    def reserve_request(self, req_id: int, tokens: int) -> None:
        if tokens < 0:
            raise PoolError("negative reservation")
        if tokens > self.free_tokens:
            raise PoolError(
                f"reserve_request({tokens}) exceeds free {self.free_tokens}")
        self._request_holds[req_id] = self._request_holds.get(req_id, 0) + tokens
        self.used_requests += tokens

    def grow_request(self, req_id: int, tokens: int) -> None:
        self.reserve_request(req_id, tokens)

    def release_request(self, req_id: int) -> int:
        tokens = self._request_holds.pop(req_id, 0)
        self.used_requests -= tokens
        return tokens

    # Adapters ----------------------------------------------------------
    def hold_adapter(self, adapter_id: int, tokens: int) -> None:
        if adapter_id in self._adapter_holds:
            return
        if tokens > self.free_tokens:
            raise PoolError(
                f"hold_adapter({tokens}) exceeds free {self.free_tokens}")
        self._adapter_holds[adapter_id] = tokens
        self.used_adapters += tokens

    def drop_adapter(self, adapter_id: int) -> int:
        tokens = self._adapter_holds.pop(adapter_id, 0)
        self.used_adapters -= tokens
        return tokens

    def adapter_resident(self, adapter_id: int) -> bool:
        return adapter_id in self._adapter_holds

    # Introspection -------------------------------------------------------
    def check_invariants(self) -> None:
        assert self.used_requests == sum(self._request_holds.values())
        assert self.used_adapters == sum(self._adapter_holds.values())
        assert 0 <= self.used_requests
        assert 0 <= self.used_adapters
        assert self.used_requests + self.used_adapters <= self.capacity_tokens

    def snapshot(self) -> dict:
        return {
            "capacity": self.capacity_tokens,
            "requests": self.used_requests,
            "adapters": self.used_adapters,
            "free": self.free_tokens,
        }


def kv_token_bytes(n_layers: int, n_kv_heads: int, head_dim: int,
                   dtype_bytes: int = 2) -> int:
    """Bytes of one token's KV across all layers (the pool's currency)."""
    return 2 * n_layers * n_kv_heads * head_dim * dtype_bytes
