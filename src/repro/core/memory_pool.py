"""Unified device-memory pool in token units (TPU adaptation, DESIGN §2).

The paper reuses "idle GPU memory" for the adapter cache. On TPU, XLA
owns HBM, so idleness must be made explicit: the serving engine
pre-allocates one pool and accounts *everything* in token units:

    1 token  =  bytes of one KV-cache token slot
               (2 · n_kv_heads · head_dim · n_layers · dtype_bytes)

- Running requests hold KV tokens. Dense engines reserve the predicted
  worst case (input + predicted output) up front; the paged engine holds
  exactly its allocated KV pages and grows page by page, so ``free``
  tracks *actual* HBM occupancy, not a prediction.
- Resident adapters occupy ceil(adapter_bytes / token_bytes) tokens.
- free = capacity − requests − adapters. The Chameleon cache *is* the
  adapter region; "dynamic cache resizing" = this watermark moving.

``page_size > 1`` switches the pool to page currency for requests
(S-LoRA-style unified paging): every request hold must be a whole
number of pages, enforced by ``check_invariants``. Adapter holds stay
token-granular — adapters are contiguous slot buffers, not paged.

Shared pages (prefix-cache substrate): a page can be promoted out of a
request hold into a refcounted shared ledger (``add_shared_page``),
after which any number of requests — and the prefix radix tree itself —
hold references (``share_pages``/``release_shared``). Shared pages are
charged to ``used_shared`` so they count against both request headroom
and the adapter-cache watermark: cached prefixes are *accounted* idle
memory, exactly like resident adapters, never invisible occupancy.

The pool is deliberately policy-free: eviction choices live in
adapter_cache.py / prefix_cache.py, admission choices in scheduler.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field


class PoolError(RuntimeError):
    pass


@dataclass
class MemoryPool:
    capacity_tokens: int
    page_size: int = 1                # tokens per KV page (1 = dense mode)
    n_shards: int = 1                 # devices the physical plane spans
    used_requests: int = 0
    used_adapters: int = 0
    used_shared: int = 0              # refcounted prefix-cache pages
    _request_holds: dict = field(default_factory=dict)   # req_id -> tokens
    _adapter_holds: dict = field(default_factory=dict)   # adapter_id -> tokens
    _shared_refs: dict = field(default_factory=dict)     # page_id -> refcount

    # ------------------------------------------------------------------
    @property
    def free_tokens(self) -> int:
        return (self.capacity_tokens - self.used_requests
                - self.used_adapters - self.used_shared)

    @property
    def cache_tokens(self) -> int:
        """Current adapter-cache capacity = resident adapters + free HBM."""
        return self.capacity_tokens - self.used_requests - self.used_shared

    def request_headroom(self) -> int:
        """Tokens available to requests without evicting any adapter."""
        return self.free_tokens

    # Per-shard view ----------------------------------------------------
    # When the KV plane is mesh-sharded, each device physically holds
    # capacity/n_shards tokens ("Serving Heterogeneous LoRA Adapters":
    # size the memory plane per device, not per host). The *accounting*
    # stays global — pages are a logical currency and the control plane
    # must make identical decisions at every mesh shape for token
    # parity — so these are telemetry, not gates.
    @property
    def per_shard_capacity_tokens(self) -> int:
        return self.capacity_tokens // self.n_shards

    @property
    def per_shard_free_tokens(self) -> int:
        return self.free_tokens // self.n_shards

    # Pages -------------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` KV entries."""
        return -(-tokens // self.page_size)

    @property
    def free_pages(self) -> int:
        return self.free_tokens // self.page_size

    def request_pages(self, req_id: int) -> int:
        return self._request_holds.get(req_id, 0) // self.page_size

    def reserve_request_pages(self, req_id: int, n_pages: int) -> None:
        """Page-granular hold (the paged engine's allocation unit)."""
        self.reserve_request(req_id, n_pages * self.page_size)

    # Requests ----------------------------------------------------------
    def reserve_request(self, req_id: int, tokens: int) -> None:
        if tokens < 0:
            raise PoolError("negative reservation")
        if tokens > self.free_tokens:
            raise PoolError(
                f"reserve_request({tokens}) exceeds free {self.free_tokens}")
        if self.page_size > 1 and tokens % self.page_size:
            raise PoolError(
                f"paged pool: hold of {tokens} tokens is not a multiple "
                f"of page_size={self.page_size}")
        if tokens == 0:
            # A zero-token reserve must not materialise a phantom hold
            # entry: ``req_id in _request_holds`` is how callers test
            # "does this request occupy memory".
            return
        self._request_holds[req_id] = self._request_holds.get(req_id, 0) + tokens
        self.used_requests += tokens

    def grow_request(self, req_id: int, tokens: int) -> None:
        self.reserve_request(req_id, tokens)

    def release_request(self, req_id: int) -> int:
        tokens = self._request_holds.pop(req_id, 0)
        self.used_requests -= tokens
        return tokens

    def shrink_request(self, req_id: int, tokens: int) -> None:
        """Give back part of a hold (paged engine: per-page reclaim)."""
        held = self._request_holds.get(req_id, 0)
        if tokens < 0 or tokens > held:
            raise PoolError(
                f"shrink_request({tokens}) exceeds hold {held}")
        if self.page_size > 1 and tokens % self.page_size:
            raise PoolError(
                f"paged pool: shrink of {tokens} tokens is not a "
                f"multiple of page_size={self.page_size}")
        if tokens == held:
            self._request_holds.pop(req_id, None)
        else:
            self._request_holds[req_id] = held - tokens
        self.used_requests -= tokens

    # Shared pages (prefix cache) ---------------------------------------
    def add_shared_page(self, page_id: int) -> None:
        """Admit ``page_id`` to the shared ledger with refcount 1 (the
        prefix cache's own reference). The page's tokens move to
        ``used_shared``; the caller is responsible for having given up
        (or never taken) any request hold covering them — adoption of a
        prompt page is ``shrink_request`` then ``add_shared_page``, a
        conserving transfer."""
        if self.page_size <= 1:
            raise PoolError("shared pages require a paged pool")
        if page_id in self._shared_refs:
            raise PoolError(f"page {page_id} already shared")
        if self.page_size > self.free_tokens:
            raise PoolError(
                f"add_shared_page: page_size {self.page_size} exceeds "
                f"free {self.free_tokens}")
        self._shared_refs[page_id] = 1
        self.used_shared += self.page_size

    def share_pages(self, page_ids) -> None:
        """Take one reference on each page (a request mapping them into
        its page table). All-or-nothing: unknown ids fail before any
        refcount moves."""
        for pid in page_ids:
            if pid not in self._shared_refs:
                raise PoolError(f"share_pages: page {pid} is not shared")
        for pid in page_ids:
            self._shared_refs[pid] += 1

    def release_shared(self, page_ids) -> list:
        """Drop one reference per page; pages hitting refcount zero are
        freed (tokens returned to the pool) and their ids returned so
        the engine can restore them to its physical free list."""
        for pid in page_ids:
            if self._shared_refs.get(pid, 0) < 1:
                raise PoolError(
                    f"release_shared: page {pid} has no reference")
        freed = []
        for pid in page_ids:
            self._shared_refs[pid] -= 1
            if self._shared_refs[pid] == 0:
                del self._shared_refs[pid]
                self.used_shared -= self.page_size
                freed.append(pid)
        return freed

    def shared_refcount(self, page_id: int) -> int:
        return self._shared_refs.get(page_id, 0)

    def shared_page_ids(self):
        return set(self._shared_refs)

    @property
    def n_shared_pages(self) -> int:
        return len(self._shared_refs)

    # Adapters ----------------------------------------------------------
    def hold_adapter(self, adapter_id: int, tokens: int) -> None:
        if adapter_id in self._adapter_holds:
            return
        if tokens > self.free_tokens:
            raise PoolError(
                f"hold_adapter({tokens}) exceeds free {self.free_tokens}")
        self._adapter_holds[adapter_id] = tokens
        self.used_adapters += tokens

    def drop_adapter(self, adapter_id: int) -> int:
        tokens = self._adapter_holds.pop(adapter_id, 0)
        self.used_adapters -= tokens
        return tokens

    def adapter_resident(self, adapter_id: int) -> bool:
        return adapter_id in self._adapter_holds

    # Introspection -------------------------------------------------------
    def check_invariants(self, free_page_ids=None) -> None:
        """Exact-accounting invariants; cheap enough to call per step.

        ``free_page_ids``: the engine's physical free list, when the
        caller has one — asserts no page is simultaneously free and
        shared-referenced, and that the free list has no duplicates.
        """
        assert self.used_requests == sum(self._request_holds.values())
        assert self.used_adapters == sum(self._adapter_holds.values())
        assert self.used_shared == len(self._shared_refs) * self.page_size
        assert 0 <= self.used_requests
        assert 0 <= self.used_adapters
        assert 0 <= self.used_shared
        assert (self.used_requests + self.used_adapters
                + self.used_shared) <= self.capacity_tokens
        # Conservation: free is exactly what the ledgers leave over.
        assert self.free_tokens == (
            self.capacity_tokens - self.used_requests
            - self.used_adapters - self.used_shared)
        for pid, refs in self._shared_refs.items():
            assert refs >= 1, f"shared page {pid} with refcount {refs}"
        if self.page_size > 1:
            for req_id, tokens in self._request_holds.items():
                assert tokens % self.page_size == 0, (
                    f"request {req_id} holds {tokens} tokens, not a "
                    f"multiple of page_size={self.page_size}")
        for req_id, tokens in self._request_holds.items():
            assert tokens > 0, f"phantom zero-token hold for {req_id}"
        if free_page_ids is not None:
            free = list(free_page_ids)
            assert len(free) == len(set(free)), "duplicate free page ids"
            both = set(free) & set(self._shared_refs)
            assert not both, f"pages both free and shared: {sorted(both)}"

    def snapshot(self) -> dict:
        snap = {
            "capacity": self.capacity_tokens,
            "requests": self.used_requests,
            "adapters": self.used_adapters,
            "free": self.free_tokens,
        }
        if self.page_size > 1:
            snap["page_size"] = self.page_size
            snap["pages_used"] = self.used_requests // self.page_size
            snap["pages_free"] = self.free_pages
            snap["shared"] = self.used_shared
            snap["pages_shared"] = self.n_shared_pages
        if self.n_shards > 1:
            snap["n_shards"] = self.n_shards
            snap["per_shard_capacity"] = self.per_shard_capacity_tokens
            snap["per_shard_free"] = self.per_shard_free_tokens
        return snap


def kv_token_bytes(n_layers: int, n_kv_heads: int, head_dim: int,
                   dtype_bytes: int = 2) -> int:
    """Bytes of one token's KV across all layers (the pool's currency)."""
    return 2 * n_layers * n_kv_heads * head_dim * dtype_bytes
