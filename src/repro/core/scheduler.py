"""Chameleon Scheduler (paper §4.2) and the scheduler interface.

Non-preemptive, adapter-aware multi-level queue with:

- WRS-based queue admission (wrs.py), K-means queue/cutoff adaptation
  (kmeans.py) every ``t_refresh`` seconds, M/M/1 quotas (quotas.py);
- two-phase batch assembly (Algorithm 1): per-queue quota admission,
  then top-down redistribution of spare tokens;
- adapter-blocking bypass with squash-on-misprediction;
- quota charges returned on completion (reservation semantics).

Quota charge of a request = input + predicted output + adapter tokens
(paper: the quota "includes input tokens, output tokens, and the memory
required for the corresponding adapter"). The *pool* reservation excludes
the adapter (adapters are held once, reference-counted, by the cache).

Layering (DESIGN §3): this scheduler orders work *within one node's
continuous batch* and is deliberately tenant-blind — per-tenant
fairness, admission limits, and SLO-aware rejection live a layer up in
``serving/gateway.py``, which holds its own queue and keeps this one
shallow. Both layers price requests with the same length-prediction
hook (``predictor.predict_request``), so a gateway-degraded
``max_new_tokens`` is the number this scheduler charges quota for.
``submit`` is non-blocking (enqueue only); placement happens inside
``schedule`` on the engine's step, and deadline enforcement for queued
requests is the step loop's ``reap_expired`` sweep.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np

from .adapter_cache import AdapterCache
from .kmeans import choose_queues, queue_index
from .lora import AdapterInfo
from .memory_pool import MemoryPool, PoolError
from .predictor import predict_request
from .quotas import QueueStats, assign_quotas
from .request import Request, RequestState
from .wrs import WRSCalculator


class BaseScheduler:
    """Engine-facing interface shared by Chameleon and the baselines."""

    name = "base"

    # Subclasses are expected to expose ``self.cache`` (AdapterCache);
    # the unplaced-release helper below uses it to drop async-load pins.

    # Dense engines let the scheduler reserve the predicted worst case
    # (input + predicted output) in the MemoryPool at admission. The
    # paged engine flips this off: it holds exactly its allocated KV
    # pages under the same req_id and grows/releases them itself, so the
    # scheduler must neither reserve nor release request holds (a
    # release here would drop the engine's page hold).
    reserve_from_pool = True

    def submit(self, req: Request, now: float) -> None:
        raise NotImplementedError

    def schedule(self, now: float, running: list[Request]) -> list[Request]:
        """Return requests to admit to the continuous batch this iteration."""
        raise NotImplementedError

    def on_finish(self, req: Request, now: float) -> None:
        pass

    def requeue(self, req: Request, now: float) -> None:
        self.submit(req, now)

    def pending_count(self) -> int:
        raise NotImplementedError

    def queue_pressure(self) -> float:
        """Routing signal: how loaded is this scheduler's backlog
        (cluster routers rank replicas by this, DESIGN §3).

        Base implementation: queued request count. Subclasses with a
        pool add a token-backlog term normalised by capacity.
        """
        return float(self.pending_count())

    def queued_requests_in_order(self) -> list[Request]:
        return []

    def queued_adapter_ids(self) -> set[int]:
        return set()

    # -- lifecycle: cancellation and deadlines ---------------------------
    def _release_unplaced(self, req: Request, now: float) -> None:
        """Drop everything an *unplaced* request can hold: queued
        requests carry no pool reservation or quota charges, so the
        only resource is the async-load adapter pin (``adapter_ref``).
        The in-flight H2D transfer, if any, completes harmlessly — the
        entry is merely unpinned and becomes evictable."""
        if req.adapter_ref:
            self.cache.release(req.adapter_id, now)
            req.adapter_ref = False
        req.load_wait_start = None

    def cancel(self, req: Request, now: float) -> bool:
        """Remove a queued (or LOADING-deferred) request from the wait
        queues, releasing its adapter pin. Returns False when the
        request is not queued here (already placed or finished) — the
        engine then cancels it at the next step boundary."""
        return False

    def reap_expired(self, now: float) -> list[Request]:
        """Remove and return queued requests whose deadline passed.
        Called from the engine/simulator step loop; the caller marks
        the returned requests EXPIRED and notifies their handles."""
        return []

    def _gate_adapter_ready(self, req: Request, now: float) -> bool:
        """Async-load admission gate shared by every scheduler: while
        the pinned adapter's H2D transfer is in flight the request is
        *deferred* — surfaced as LOADING, its load-wait window opened
        for the latency breakdown, never placed. Returns True once the
        adapter is usable (closing the window and restoring QUEUED so
        the caller's admission can proceed)."""
        if not self.cache.is_ready(req.adapter_id):
            self.n_deferred += 1
            if req.load_wait_start is None:
                req.load_wait_start = now
            req.state = RequestState.LOADING
            return False
        if req.load_wait_start is not None:
            req.adapter_load_wait += now - req.load_wait_start
            req.load_wait_start = None
            req.state = RequestState.QUEUED
        return True


@dataclass
class _QueueState:
    cutoff_hi: float                      # WRS upper bound (inf for last)
    quota: int                            # tokens this queue may reserve
    used: int = 0                         # tokens currently reserved
    reqs: deque = field(default_factory=deque)

    @property
    def available(self) -> int:
        return max(0, self.quota - self.used)


class ChameleonScheduler(BaseScheduler):
    """The paper's adapter-aware multi-level queue (§4.2).

    Requests land in one of K WRS-cutoff queues at ``submit``
    (non-blocking; prediction via the shared ``predict_request`` hook);
    ``schedule`` assembles each batch in two phases — per-queue M/M/1
    quota admission, then top-down redistribution of spare tokens —
    with an adapter-blocking bypass lane whose mispredictors are
    squashed back to their queue. Queue count and cutoffs re-adapt by
    K-means over observed WRS every ``t_refresh`` seconds (minimum
    ``refresh_min_samples`` completions). Non-preemptive: admitted
    requests run to completion unless the paged engine preempts for
    pages or a deadline/cancel sweep removes them.
    """

    name = "chameleon"

    def __init__(self,
                 pool: MemoryPool,
                 cache: AdapterCache,
                 adapters: dict[int, AdapterInfo],
                 predictor,
                 wrs_calc: Optional[WRSCalculator] = None,
                 slo: float = 5.0,
                 k_max: int = 4,
                 t_refresh: float = 300.0,
                 max_batch_requests: int = 64,
                 bypass_window: int = 8,
                 refresh_min_samples: int = 32,
                 max_predicted_output: int = 4096,
                 seed: int = 0):
        self.pool = pool
        self.cache = cache
        self.adapters = adapters
        self.predictor = predictor
        self.wrs_calc = wrs_calc or WRSCalculator()
        self.slo = slo
        self.k_max = k_max
        self.t_refresh = t_refresh
        self.max_batch_requests = max_batch_requests
        self.bypass_window = bypass_window
        self.refresh_min_samples = refresh_min_samples
        # Clamp predictions: an unbounded mispredict reserves an
        # unadmittable quota charge and starves the request forever.
        self.max_predicted_output = max_predicted_output
        self.rng = np.random.default_rng(seed)

        # Start with a single queue holding the whole budget; the first
        # refresh (once samples accumulate) will split it.
        self.queues: list[_QueueState] = [
            _QueueState(cutoff_hi=float("inf"),
                        quota=pool.capacity_tokens)]
        self._last_refresh = 0.0

        # Telemetry for adaptation.
        self._wrs_samples: deque = deque(maxlen=4096)
        self._charge_samples: deque = deque(maxlen=4096)  # (wrs, charge_tok)
        self._arrivals: deque = deque(maxlen=4096)        # (time, queue_idx)
        self._durations: dict[int, float] = {}            # queue -> EMA secs
        self._sizes: dict[int, float] = {}                # queue -> EMA tokens
        self.n_bypassed = 0
        self.n_squashed = 0
        self.n_deferred = 0   # placements refused while the adapter loads

    # -- helpers -----------------------------------------------------------
    def _charge_tokens(self, req: Request) -> int:
        ad = self.adapters[req.adapter_id]
        return req.input_len + req.predicted_output + ad.size_tokens

    def _reserve_tokens(self, req: Request) -> int:
        return req.input_len + req.predicted_output

    def pending_count(self) -> int:
        return sum(len(q.reqs) for q in self.queues)

    def queued_adapter_ids(self) -> set[int]:
        out: set[int] = set()
        for q in self.queues:
            for r in q.reqs:
                out.add(r.adapter_id)
        return out

    def queued_requests_in_order(self) -> list[Request]:
        """Priority order: queue 0 first, FIFO within a queue (prefetcher)."""
        out = []
        for q in self.queues:
            out.extend(q.reqs)
        return out

    def queue_pressure(self) -> float:
        """Backlog signal for cluster routing: queued requests plus the
        quota tokens they would charge, expressed as a fraction of pool
        capacity (so a few huge requests weigh like many small ones)."""
        charge = sum(self._charge_tokens(r)
                     for r in self.queued_requests_in_order())
        return self.pending_count() + charge / max(1, self.pool.capacity_tokens)

    def cancel(self, req: Request, now: float) -> bool:
        for q in self.queues:
            if req in q.reqs:
                q.reqs.remove(req)
                self._release_unplaced(req, now)
                return True
        return False

    def reap_expired(self, now: float) -> list[Request]:
        expired: list[Request] = []
        for q in self.queues:
            overdue = [r for r in q.reqs
                       if r.deadline is not None and r.deadline <= now]
            for r in overdue:
                q.reqs.remove(r)
                self._release_unplaced(r, now)
                expired.append(r)
        return expired

    # -- submission ----------------------------------------------------------
    def submit(self, req: Request, now: float) -> None:
        predict_request(self.predictor, req, self.max_predicted_output)
        ad = self.adapters[req.adapter_id]
        req.wrs = self.wrs_calc.wrs(req.input_len, req.predicted_output,
                                    ad.size_tokens)
        qi = self._queue_for(req.wrs)
        req.queue_idx = qi
        self.queues[qi].reqs.append(req)
        self._wrs_samples.append(req.wrs)
        self._charge_samples.append((req.wrs, self._charge_tokens(req)))
        self._arrivals.append((now, qi))

    def requeue(self, req: Request, now: float) -> None:
        """Squashed bypasser returns to the *front* of its queue."""
        qi = min(req.queue_idx, len(self.queues) - 1)
        req.queue_idx = qi
        self.queues[qi].reqs.appendleft(req)

    def _queue_for(self, wrs: float) -> int:
        for i, q in enumerate(self.queues):
            if wrs < q.cutoff_hi:
                return i
        return len(self.queues) - 1

    # -- adaptation -----------------------------------------------------------
    def maybe_refresh(self, now: float) -> bool:
        if (now - self._last_refresh) < self.t_refresh:
            return False
        if len(self._wrs_samples) < self.refresh_min_samples:
            return False
        self.refresh(now)
        return True

    def refresh(self, now: float) -> None:
        """Recompute queue count, cutoffs and quotas from recent load."""
        samples = np.array(self._wrs_samples, dtype=np.float64)
        k, _, cutoffs = choose_queues(samples, self.k_max)
        cut_hi = list(cutoffs) + [float("inf")]

        # Per-queue arrival rates over the telemetry window.
        window = max(1e-6, now - (self._arrivals[0][0] if self._arrivals
                                  else now - 1.0))
        new_assign = [queue_index(w, cutoffs) for w in samples]
        rates = [0.0] * k
        for qi in new_assign:
            rates[qi] += 1.0
        rates = [r / window for r in rates]

        # S per new queue: the max *charge* (tokens) among recent requests
        # that map into that queue — measured, not inferred from WRS.
        charge_max = [0.0] * k
        for wrs, tok in self._charge_samples:
            qi = queue_index(wrs, cutoffs)
            charge_max[qi] = max(charge_max[qi], float(tok))

        stats = []
        for qi in range(k):
            s_tok = max(self._sizes.get(qi, 0.0), charge_max[qi], 64.0)
            d_sec = self._durations.get(qi, max(self.slo / 5.0, 0.1))
            stats.append(QueueStats(max_size=s_tok, duration=d_sec,
                                    arrival_rate=rates[qi], slo=self.slo))
        quotas = assign_quotas(stats, self.pool.capacity_tokens)

        # Rebuild queues, re-binning waiting requests.
        waiting = [r for q in self.queues for r in q.reqs]
        used_per_new_q = [0] * k
        # Keep charges consistent: move each *running* charge to the new
        # queue of the same index clamped (charges reference queue ids).
        old_used = [q.used for q in self.queues]
        for i, u in enumerate(old_used):
            used_per_new_q[min(i, k - 1)] += u
        self.queues = [
            _QueueState(cutoff_hi=cut_hi[i], quota=quotas[i],
                        used=used_per_new_q[i]) for i in range(k)]
        for r in waiting:
            qi = self._queue_for(r.wrs)
            r.queue_idx = qi
            self.queues[qi].reqs.append(r)
        self._last_refresh = now

    def note_duration(self, req: Request, now: float) -> None:
        if req.first_scheduled_time is None:
            return
        dur = max(1e-6, now - req.first_scheduled_time)
        qi = min(req.queue_idx, len(self.queues) - 1)
        prev = self._durations.get(qi, dur)
        self._durations[qi] = 0.9 * prev + 0.1 * dur
        size = float(self._charge_tokens(req))
        prev_s = self._sizes.get(qi, size)
        self._sizes[qi] = 0.9 * prev_s + 0.1 * size

    # -- batch assembly (Algorithm 1 + bypass) ---------------------------------
    def schedule(self, now: float, running: list[Request]) -> list[Request]:
        self.maybe_refresh(now)
        batch: list[Request] = []
        slots = self.max_batch_requests - len(running)
        if slots <= 0:
            return batch

        queued_protect = self.queued_adapter_ids()
        # Min predicted remaining decode tokens across running requests —
        # the token-unit proxy for "how long the blocked head would wait
        # anyway" used by the bypass rule.
        remaining = [max(0, r.predicted_output - r.generated)
                     for r in running]
        min_remaining = min(remaining) if remaining else 0

        # Phase 1: per-queue quota admission. Every queue lends whatever
        # quota it did not consume — Algorithm 1 redistributes *all*
        # unused quota top-down, including that of a queue whose head is
        # memory-blocked (it cannot use the spare itself this iteration,
        # so withholding it would just idle tokens).
        leftover = 0
        for q in self.queues:
            if len(batch) >= slots:
                break
            self._put_batch(q, q.available, batch, slots, now,
                            queued_protect, min_remaining,
                            charge_queue=self.queues.index(q))
            leftover += q.available
        # Phase 2: redistribute spare tokens top-down.
        if leftover > 0:
            for qi, q in enumerate(self.queues):
                if leftover <= 0 or len(batch) >= slots:
                    break
                if not q.reqs:
                    continue
                consumed = self._put_batch(
                    q, leftover, batch, slots, now, queued_protect,
                    min_remaining, charge_queue=None, lenders=True)
                leftover -= consumed
        return batch

    def _admit(self, req: Request, q: _QueueState, now: float,
               queued_protect: set[int]) -> bool:
        """Memory-side admission: reserve pool tokens + adapter residency.

        Paged mode keeps this worst-case check as the admission
        *throttle* (without it every request would admit and preemption
        would do all the work, wasting prefills) but rounds the demand
        up to whole pages — the engine allocates page-granular, so a
        request that passes here can always get its prompt pages.

        Async loads: the first admission attempt pins the adapter
        (``req.adapter_ref``) and starts the load; while the entry is
        LOADING the request is *deferred* — never placed, but the rest
        of the batch (and the bypass lane) proceeds, and the pin keeps
        the mid-flight entry from being evicted. Synchronous data
        planes (the simulator, ``async_load=False`` engines) mark
        entries READY inside ``on_load``, so the deferral branch never
        triggers and admission is the old single-shot path.
        """
        need = self._reserve_tokens(req)
        if not self.reserve_from_pool:
            need = self.pool.pages_for(need) * self.pool.page_size
        aid = req.adapter_id
        protect = queued_protect - {aid}
        if not req.adapter_ref:
            extra = (0 if self.cache.resident(aid)
                     else self.adapters[aid].size_tokens)
            if not self.cache.shrink_for_requests(need + extra, now,
                                                  protect):
                return False
            try:
                self.cache.acquire(aid, now, queued_protect=protect)
            except PoolError:
                return False
            req.adapter_ref = True
        elif not self.cache.shrink_for_requests(need, now, protect):
            return False
        if not self._gate_adapter_ready(req, now):
            return False
        try:
            if self.reserve_from_pool:
                self.pool.reserve_request(req.req_id, need)
        except PoolError:
            return False
        req.reserved_tokens = need if self.reserve_from_pool else 0
        return True

    def _charge(self, req: Request, need: int, charge_queue: Optional[int],
                ) -> None:
        """Record quota charges; lenders=None means spread over lenders."""
        if charge_queue is not None:
            self.queues[charge_queue].used += need
            req.charges.append((charge_queue, need))
            return
        # Phase-2 borrow: charge queues with spare capacity, top down.
        left = need
        for qi, q in enumerate(self.queues):
            spare = q.available
            if spare <= 0:
                continue
            take = min(spare, left)
            q.used += take
            req.charges.append((qi, take))
            left -= take
            if left <= 0:
                break
        if left > 0:   # over-subscription falls on the last queue
            qi = len(self.queues) - 1
            self.queues[qi].used += left
            req.charges.append((qi, left))

    def _put_batch(self, q: _QueueState, budget: int, batch: list[Request],
                   slots: int, now: float, queued_protect: set[int],
                   min_remaining: int, charge_queue: Optional[int],
                   lenders: bool = False) -> int:
        """Admit from one queue within ``budget`` tokens. Returns consumed."""
        consumed = 0
        blocked_head: Optional[Request] = None
        scanned = 0
        while q.reqs and len(batch) < slots:
            req = q.reqs[0]
            need = self._charge_tokens(req)
            if need > budget - consumed:
                break
            if self._admit(req, q, now, queued_protect):
                q.reqs.popleft()
                self._charge(req, need, charge_queue)
                consumed += need
                req.state = RequestState.RUNNING
                req.first_scheduled_time = (req.first_scheduled_time
                                            if req.first_scheduled_time
                                            is not None else now)
                batch.append(req)
                blocked_head = None
                continue
            # Head blocked on memory/adapter: try the bypass lane.
            blocked_head = req
            break
        if blocked_head is not None and len(batch) < slots:
            consumed += self._bypass(q, budget - consumed, batch, slots, now,
                                     queued_protect, min_remaining,
                                     charge_queue)
        return consumed

    def _bypass(self, q: _QueueState, budget: int, batch: list[Request],
                slots: int, now: float, queued_protect: set[int],
                min_remaining: int, charge_queue: Optional[int]) -> int:
        """Adapter-blocking bypass (paper §4.2 'Bypassing Adapter Blocking').

        Younger requests may jump the blocked head iff (a) they fit the
        remaining quota, (b) their adapter is already resident or fits in
        currently-free memory, and (c) their predicted length does not
        exceed the head's expected wait (token-unit proxy:
        predicted_output ≤ min remaining decode tokens of the running
        batch). Admitted bypassers are flagged; if they outlive their
        prediction they are squashed by the engine and re-queued.
        """
        consumed = 0
        candidates = list(q.reqs)[1:1 + self.bypass_window]
        for req in candidates:
            if len(batch) >= slots:
                break
            need = self._charge_tokens(req)
            if need > budget - consumed:
                continue
            resident = self.cache.resident(req.adapter_id)
            ad = self.adapters[req.adapter_id]
            fits_free = (self._reserve_tokens(req)
                         + (0 if resident else ad.size_tokens)
                         ) <= self.pool.free_tokens
            if not (resident or fits_free):
                continue
            # A bypasser may *start* a load only into genuinely idle
            # capacity (a free entry slot + free tokens, both checked
            # above): with async loads the candidate is deferred, not
            # placed, so letting up to bypass_window speculative loads
            # evict useful entries would churn the cache for requests
            # that may never win their seat.
            if not resident and self.cache.max_entries is not None \
                    and len(self.cache.entries) >= self.cache.max_entries:
                continue
            if min_remaining and req.predicted_output > min_remaining:
                continue
            if not self._admit(req, q, now, queued_protect):
                continue
            q.reqs.remove(req)
            self._charge(req, need, charge_queue)
            consumed += need
            req.state = RequestState.RUNNING
            req.bypassed = True
            req.first_scheduled_time = (req.first_scheduled_time
                                        if req.first_scheduled_time
                                        is not None else now)
            batch.append(req)
            self.n_bypassed += 1
        return consumed

    # -- completion -------------------------------------------------------------
    def on_finish(self, req: Request, now: float) -> None:
        self.note_duration(req, now)
        self._return_charges(req)
        if self.reserve_from_pool:
            self.pool.release_request(req.req_id)
        self.cache.release(req.adapter_id, now)

    def on_squash(self, req: Request, now: float) -> None:
        """Bypasser exceeded its prediction: release and re-queue (§4.2)."""
        self._return_charges(req)
        if self.reserve_from_pool:
            self.pool.release_request(req.req_id)
        self.cache.release(req.adapter_id, now)
        self.n_squashed += 1
        req.reset_for_requeue()
        self.requeue(req, now)

    def _return_charges(self, req: Request) -> None:
        for qi, tok in req.charges:
            qi = min(qi, len(self.queues) - 1)
            self.queues[qi].used = max(0, self.queues[qi].used - tok)
        req.charges = []
