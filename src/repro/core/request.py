"""Request lifecycle for many-adapter LLM serving.

A request arrives with a known input length, an (unknown at admission)
true output length, and the id of the LoRA adapter it targets. The
scheduler sees only the *predicted* output length. All timestamps are
floats in seconds on an externally-supplied clock so that the same code
drives both the real engine and the discrete-event simulator.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional

_req_counter = itertools.count()


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"       # in the continuous batch (prefill or decode)
    FINISHED = "finished"
    SQUASHED = "squashed"     # bypasser that exceeded its predicted length


@dataclass
class Request:
    """One inference request."""

    input_len: int
    output_len: int                 # ground truth (revealed token by token)
    adapter_id: int
    arrival_time: float = 0.0
    req_id: int = field(default_factory=lambda: next(_req_counter))

    # Filled by the predictor at admission.
    predicted_output: int = 0

    # Scheduling metadata.
    wrs: float = 0.0                # weighted request size
    queue_idx: int = -1
    charges: list = field(default_factory=list)   # [(queue_idx, tokens)] quota charges
    reserved_tokens: int = 0                      # memory-pool reservation
    bypassed: bool = False                        # admitted via the bypass lane
    squash_count: int = 0
    # Async adapter loads: True once admission has pinned (and begun
    # loading) this request's adapter; placement may still be deferred
    # until the load completes, and the pin survives the deferral so
    # the mid-flight adapter cannot be evicted out from under it.
    adapter_ref: bool = False

    # Progress.
    state: RequestState = RequestState.QUEUED
    generated: int = 0              # decode tokens emitted so far

    # Timestamps (seconds).
    first_scheduled_time: Optional[float] = None
    first_token_time: Optional[float] = None      # TTFT reference point
    finish_time: Optional[float] = None
    adapter_load_wait: float = 0.0  # time spent stalled on adapter loading

    # ------------------------------------------------------------------
    @property
    def total_true_tokens(self) -> int:
        return self.input_len + self.output_len

    def predicted_total_tokens(self) -> int:
        return self.input_len + self.predicted_output

    @property
    def done(self) -> bool:
        return self.generated >= self.output_len

    def exceeded_prediction(self) -> bool:
        """True when the request ran past its predicted decode length."""
        return self.generated > self.predicted_output

    # Latency metrics -----------------------------------------------------
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def e2e(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def reset_for_requeue(self) -> None:
        """Squash: roll progress back so the request re-executes fully."""
        self.generated = 0
        self.state = RequestState.QUEUED
        self.charges = []
        self.reserved_tokens = 0
        self.bypassed = False
        self.adapter_ref = False     # the squash path released the pin
        self.squash_count += 1
        # TTFT is *not* reset: the user saw nothing yet on squash (the
        # first token is only surfaced once prefill re-runs), so keeping
        # the worst-case timestamps is the honest accounting. We clear
        # first_token_time because the original token was discarded.
        self.first_token_time = None
