"""Request lifecycle for many-adapter LLM serving.

A request arrives with a known input length (or real prompt tokens), an
(unknown at admission) true output length, and the id of the LoRA
adapter it targets. The scheduler sees only the *predicted* output
length. All timestamps are floats in seconds on an externally-supplied
clock so that the same code drives both the real engine and the
discrete-event simulator.

Lifecycle (DESIGN §3):

    QUEUED --> LOADING --> RUNNING --> FINISHED
       |          |           |  ^
       |          |           |  | (disagg: prefill done, KV in flight)
       |          |           v  |
       |          |         MIGRATING
       |          |           |
       |          |           +-----> EXPIRED   (deadline passed)
       +----------+----------------> CANCELLED  (handle.cancel())

REJECTED is a fourth terminal state reached *before* QUEUED: gateway
admission control (serving/gateway.py) refused entry, so no scheduler
ever saw the request. Its handle still resolves (state + decision
trace) — a refused submit is reported, never dropped.

MIGRATING (serving/disagg.py) is the disaggregated-cluster handoff
window: prefill completed on a prefill-role replica and the request's
KV pages are crossing the inter-replica link to a decode replica. The
request holds pool references on *both* ends (source pages are
share-pinned so eviction cannot reclaim them mid-copy); cancel and
deadline expiry remain legal and must release both sides.

LOADING is the async-adapter deferral: admission pinned the adapter and
its host->device transfer is in flight, so the request cannot be placed
yet (the rest of the batch proceeds). RUNNING requests may bounce back
to QUEUED via the squash path (bypass misprediction / page preemption);
``preserved_tokens`` keeps the already-streamed prefix across that
requeue so the user-visible stream never rewinds.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional

from .sampling import SamplingParams

_req_counter = itertools.count()

#: Terminal lifecycle states: once reached, a request never leaves.
TERMINAL_STATES: frozenset = None  # filled below (needs RequestState)


class RequestState(enum.Enum):
    QUEUED = "queued"
    LOADING = "loading"       # admission pinned the adapter; H2D in flight
    RUNNING = "running"       # in the continuous batch (prefill or decode)
    MIGRATING = "migrating"   # disagg: KV handoff prefill -> decode replica
    FINISHED = "finished"
    CANCELLED = "cancelled"   # handle.cancel() before completion
    EXPIRED = "expired"       # deadline/TTL passed before completion
    SQUASHED = "squashed"     # bypasser that exceeded its predicted length
    REJECTED = "rejected"     # gateway admission control refused entry


TERMINAL_STATES = frozenset({RequestState.FINISHED, RequestState.CANCELLED,
                             RequestState.EXPIRED, RequestState.REJECTED})


@dataclass
class Request:
    """One inference request."""

    input_len: int
    output_len: int                 # ground truth (revealed token by token)
    adapter_id: int
    arrival_time: float = 0.0
    req_id: int = field(default_factory=lambda: next(_req_counter))

    # Multi-tenant serving: which tenant (org/user) submitted this.
    # Engines and schedulers ignore it; the gateway keys its per-tenant
    # limits, fair-queueing weights and decision traces on it.
    tenant: str = "default"

    # Real prompt token ids (length == input_len). None keeps the
    # synthetic arange prompt the engine historically fabricated, so
    # trace-driven workloads need no token material.
    prompt: Optional[list] = None

    # How to turn logits into tokens (engine tier). None = greedy.
    sampling: Optional[SamplingParams] = None

    # Absolute deadline on the serving system's clock; the scheduler
    # reaps queued requests past it and the engine step loop expires
    # running ones. None = no deadline.
    deadline: Optional[float] = None

    # Filled by the predictor at admission.
    predicted_output: int = 0

    # Scheduling metadata.
    wrs: float = 0.0                # weighted request size
    queue_idx: int = -1
    charges: list = field(default_factory=list)   # [(queue_idx, tokens)] quota charges
    reserved_tokens: int = 0                      # memory-pool reservation
    bypassed: bool = False                        # admitted via the bypass lane
    squash_count: int = 0
    # Async adapter loads: True once admission has pinned (and begun
    # loading) this request's adapter; placement may still be deferred
    # until the load completes, and the pin survives the deferral so
    # the mid-flight adapter cannot be evicted out from under it.
    adapter_ref: bool = False
    # Cooperative cancellation: set by RequestHandle.cancel() on a
    # RUNNING request; the engine finalises the slot at the next step
    # boundary (a jit'd decode cannot be interrupted mid-call).
    cancel_requested: bool = False

    # Progress.
    state: RequestState = RequestState.QUEUED
    generated: int = 0              # decode tokens emitted so far

    # Squash/requeue continuity: tokens already surfaced to the handle
    # (and their TBT records) survive the requeue; re-execution
    # regenerates the same prefix (greedy / position-seeded sampling is
    # deterministic) without re-streaming or re-counting it.
    preserved_tokens: list = field(default_factory=list)
    preserved_tbts: list = field(default_factory=list)
    # Engine clock time of the last token actually streamed to the
    # handle; survives requeue so the first *new* token after a squash
    # gets an honest TBT (measured from what the user last saw, not
    # from the silent re-execution of the prefix).
    last_stream_time: Optional[float] = None

    # Timestamps (seconds).
    first_scheduled_time: Optional[float] = None
    first_token_time: Optional[float] = None      # TTFT reference point
    finish_time: Optional[float] = None
    adapter_load_wait: float = 0.0  # time spent stalled on adapter loading
    load_wait_start: Optional[float] = None       # deferral began (transient)

    def __post_init__(self):
        if self.prompt is not None:
            self.prompt = list(self.prompt)
            if len(self.prompt) != self.input_len:
                # The prompt is authoritative when both are given.
                self.input_len = len(self.prompt)

    # ------------------------------------------------------------------
    @property
    def total_true_tokens(self) -> int:
        return self.input_len + self.output_len

    def predicted_total_tokens(self) -> int:
        return self.input_len + self.predicted_output

    @property
    def max_output_tokens(self) -> int:
        """Decode budget: the workload truth capped by SamplingParams."""
        if self.sampling is not None \
                and self.sampling.max_new_tokens is not None:
            return min(self.output_len, self.sampling.max_new_tokens)
        return self.output_len

    @property
    def done(self) -> bool:
        return self.generated >= self.max_output_tokens

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def exceeded_prediction(self) -> bool:
        """True when the request ran past its predicted decode length."""
        return self.generated > self.predicted_output

    # Latency metrics -----------------------------------------------------
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def e2e(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def queue_wait(self) -> Optional[float]:
        """Arrival -> first admission into the batch."""
        if self.first_scheduled_time is None:
            return None
        return self.first_scheduled_time - self.arrival_time

    def stash_progress(self, tokens: Optional[list],
                       tbts: Optional[list],
                       last_stream_time: Optional[float]) -> None:
        """Squash/preemption: keep the already-streamed tokens, their
        TBT records and the last stream timestamp on the request so
        the requeue (and the eventual re-execution) preserves them.
        One implementation shared by every serving tier — the engine
        and the DES pop their per-request records into this."""
        if tokens is not None:
            self.preserved_tokens = tokens
        if tbts is not None:
            self.preserved_tbts = tbts
        if last_stream_time is not None:
            self.last_stream_time = last_stream_time

    def reset_for_requeue(self) -> None:
        """Squash: roll progress back so the request re-executes fully."""
        self.generated = 0
        self.state = RequestState.QUEUED
        self.charges = []
        self.reserved_tokens = 0
        self.bypassed = False
        self.adapter_ref = False     # the squash path released the pin
        self.load_wait_start = None
        self.squash_count += 1
        # TTFT is *not* reset when tokens were already streamed: the
        # user saw the preserved prefix, so the original first-token
        # timestamp is the honest one. Without streamed tokens (legacy
        # paths that never populated preserved_tokens) the first token
        # is only surfaced once prefill re-runs, so it is cleared.
        if not self.preserved_tokens:
            self.first_token_time = None
