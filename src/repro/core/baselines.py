"""Baseline schedulers the paper compares against (§5).

- ``FIFOScheduler`` — S-LoRA's policy: strict arrival order, admit while
  memory fits. With ``cache.enabled = False`` this *is* the S-LoRA
  system (adapters dropped when their last request completes; queued
  adapters are asynchronously prefetched by the engine's prefetcher).
- ``SJFScheduler`` — µServe's speculative shortest-job-first over the
  predicted output length, with linear aging to mitigate starvation.

Both share Chameleon's memory plumbing (pool + cache manager) so that
the *only* experimental variable is the policy.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

from .adapter_cache import AdapterCache
from .lora import AdapterInfo
from .memory_pool import MemoryPool, PoolError
from .predictor import predict_request
from .request import Request, RequestState
from .scheduler import BaseScheduler


class _SingleQueueScheduler(BaseScheduler):
    def __init__(self, pool: MemoryPool, cache: AdapterCache,
                 adapters: dict[int, AdapterInfo], predictor,
                 max_batch_requests: int = 64,
                 max_predicted_output: int = 4096):
        self.pool = pool
        self.cache = cache
        self.adapters = adapters
        self.predictor = predictor
        self.max_batch_requests = max_batch_requests
        self.max_predicted_output = max_predicted_output
        self.reqs: deque[Request] = deque()
        self.n_deferred = 0   # placements refused while the adapter loads

    def submit(self, req: Request, now: float) -> None:
        predict_request(self.predictor, req, self.max_predicted_output)
        self.reqs.append(req)

    def requeue(self, req: Request, now: float) -> None:
        self.reqs.appendleft(req)

    def pending_count(self) -> int:
        return len(self.reqs)

    def queued_adapter_ids(self) -> set[int]:
        return {r.adapter_id for r in self.reqs}

    def queued_requests_in_order(self) -> list[Request]:
        return list(self.reqs)

    def cancel(self, req: Request, now: float) -> bool:
        if req in self.reqs:
            self.reqs.remove(req)
            self._release_unplaced(req, now)
            return True
        return False

    def reap_expired(self, now: float) -> list[Request]:
        expired = [r for r in self.reqs
                   if r.deadline is not None and r.deadline <= now]
        for r in expired:
            self.reqs.remove(r)
            self._release_unplaced(r, now)
        return expired

    def _order(self, now: float) -> None:
        """Hook: reorder self.reqs before admission."""

    def _admit(self, req: Request, now: float) -> bool:
        need = req.input_len + req.predicted_output
        if not self.reserve_from_pool:
            # Paged engine: demand is page-granular (see
            # ChameleonScheduler._admit).
            need = self.pool.pages_for(need) * self.pool.page_size
        aid = req.adapter_id
        protect = self.queued_adapter_ids() - {aid}
        # Async loads: first attempt pins + starts the load; a LOADING
        # adapter is never placed (see ChameleonScheduler._admit).
        if not req.adapter_ref:
            extra = (0 if self.cache.resident(aid)
                     else self.adapters[aid].size_tokens)
            if not self.cache.shrink_for_requests(need + extra, now,
                                                  protect):
                return False
            try:
                self.cache.acquire(aid, now, queued_protect=protect)
            except PoolError:
                return False
            req.adapter_ref = True
        elif not self.cache.shrink_for_requests(need, now, protect):
            return False
        if not self._gate_adapter_ready(req, now):
            return False
        try:
            if self.reserve_from_pool:
                self.pool.reserve_request(req.req_id, need)
        except PoolError:
            return False
        req.reserved_tokens = need if self.reserve_from_pool else 0
        return True

    def schedule(self, now: float, running: list[Request]) -> list[Request]:
        self._order(now)
        batch: list[Request] = []
        slots = self.max_batch_requests - len(running)
        while self.reqs and len(batch) < slots:
            req = self.reqs[0]
            if not self._admit(req, now):
                break   # head-of-line blocking, by design
            self.reqs.popleft()
            req.state = RequestState.RUNNING
            if req.first_scheduled_time is None:
                req.first_scheduled_time = now
            batch.append(req)
        return batch

    def on_finish(self, req: Request, now: float) -> None:
        if self.reserve_from_pool:
            self.pool.release_request(req.req_id)
        self.cache.release(req.adapter_id, now)

    def on_squash(self, req: Request, now: float) -> None:
        if self.reserve_from_pool:
            self.pool.release_request(req.req_id)
        self.cache.release(req.adapter_id, now)
        req.reset_for_requeue()
        self.requeue(req, now)


class FIFOScheduler(_SingleQueueScheduler):
    """S-LoRA: arrival order."""

    name = "fifo"


class SJFScheduler(_SingleQueueScheduler):
    """µServe: speculative SJF on predicted output length, with aging.

    priority = predicted_output − aging_rate · wait_seconds
    (lower = scheduled first). ``aging_rate`` is tokens/second of
    priority credit; the paper observes that even with aging, SJF starves
    long requests at high load — our Fig. 13 reproduction shows the same.
    """

    name = "sjf"

    def __init__(self, *args, aging_rate: float = 1.0, **kwargs):
        super().__init__(*args, **kwargs)
        self.aging_rate = aging_rate

    def _order(self, now: float) -> None:
        self.reqs = deque(sorted(
            self.reqs,
            key=lambda r: (r.predicted_output
                           - self.aging_rate * (now - r.arrival_time))))
