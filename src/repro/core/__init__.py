"""Chameleon core: the paper's contribution (adapter cache + scheduler).

Pure-Python control plane (host-side, as in the real system); the JAX
data plane lives in repro.serving / repro.models / repro.kernels.
"""
from .adapter_cache import (AdapterCache, AdapterState, CacheEntry,
                            CacheStats, CostAwareEviction, EvictionWeights,
                            FairShareEviction, LRUEviction)
from .baselines import FIFOScheduler, SJFScheduler
from .kmeans import choose_queues, kmeans_1d, queue_index
from .lora import (PAPER_RANKS, AdapterInfo, adapter_bytes, assign_adapters,
                   build_adapter_pool, powerlaw_rank_sampler)
from .memory_pool import MemoryPool, PoolError, kv_token_bytes
from .predictor import (HistogramPredictor, NoisyOraclePredictor, bucket_of,
                        bucket_repr, measure_accuracy, predict_request)
from .prefetcher import HistogramPrefetcher, QueuedRequestPrefetcher
from .prefix_cache import PrefixCache, PrefixNode
from .quotas import QueueStats, assign_quotas, tok_min
from .request import Request, RequestState, TERMINAL_STATES
from .sampling import GREEDY, SamplingParams
from .scheduler import BaseScheduler, ChameleonScheduler
from .wrs import OutputOnlyCalculator, WRSCalculator, WRSWeights

__all__ = [
    "AdapterCache", "AdapterState", "CacheEntry", "CacheStats",
    "CostAwareEviction",
    "EvictionWeights", "FairShareEviction", "LRUEviction",
    "FIFOScheduler", "SJFScheduler",
    "choose_queues", "kmeans_1d", "queue_index",
    "PAPER_RANKS", "AdapterInfo", "adapter_bytes", "assign_adapters",
    "build_adapter_pool", "powerlaw_rank_sampler",
    "MemoryPool", "PoolError", "kv_token_bytes",
    "HistogramPredictor", "NoisyOraclePredictor", "bucket_of",
    "bucket_repr", "measure_accuracy", "predict_request",
    "HistogramPrefetcher", "QueuedRequestPrefetcher",
    "PrefixCache", "PrefixNode",
    "QueueStats", "assign_quotas", "tok_min",
    "Request", "RequestState", "TERMINAL_STATES",
    "GREEDY", "SamplingParams",
    "BaseScheduler", "ChameleonScheduler",
    "OutputOnlyCalculator", "WRSCalculator", "WRSWeights",
]
