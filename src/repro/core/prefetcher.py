"""Adapter prefetching (paper §4.1 'Prefetching').

Two tiers:

1. ``QueuedRequestPrefetcher`` (always on, S-LoRA-style): walk the wait
   queues in priority order and prefetch missing adapters into the cache
   while free memory allows, without evicting anything useful.
2. ``HistogramPrefetcher`` (optional, Fig. 15): histogram-based load
   prediction in the style of Serverless-in-the-Wild [46] — per adapter,
   a histogram over inter-arrival times predicts the next arrival; the
   prefetcher warms adapters whose predicted next use falls within the
   horizon, most-imminent first.
"""
from __future__ import annotations

from collections import defaultdict, deque

import numpy as np


class QueuedRequestPrefetcher:
    def __init__(self, cache, max_per_round: int = 4):
        self.cache = cache
        self.max_per_round = max_per_round

    def run(self, queued_requests, now: float,
            budget: int | None = None) -> list[int]:
        """Prefetch missing adapters of queued requests. Returns ids
        loaded. ``budget`` caps this round below ``max_per_round`` —
        engines pass their free *slot* count so prefetch can never
        trigger a slot-capacity eviction."""
        limit = (self.max_per_round if budget is None
                 else min(self.max_per_round, budget))
        loaded = []
        seen = set()
        queued_ids = {r.adapter_id for r in queued_requests}
        for req in queued_requests:
            if len(loaded) >= limit:
                break
            aid = req.adapter_id
            if aid in seen or self.cache.resident(aid):
                continue
            seen.add(aid)
            info = self.cache.catalog[aid]
            # Only use genuinely free memory: prefetching must never
            # evict (that would fight the cost-aware policy).
            if info.size_tokens <= self.cache.pool.free_tokens:
                if self.cache.prefetch(aid, now,
                                       queued_protect=queued_ids - {aid}):
                    loaded.append(aid)
        return loaded


class HistogramPrefetcher:
    """Predictive prefetch from per-adapter inter-arrival histograms.

    Buckets are logarithmic (powers of two seconds). Prediction: the
    modal inter-arrival bucket's midpoint after the adapter's last
    arrival. Accuracy is high for the paper's power-law/uniform workload
    (they report >95 %); bursty adapters predict "soon" and stay warm.
    """

    def __init__(self, cache, horizon: float = 2.0, max_history: int = 64,
                 max_per_round: int = 2):
        self.cache = cache
        self.horizon = horizon
        self.max_per_round = max_per_round
        self._last_arrival: dict[int, float] = {}
        self._inter: dict[int, deque] = defaultdict(
            lambda: deque(maxlen=max_history))

    def observe_arrival(self, adapter_id: int, now: float) -> None:
        last = self._last_arrival.get(adapter_id)
        if last is not None:
            self._inter[adapter_id].append(max(1e-3, now - last))
        self._last_arrival[adapter_id] = now

    def _predict_next(self, adapter_id: int) -> float | None:
        hist = self._inter.get(adapter_id)
        last = self._last_arrival.get(adapter_id)
        if not hist or last is None:
            return None
        buckets = defaultdict(int)
        for dt in hist:
            buckets[int(np.ceil(np.log2(dt)))] += 1
        mode = max(buckets.items(), key=lambda kv: kv[1])[0]
        midpoint = (2.0 ** (mode - 1) + 2.0 ** mode) / 2 if mode > -10 else 0.0
        return last + midpoint

    def run(self, now: float, queued_protect=(),
            budget: int | None = None) -> list[int]:
        """``queued_protect`` (adapter ids of queued requests) threads
        through to the cache so a predictive prefetch never evicts an
        adapter a queued request is about to need (§4.1 second tier);
        ``budget`` caps the round (see QueuedRequestPrefetcher.run)."""
        cands = []
        for aid in self._last_arrival:
            if self.cache.resident(aid):
                continue
            t = self._predict_next(aid)
            # Accept anything predicted inside the horizon, *including*
            # overdue predictions (t < now): an adapter whose predicted
            # arrival just slipped past is the most imminent of all, not
            # a stale entry to skip — requiring now <= t meant a
            # prefetcher tick landing one tick late never warmed it.
            # Predictions more than one horizon in the past are stale
            # (the adapter's traffic stopped), not imminent: without the
            # lower bound a dead adapter's fixed past prediction would
            # top-rank every tick forever, burning load bandwidth and a
            # cache slot.
            if t is not None and now - self.horizon <= t <= now + self.horizon:
                cands.append((t, aid))
        cands.sort()
        limit = (self.max_per_round if budget is None
                 else min(self.max_per_round, budget))
        loaded = []
        protect = set(queued_protect)
        for _, aid in cands[:limit]:
            info = self.cache.catalog[aid]
            if info.size_tokens <= self.cache.pool.free_tokens:
                if self.cache.prefetch(aid, now,
                                       queued_protect=protect - {aid}):
                    loaded.append(aid)
        return loaded
