"""Token-id-keyed radix prefix tree over the paged KV pool (ROADMAP 1).

Shared system prompts and few-shot preambles are re-prefilled on every
request in the seed engine, even though their KV is identical across
requests. This cache keeps *prompt* KV pages resident after a request
finishes and lets the next request map them straight into its page
table, prefilling only the unseen suffix — the paper's idle-memory
argument (repurpose free HBM to kill redundant work) applied one level
below the adapter cache.

Structure: a radix tree at page granularity. Each node owns exactly one
physical KV page and is keyed by the tuple of ``page_size`` token ids
written into it; a root-to-node path spells out a prompt prefix. Trees
are segregated by a *KV signature* (``sig``):

- exact mode: ``sig = adapter_id``. LoRA in this repo touches the
  q/k/v/o projections, so a page's KV depends on which adapter ran the
  prefill — only same-adapter reuse is output-identical.
- aLoRA mode: ``sig = -1`` for everyone. The engine computes prompt KV
  with the base model only (the adapter activates at generation, per
  "Activated LoRA", PAPERS.md), which makes prefix pages adapter-
  invariant and genuinely shareable *across* adapters.

A KV page's contents are a pure function of (sig, absolute positions,
token ids): two requests whose prompts agree on the first k tokens have
bit-identical KV rows for those positions. That is what makes both
whole-page reuse and the mid-page copy-on-write fork (copy the first
``rem`` rows of a cached page whose key agrees on ``rem`` tokens)
sound.

Memory safety is the pool's refcount ledger: every node holds one pool
reference on its page (taken at adoption); each request mapping the
page holds another. Eviction (`evict_lru`) only ever touches leaf nodes
whose refcount is exactly 1 — i.e. pages no live request can read — so
a stale page can never be handed to another request while mapped.
"""
from __future__ import annotations

from typing import Optional

from repro.core.memory_pool import MemoryPool


class PrefixNode:
    """One cached KV page: ``key`` = the page's token ids."""
    __slots__ = ("sig", "key", "page_id", "parent", "children",
                 "last_used")

    def __init__(self, sig: int, key: tuple, page_id: int,
                 parent: Optional["PrefixNode"]):
        self.sig = sig
        self.key = key
        self.page_id = page_id
        self.parent = parent
        self.children: dict = {}          # key tuple -> PrefixNode
        self.last_used = 0


class PrefixCache:
    """Radix insert/match/evict over pool-refcounted KV pages."""

    def __init__(self, pool: MemoryPool, page_size: int):
        if page_size <= 1:
            raise ValueError("prefix cache requires a paged pool")
        self.pool = pool
        self.page_size = page_size
        self._roots: dict = {}            # sig -> {key tuple: PrefixNode}
        self._nodes: dict = {}            # page_id -> PrefixNode
        self._clock = 0                   # logical LRU clock
        self.evictions = 0
        self.inserts = 0

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._nodes)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ------------------------------------------------------------------
    def match(self, sig: int, tokens, limit: int):
        """Longest cached prefix of ``tokens`` under signature ``sig``.

        ``limit`` caps the matched length (the engine passes L-1 so at
        least one prompt token always prefills — the last-position
        logits must be computed fresh).

        Returns ``(pages, n_full_tokens, partial_page, partial_len)``:
        ``pages`` are whole shared pages covering ``n_full_tokens``;
        ``partial_page``, when not None, is a cached page whose first
        ``partial_len`` token ids extend the match mid-page — the
        copy-on-write fork source. The touched chain's LRU stamps are
        refreshed. No references are taken here; the caller must
        ``pool.share_pages(pages)`` before anything can evict them.
        """
        ps = self.page_size
        now = self._tick()
        children = self._roots.get(sig, {})
        pages: list = []
        consumed = 0
        while consumed + ps <= limit:
            key = tuple(tokens[consumed:consumed + ps])
            child = children.get(key)
            if child is None:
                break
            child.last_used = now
            pages.append(child.page_id)
            consumed += ps
            children = child.children
        partial_page, partial_len = None, 0
        rem = limit - consumed
        if rem > 0 and children:
            # Mid-page divergence: fork from the child sharing the
            # longest token run (the COW source). lcp < ps always — an
            # lcp of ps is a whole-page match the walk above took.
            want = tuple(tokens[consumed:consumed + min(rem, ps)])
            best, best_len = None, 0
            for key, child in children.items():
                lcp = 0
                for a, b in zip(key, want):
                    if a != b:
                        break
                    lcp += 1
                if lcp > best_len:
                    best, best_len = child, lcp
            if best is not None:
                best.last_used = now
                partial_page, partial_len = best.page_id, best_len
        return pages, consumed, partial_page, partial_len

    # ------------------------------------------------------------------
    def insert(self, sig: int, tokens, page_ids) -> list:
        """Adopt a request's fully-written prompt pages into the tree.

        ``tokens`` must cover ``len(page_ids)`` whole pages; pages whose
        token path is already cached are skipped (first writer wins —
        the duplicate page stays private to its request and is freed
        normally). Returns the page ids actually adopted, in order; the
        caller performs the pool accounting transfer for each
        (``shrink_request`` → ``add_shared_page`` → ``share_pages``).
        """
        ps = self.page_size
        now = self._tick()
        children = self._roots.setdefault(sig, {})
        parent: Optional[PrefixNode] = None
        adopted: list = []
        for i, pid in enumerate(page_ids):
            key = tuple(tokens[i * ps:(i + 1) * ps])
            node = children.get(key)
            if node is None:
                node = PrefixNode(sig, key, pid, parent)
                children[key] = node
                self._nodes[pid] = node
                self.inserts += 1
                adopted.append(pid)
            node.last_used = now
            parent, children = node, node.children
        return adopted

    # ------------------------------------------------------------------
    def evict_lru(self, n_pages: int = 1) -> list:
        """Reclaim up to ``n_pages`` pages under pool pressure.

        Only leaf nodes whose pool refcount is exactly 1 (the cache's
        own reference — no request is reading the page) are candidates;
        least-recently-used first. Evicting a leaf can expose its
        parent, so deep cold chains unwind across calls. Returns the
        freed physical page ids for the engine's free list.
        """
        freed: list = []
        while len(freed) < n_pages:
            victim = None
            for node in self._nodes.values():
                if node.children:
                    continue
                if self.pool.shared_refcount(node.page_id) != 1:
                    continue
                if victim is None or node.last_used < victim.last_used:
                    victim = node
            if victim is None:
                break
            self._remove(victim)
            freed.extend(self.pool.release_shared([victim.page_id]))
            self.evictions += 1
        return freed

    def _remove(self, node: PrefixNode) -> None:
        siblings = (node.parent.children if node.parent is not None
                    else self._roots.get(node.sig, {}))
        if siblings.get(node.key) is node:
            del siblings[node.key]
        del self._nodes[node.page_id]

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "nodes": len(self._nodes),
            "evictions": self.evictions,
            "inserts": self.inserts,
        }
