"""Per-queue resource-quota assignment via M/M/1 (paper §4.2).

For each queue q with max request size S (tokens), expected duration D
(seconds), arrival rate λ (req/s) and latency target SLO (seconds):

    service rate     µ = Tok / (S · D)          [requests/s the quota sustains]
    time in system   T = 1 / (µ − λ)
    SLO constraint   T ≤ SLO
    ⇒  Tok_min ≥ S · D · (1/SLO + λ)

Each queue gets its Tok_min; the remaining budget is split proportionally
to the Tok_min weights. If Σ Tok_min exceeds the budget the system is in
overload: quotas are scaled down proportionally (SLOs are best-effort
until load subsides) — the paper's model implicitly assumes feasibility,
we make the overload path explicit.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class QueueStats:
    max_size: float          # S: max tokens of a request admitted to this queue
    duration: float          # D: expected seconds a request occupies resources
    arrival_rate: float      # λ: req/s entering this queue
    slo: float               # seconds


def tok_min(stats: QueueStats) -> float:
    """Paper formula with a progress guard.

    The raw formula S·D·(1/SLO + λ) can fall below S itself whenever
    D·(1/SLO + λ) < 1 (lightly-loaded queue of large requests) — a quota
    smaller than one maximal request permanently starves the queue,
    since phase-2 redistribution only lends tokens left over by *empty*
    queues. We therefore floor the quota at S: every queue must always
    be able to hold at least one of its largest requests.
    """
    raw = stats.max_size * stats.duration * (1.0 / stats.slo
                                             + stats.arrival_rate)
    return max(raw, stats.max_size)


def assign_quotas(queues: list[QueueStats], total_tokens: int,
                  ) -> list[int]:
    """Integer token quota per queue, summing to ``total_tokens``."""
    if not queues:
        return []
    mins = np.array([tok_min(q) for q in queues], dtype=np.float64)
    mins = np.maximum(mins, 1.0)
    total = float(total_tokens)
    if mins.sum() >= total:
        # Overload: proportional scale-down.
        quota = mins / mins.sum() * total
    else:
        spare = total - mins.sum()
        quota = mins + spare * (mins / mins.sum())
    out = np.floor(quota).astype(int)
    out = np.maximum(out, 1)
    # Settle the rounding residue on the largest queues while keeping
    # every quota >= 1 (hypothesis found the naive "give it to queue 0"
    # version overflowing the budget when min-bumps exceeded it).
    residue = total_tokens - int(out.sum())
    while residue != 0:
        if residue > 0:
            out[int(np.argmax(out))] += residue
            residue = 0
        else:
            i = int(np.argmax(out))
            take = min(out[i] - 1, -residue)
            if take <= 0:
                break            # budget < n queues: all floored at 1
            out[i] -= take
            residue += take
    return out.tolist()
