"""Weighted Request Size (paper §4.2).

    WRS = A·In/MaxIn + B·Out/MaxOut + C·Adapter/MaxAdapter

with A=0.3, B=0.5, C=0.2 (the paper's sensitivity-tuned constants).
``Out`` is the *predicted* output length. Max values are workload
normalisers tracked online (decayed max so that a single outlier does not
permanently flatten the distribution).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class WRSWeights:
    a_input: float = 0.3
    b_output: float = 0.5
    c_adapter: float = 0.2


class WRSCalculator:
    def __init__(self, weights: WRSWeights | None = None,
                 max_input: int = 1, max_output: int = 1,
                 max_adapter: int = 1, decay: float = 0.999):
        self.w = weights or WRSWeights()
        self.max_input = float(max_input)
        self.max_output = float(max_output)
        self.max_adapter = float(max_adapter)
        self.decay = decay

    def update_normalisers(self, input_len: int, output_len: int,
                           adapter_size: int) -> None:
        self.max_input = max(self.max_input * self.decay, float(input_len), 1.0)
        self.max_output = max(self.max_output * self.decay, float(output_len), 1.0)
        self.max_adapter = max(self.max_adapter * self.decay,
                               float(adapter_size), 1.0)

    def wrs(self, input_len: int, predicted_output: int,
            adapter_size: int) -> float:
        self.update_normalisers(input_len, predicted_output, adapter_size)
        return (self.w.a_input * min(1.0, input_len / self.max_input)
                + self.w.b_output * min(1.0, predicted_output / self.max_output)
                + self.w.c_adapter * min(1.0, adapter_size / self.max_adapter))


class OutputOnlyCalculator(WRSCalculator):
    """Fig. 16 baseline: size = predicted output length only (µServe-like)."""

    def wrs(self, input_len: int, predicted_output: int,
            adapter_size: int) -> float:
        self.update_normalisers(input_len, predicted_output, adapter_size)
        return min(1.0, predicted_output / self.max_output)
