"""Collective-byte accounting from the compiled (post-SPMD) HLO text.

cost_analysis() has no collective breakdown, so we parse
``compiled.as_text()`` — the optimized HLO after the SPMD partitioner
has inserted collectives (the pre-partitioning StableHLO has none).

Per-device wire bytes per op (ring algorithms, k = replica-group size,
b = result buffer bytes):

    all-reduce          2·b·(k-1)/k
    all-gather            b·(k-1)/k
    reduce-scatter        b·(k-1)          (result is the scattered 1/k)
    all-to-all            b·(k-1)/k
    collective-permute    b

Loop awareness: collectives inside a ``while`` body execute once per
trip. HLO text carries no trip counts, so ops in loop bodies are
tallied separately (``@loop``) and the caller scales them by the known
scan length (n_layers for scan-over-layers — the only collective-
carrying loop in this codebase; SSM chunk scans are elementwise).
Loop bodies are identified from ``body=%name`` on while ops, so
non-collective fusions never misclassify.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5,
}

_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute", "collective-broadcast")

_RESULT_RE = re.compile(
    r"=\s*(?:\()?([a-z][0-9a-z]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{")


def _bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _wire_bytes(op: str, b: float, k: int) -> float:
    k = max(k, 2)
    if op == "all-reduce":
        return 2.0 * b * (k - 1) / k
    if op == "all-gather":
        return b * (k - 1) / k
    if op == "reduce-scatter":
        return b * (k - 1)
    if op == "all-to-all":
        return b * (k - 1) / k
    return b        # permute / broadcast


def collective_bytes_from_text(text: str, n_devices: int = 1) -> dict:
    """Per-device collective wire bytes from optimized HLO text.

    Returns {per_op: {op[@loop]: bytes}, count, total_bytes} where
    total_bytes leaves @loop entries UNSCALED — apply
    ``scaled_collective_bytes`` with the scan trip count.
    """
    lines = text.splitlines()
    loop_bodies: set[str] = set()
    for line in lines:
        if " while(" in line:
            m = _BODY_RE.search(line)
            if m:
                loop_bodies.add(m.group(1))

    per_op: dict[str, float] = defaultdict(float)
    count: dict[str, int] = defaultdict(int)
    current = ""
    for line in lines:
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            current = m.group(1)
            continue
        hit = None
        for op in _OPS:
            if f" {op}(" in line or f" {op}-start(" in line:
                hit = op
                break
        if hit is None:
            continue
        mr = _RESULT_RE.search(line)
        if not mr:
            continue
        b = _bytes(mr.group(1), mr.group(2))
        mg = _GROUPS_RE.search(line)
        if mg:
            k = int(mg.group(2))
        else:
            mo = _GROUPS_OLD_RE.search(line)
            k = len(mo.group(1).split(",")) if mo else n_devices
        wire = _wire_bytes(hit, b, k)
        key = hit + ("@loop" if current in loop_bodies else "")
        per_op[key] += wire
        count[key] += 1
    return {"per_op": dict(per_op), "count": dict(count),
            "total_bytes": float(sum(per_op.values()))}


def scaled_collective_bytes(coll: dict, n_layers: int) -> float:
    """Total per-device wire bytes with loop-body ops scaled by the
    scan trip count (scan-over-layers)."""
    total = 0.0
    for op, b in coll["per_op"].items():
        total += b * (n_layers if op.endswith("@loop") else 1)
    return total
