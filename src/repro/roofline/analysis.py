"""Roofline analysis over the dry-run artifacts (deliverable g).

Three terms per (arch × shape × mesh), all in seconds-per-step:

    compute    = HLO_FLOPs            / (peak_FLOP/s per chip)
    memory     = HLO_bytes_accessed   / (HBM bytes/s per chip)
    collective = collective_bytes     / (ICI bytes/s per chip)

cost_analysis() on an SPMD program reports per-device FLOPs/bytes, and
the collective bytes are parsed per-device from the partitioned HLO
(hlo_stats), so no further division by chip count is needed. The
dominant term is the bottleneck; the §Perf loop iterates on it.

MODEL_FLOPS (usefulness check):
    train:   6·N·D      (N params — active for MoE; D tokens processed)
    prefill: 2·N·D
    decode:  2·N·B      (one token per request) + 2·B·KV·kv_bytes-ish
The ratio MODEL_FLOPS / (HLO_FLOPs × chips) exposes remat/padding/
redundancy waste.
"""
from __future__ import annotations

import json
from dataclasses import dataclass

from repro.configs import SHAPE_BY_NAME, get_config
from repro.models.base import Family

# TPU v5e constants (assignment).
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BPS = 819e9              # bytes/s / chip
ICI_BPS = 50e9               # bytes/s / link (per-chip effective)


def scan_trips(arch: str, shape_name: str) -> int:
    """Executions of the layer-scan body per step.

    cost_analysis() counts a while body ONCE (verified: useful_ratio ≈
    n_layers before correction), so FLOPs/bytes are scaled by the scan
    trip count; collective @loop bytes use the same factor. Micro-
    batched cells multiply by the accumulation factor (nested scan).
    """
    from repro.launch.cases import MICROBATCHES
    cfg = get_config(arch)
    kind = SHAPE_BY_NAME[shape_name].kind
    if cfg.family == Family.MOE:
        base = cfg.n_layers // cfg.moe_every
    elif cfg.family == Family.HYBRID:
        base = cfg.n_layers // cfg.attn_every
    else:
        base = cfg.n_layers
    mb = MICROBATCHES.get(arch, 1)
    if kind == "train":
        base *= mb
    elif kind == "prefill":
        base *= min(mb, 2)
    return max(base, 1)


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float          # analytic HBM lower bound (see memory_lb_bytes)
    memory_hlo_s: float      # raw cost_analysis bytes (upper bound)
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    bytes_per_device: float
    step_lower_bound_s: float
    mfu_bound: float         # MODEL_FLOPS / (chips·peak·step_bound)

    def table_row(self) -> dict:
        return {k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in self.__dict__.items()}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    spec = SHAPE_BY_NAME[shape_name]
    n_active = cfg.active_param_count()
    tokens = spec.global_batch * spec.seq_len
    if spec.kind == "train":
        return 6.0 * n_active * tokens
    if spec.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one new token per sequence.
    return 2.0 * n_active * spec.global_batch


def memory_lb_bytes(arch: str, shape_name: str, n_devices: int) -> float:
    """Analytic per-device HBM-traffic lower bound (bytes/step).

    cost_analysis()'s "bytes accessed" counts logical operand bytes
    pre-fusion (~100× real HBM traffic), so the memory roofline term
    uses this physical minimum instead: every resident byte the step
    must touch at least once — weights (+moments r/w ×2 for train, ×3
    with gradient), KV/state read+write, activation stream. The raw HLO
    bytes are still reported as an upper bound.
    """
    cfg = get_config(arch)
    spec = SHAPE_BY_NAME[shape_name]
    par = cfg.param_count() * 2 / n_devices           # bf16, FSDP (train)
    # Inference weights shard over TP only (replicated across data) —
    # except MoE expert weights, which shard over the expert axis too.
    tp = 16
    if cfg.family == Family.MOE:
        inf_par = cfg.param_count() * 2 / min(n_devices, 256)
    else:
        inf_par = cfg.param_count() * 2 / tp
    act_par = (cfg.active_param_count() * 2
               * (inf_par / (cfg.param_count() * 2)))
    tokens_dev = spec.global_batch * spec.seq_len / n_devices
    d = cfg.d_model
    if spec.kind == "train":
        # params read + grad write + moments r/w (bf16) + activation
        # stream (~12 residual-sized reads/writes per layer with remat).
        weights = par * (1 + 1 + 4)
        acts = tokens_dev * d * 2 * 12 * max(cfg.n_layers, 1)
        return weights + acts
    if spec.kind == "prefill":
        weights = act_par
        acts = tokens_dev * d * 2 * 8 * max(cfg.n_layers, 1)
        kv_write = (2 * cfg.n_layers * cfg.kv_dim * tokens_dev * 2
                    if cfg.n_kv_heads else 0)
        return weights + acts + kv_write
    # decode: stream active params once + read the full KV/state.
    if cfg.n_kv_heads:
        kv = (2 * cfg.n_layers * cfg.kv_dim * spec.seq_len
              * spec.global_batch * 2 / n_devices)
        if cfg.family == Family.HYBRID:
            n_sites = cfg.n_layers // cfg.attn_every
            kv = (2 * n_sites * cfg.kv_dim * spec.seq_len
                  * spec.global_batch * 2 / n_devices)
    else:
        kv = 0.0
    if cfg.family in (Family.SSM, Family.HYBRID):
        kv += (cfg.n_layers * cfg.d_inner * max(cfg.d_state, 1) * 4
               * spec.global_batch / n_devices)
    return act_par + kv


def analyze_cell(rec: dict, n_layers: int) -> RooflineRow | None:
    if not rec.get("ok"):
        return None
    trips = scan_trips(rec["arch"], rec["shape"])
    # Loop-body correction (see scan_trips): entry-portion FLOPs are
    # double-counted by the multiplication, making compute/memory terms
    # slight over-estimates (documented; entry ≤ ~5 % for train/prefill,
    # larger for decode where the lm_head dominates the entry).
    flops_dev = rec["cost"].get("flops", 0.0) * trips
    bytes_dev = rec["cost"].get("bytes_accessed", 0.0) * trips
    per_op = rec["collectives"]["per_op"]
    coll_dev = sum(b * (trips if op.endswith("@loop") else 1)
                   for op, b in per_op.items())
    compute_s = flops_dev / PEAK_FLOPS
    mem_lb = memory_lb_bytes(rec["arch"], rec["shape"],
                             rec["n_devices"]) / HBM_BPS
    memory_hlo_s = bytes_dev / HBM_BPS
    coll_s = coll_dev / ICI_BPS
    dom = max(("compute", compute_s), ("memory", mem_lb),
              ("collective", coll_s), key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    total_flops = flops_dev * rec["n_devices"]
    bound = max(compute_s, mem_lb, coll_s)
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=mem_lb, memory_hlo_s=memory_hlo_s,
        collective_s=coll_s,
        dominant=dom, model_flops=mf, hlo_flops_total=total_flops,
        useful_ratio=mf / total_flops if total_flops else 0.0,
        bytes_per_device=bytes_dev,
        step_lower_bound_s=bound,
        mfu_bound=(mf / (rec["n_devices"] * PEAK_FLOPS * bound)
                   if bound else 0.0))


def analyze_file(path: str, mesh: str = "16x16") -> list[RooflineRow]:
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key, rec in sorted(results.items()):
        if rec.get("mesh") != mesh or not rec.get("ok"):
            continue
        row = analyze_cell(rec, get_config(rec["arch"]).n_layers)
        if row:
            rows.append(row)
    return rows


def whats_the_bottleneck(row: RooflineRow) -> str:
    """One sentence on what would move the dominant term down."""
    if row.dominant == "compute":
        if row.useful_ratio < 0.35:
            return ("compute-bound with low useful ratio: cut remat "
                    "recompute / padding waste (flash-attention kernel, "
                    "tighter head sharding)")
        return ("compute-bound near useful: more chips or lower-precision "
                "matmuls are the only levers")
    if row.dominant == "memory":
        return ("HBM-bound: fuse bandwidth-bound ops (Pallas), shrink "
                "KV/state dtype (int8 KV), or raise arithmetic intensity "
                "(larger per-chip batch)")
    return ("collective-bound: reshard to cut all-gathers (2D weight "
            "sharding), overlap collectives with compute, or quantise "
            "gradients (int8 all-reduce)")
