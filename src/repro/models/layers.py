"""Core layer primitives: norms, RoPE (+M-RoPE), GQA attention, SwiGLU.

Pure-functional JAX; einsum-structured so GSPMD can shard every
contraction. GQA never materialises repeated KV heads: queries are
reshaped to (kv_head, group) and contracted against the raw KV tensors.
Softmax and norms accumulate in fp32 regardless of activation dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import constrain_ffn_hidden, \
    constrain_heads

NEG_INF = -1e30


# ---------------------------------------------------------------- norms
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def head_rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """qk_norm (qwen3): per-head RMS over head_dim; w is (head_dim,)."""
    return rms_norm(x, w, eps)


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                 ) -> tuple[jax.Array, jax.Array]:
    """positions: (..., S) int -> cos/sin (..., S, head_dim/2)."""
    freqs = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                  sections: tuple[int, ...]) -> tuple[jax.Array, jax.Array]:
    """Multimodal RoPE (qwen2-vl): positions (3, B, S) for t/h/w axes.

    The head_dim/2 frequency slots are partitioned into ``sections``
    (t, h, w); each section takes its angle from the matching position
    axis. Text tokens carry identical t/h/w positions, so M-RoPE reduces
    to 1-D RoPE for them.
    """
    assert positions.shape[0] == 3
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(head_dim, theta)                 # (half,)
    ang_all = positions.astype(jnp.float32)[..., None] * freqs  # (3,B,S,half)
    parts = []
    off = 0
    for axis, sec in enumerate(sections):
        parts.append(ang_all[axis, ..., off:off + sec])
        off += sec
    ang = jnp.concatenate(parts, axis=-1)               # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) — rotate-half convention."""
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s,
                            x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ------------------------------------------------------------ attention
# Above this query length, attention runs query-chunked (memory-bounded).
Q_CHUNK_THRESHOLD = 2048
Q_CHUNK = 1024


def _attn_block(qg, k, v, q_offset, kv_len, causal, scale):
    """One query block. qg: (B, Sq, Kh, G, D); full k/v. Exact softmax
    per query row (query-chunking needs no online rescaling)."""
    Sq = qg.shape[1]
    Sk = k.shape[1]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = None
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(Sk)[None, :]
        mask = (kpos <= qpos)[None, None, None]
    if kv_len is not None:
        valid = jnp.arange(Sk)[None, :] < jnp.reshape(kv_len, (-1, 1))
        valid = valid[:, None, None, None, :]
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True,
                  q_offset: jax.Array | int = 0,
                  kv_len: jax.Array | None = None) -> jax.Array:
    """Grouped-query attention without KV duplication.

    q: (B, Sq, H, D); k, v: (B, Sk, Kh, D) with H = Kh * G.
    ``q_offset`` is the absolute position of q[0] (decode: cache length).
    ``kv_len`` masks out cache slots >= kv_len (padded decode caches).

    Long sequences run *query-chunked* (lax.scan over blocks of
    ``Q_CHUNK`` queries): the (Sq, Sk) score matrix never materialises —
    at 32k context that is 42 GB vs 1.3 GB per chip. Query chunking is
    exact (each row's softmax sees all keys); the Pallas flash kernel is
    the TPU fast path, this is the shardable lowering.
    Returns (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qg = constrain_heads(q.reshape(B, Sq, Kh, G, D))
    k = constrain_heads(k)
    v = constrain_heads(v)
    scale = D ** -0.5
    if Sq < Q_CHUNK_THRESHOLD or Sq % Q_CHUNK != 0:
        out = _attn_block(qg, k, v, q_offset, kv_len, causal, scale)
        return out.reshape(B, Sq, H, D)

    n_chunks = Sq // Q_CHUNK
    qc = jnp.moveaxis(qg.reshape(B, n_chunks, Q_CHUNK, Kh, G, D), 1, 0)

    def body(_, inp):
        qi, ci = inp
        off = q_offset + ci * Q_CHUNK
        return None, _attn_block(qi, k, v, off, kv_len, causal, scale)

    # Remat the chunk body: without it the backward pass stacks every
    # chunk's probs — reconstructing the full (Sq, Sk) score memory the
    # chunking exists to avoid.
    body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None,
                           (qc, jnp.arange(n_chunks)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Kh, G, D)
    return out.reshape(B, Sq, H, D)


def suffix_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     q_positions: jax.Array) -> jax.Array:
    """Causal attention where each *row* starts at its own offset.

    Suffix prefill over a prefix-cache hit: row ``b``'s queries sit at
    absolute positions ``q_positions[b, :]`` while k/v hold the whole
    context (cached prefix + fresh suffix, gathered from KV pages).
    ``gqa_attention``'s causal path only supports a scalar/step offset,
    so this applies the per-row mask ``kpos <= q_positions[b, i]``
    directly — otherwise the exact ``_attn_block`` computation (same
    einsums, NEG_INF masking, softmax) so the numerics match the dense
    prefill path.

    q: (B, S, H, D); k, v: (B, T, Kh, D); q_positions: (B, S) int.
    Returns (B, S, H, D).
    """
    B, S, H, D = q.shape
    Kh = k.shape[2]
    G = H // Kh
    qg = constrain_heads(q.reshape(B, S, Kh, G, D))
    k = constrain_heads(k)
    v = constrain_heads(v)
    scale = D ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(k.shape[1])
    mask = kpos[None, None, :] <= q_positions[:, :, None]   # (B, S, T)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, S, H, D)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array) -> jax.Array:
    """Single-token decode against a padded KV cache.

    q: (B, 1, H, D); caches: (B, Smax, Kh, D); cache_len: (B,) — number
    of valid entries (the new token's KV must already be written).
    """
    return gqa_attention(q, k_cache, v_cache, causal=False,
                         kv_len=cache_len)


# ----------------------------------------------------------------- MLP
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    h = constrain_ffn_hidden(jax.nn.silu(g) * u)
    return jnp.einsum("bsf,fd->bsd", h, w_down)


def gelu_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    return jnp.einsum("bsf,fd->bsd",
                      jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, w_up)),
                      w_down)


# ---------------------------------------------------------- embeddings
def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """x: (B, S, D); table: (D, V) -> logits (B, S, V)."""
    return jnp.einsum("bsd,dv->bsv", x, table,
                      preferred_element_type=jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean token NLL in fp32. logits (B,S,V), labels (B,S) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
