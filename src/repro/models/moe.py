"""Mixture-of-Experts with capacity-based dispatch/combine einsums.

MaxText-style dense dispatch: top-k routing, per-expert capacity
buckets, one-hot dispatch/combine tensors. The experts dimension is
sharded (EP) by the distribution layer; XLA inserts all-to-alls at the
dispatch and combine einsums. A shared expert (llama4) runs densely for
every token.

Router details: softmax over expert logits; top-k selection; optional
renormalisation of the selected weights (qwen3 style); auxiliary
load-balancing loss (Switch-style) returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import (constrain_expert_ecd,
                                            constrain_expert_ecf,
                                            constrain_moe_groups,
                                            constrain_moe_local)


def _capacity(tokens: int, n_experts: int, top_k: int,
              capacity_factor: float) -> int:
    cap = int(tokens * top_k * capacity_factor / n_experts)
    return max(cap, 1)


def route(x: jax.Array, router_w: jax.Array, top_k: int,
          ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,S,D); router_w: (D,E). Returns (weights, idx, aux_loss)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)           # (B,S,K)
    weights = weights / jnp.maximum(
        weights.sum(axis=-1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e f_e * p_e.
    E = router_w.shape[-1]
    me = jnp.mean(probs, axis=(0, 1))                    # (E,)
    one_hot = jax.nn.one_hot(idx[..., 0], E)             # top-1 fraction
    fe = jnp.mean(one_hot, axis=(0, 1))
    aux = E * jnp.sum(me * fe)
    return weights, idx, aux


MOE_GROUP = 2048      # tokens per dispatch group (MaxText-style)


def moe_block(x: jax.Array, router_w: jax.Array,
              w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
              top_k: int, capacity_factor: float = 1.25,
              group_size: int = MOE_GROUP,
              ) -> tuple[jax.Array, jax.Array]:
    """Capacity-bucketed MoE with *group-local* dispatch.

    x: (B,S,D); w_*: (E, D, F) / (E, F, D). Returns (out, aux_loss).

    Tokens are split into groups of ``group_size``; routing positions,
    capacity and the dispatch/combine one-hots are computed per group,
    so the dispatch tensor is (G, Tg, E, Cg) with Cg =
    Tg·top_k·cf/E — a *global* (T, E, C) one-hot scales as T²·k·cf/E
    and reached 25 TB/device for qwen3-moe train_4k before this fix.
    Groups ride the batch sharding; expert buckets reshard to the
    expert axis (the MoE all-to-all).
    """
    B, S, D = x.shape
    E = router_w.shape[-1]
    weights, idx, aux = route(x, router_w, top_k)
    T = B * S
    Tg = min(group_size, T)
    assert T % Tg == 0, (T, Tg)
    G = T // Tg
    cap = _capacity(Tg, E, top_k, capacity_factor)

    flat_idx = idx.reshape(G, Tg, top_k)
    flat_w = weights.reshape(G, Tg, top_k)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)   # (G,Tg,K,E)
    # Rank of each assignment within its (group, expert) bucket.
    pos = (jnp.cumsum(onehot.reshape(G, Tg * top_k, E), axis=1)
           .reshape(G, Tg, top_k, E) - 1)
    pos = jnp.sum(pos * onehot, axis=-1)                    # (G,Tg,K)
    keep = pos < cap
    flat_w = flat_w * keep
    pos_clip = jnp.minimum(pos, cap - 1)

    disp = (jax.nn.one_hot(flat_idx, E, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(pos_clip, cap, dtype=x.dtype)[..., None, :])
    disp = disp * keep[..., None, None].astype(x.dtype)     # (G,Tg,K,E,C)
    combine = jnp.sum(disp * flat_w[..., None, None].astype(x.dtype),
                      axis=2)                               # (G,Tg,E,C)
    disp = jnp.sum(disp, axis=2)                            # (G,Tg,E,C)

    xg = constrain_moe_groups(x.reshape(G, Tg, D))
    disp = constrain_moe_groups(disp)
    combine = constrain_moe_groups(combine)
    expert_in = jnp.einsum("gtd,gtec->gecd", xg, disp)
    expert_in = constrain_moe_local(expert_in)    # bucket locally...
    expert_in = constrain_expert_ecd(expert_in)   # ...then a2a reshard
    g = constrain_expert_ecf(
        jnp.einsum("gecd,edf->gecf", expert_in, w_gate))
    u = constrain_expert_ecf(
        jnp.einsum("gecd,edf->gecf", expert_in, w_up))
    act = jax.nn.silu(g) * u
    expert_out = constrain_expert_ecd(
        jnp.einsum("gecf,efd->gecd", act, w_down))
    expert_out = constrain_moe_local(expert_out)  # a2a back to groups
    yf = constrain_moe_groups(
        jnp.einsum("gecd,gtec->gtd", expert_out, combine))
    return yf.reshape(B, S, D), aux


def moe_block_gather(x: jax.Array, router_w: jax.Array,
                     w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
                     top_k: int) -> tuple[jax.Array, jax.Array]:
    """Dropless MoE for decode (S == 1, small token count).

    Gathers each token's top-k expert weights — the true memory traffic
    of MoE decode (every token streams its experts from HBM). No
    capacity buckets, no dropping; exact.
    """
    B, S, D = x.shape
    weights, idx, aux = route(x, router_w, top_k)        # (B,S,K)
    xg = x.reshape(B * S, D)
    idxf = idx.reshape(B * S, top_k)
    wf = weights.reshape(B * S, top_k).astype(x.dtype)
    g_w = jnp.take(w_gate, idxf, axis=0)                 # (T,K,D,F)
    u_w = jnp.take(w_up, idxf, axis=0)
    d_w = jnp.take(w_down, idxf, axis=0)                 # (T,K,F,D)
    g = jnp.einsum("td,tkdf->tkf", xg, g_w)
    u = jnp.einsum("td,tkdf->tkf", xg, u_w)
    y = jnp.einsum("tkf,tkfd->tkd", jax.nn.silu(g) * u, d_w)
    y = jnp.einsum("tkd,tk->td", y, wf)
    return y.reshape(B, S, D), aux
