"""Family dispatcher: one uniform entry surface over all model families.

    api.init_params(cfg, key)
    api.train_loss(cfg, params, **batch)          # batch from input specs
    api.prefill(cfg, params, **inputs)
    api.decode_step(cfg, params, **inputs)

The launcher / dry-run / engine talk to this module only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import encdec, hybrid, lm, ssm_lm
from .base import Family, ModelConfig
from .lm import init_params  # shared: param_shapes covers every family
from .lm import sample_tokens  # family-agnostic: operates on logits


def _mod(cfg: ModelConfig):
    if cfg.family == Family.SSM:
        return ssm_lm
    if cfg.family == Family.HYBRID:
        return hybrid
    if cfg.family == Family.ENCDEC:
        return encdec
    return lm


def forward(cfg, params, tokens, **kw):
    return _mod(cfg).forward(cfg, params, tokens, **kw)


def train_loss(cfg, params, tokens, labels, **kw):
    return _mod(cfg).train_loss(cfg, params, tokens, labels, **kw)


def prefill(cfg, params, tokens, **kw):
    return _mod(cfg).prefill(cfg, params, tokens, **kw)


def decode_step(cfg, params, tokens, state, cache_len=None, **kw):
    m = _mod(cfg)
    if cfg.family in (Family.DENSE, Family.MOE, Family.VLM):
        return m.decode_step(cfg, params, tokens, state, cache_len, **kw)
    if cfg.family == Family.SSM:
        return m.decode_step(cfg, params, tokens, state, **kw)
    return m.decode_step(cfg, params, tokens, state, cache_len, **kw)


# ---------------------------------------------- fused decode hot loop
def supports_fused(cfg: ModelConfig) -> bool:
    """Families servable by the fused decode+sample horizon loop.

    Anything whose ``decode_step`` is the (tokens, kv, cache_len)
    dense-KV shape: dense decoder LMs and MoE (same KV path, superstep
    scan). VLM needs per-step M-RoPE positions the engine does not
    thread; SSM/hybrid/enc-dec carry non-KV state shapes. Engines fall
    back to the two-dispatch step loop for unsupported families.
    """
    return cfg.family in (Family.DENSE, Family.MOE)


def decode_fused(cfg, params, tokens, kv_caches, cache_len, active,
                 positions, budget, stop_ids, temperature, top_k, top_p,
                 seeds, **kw):
    """K fused decode+sample steps over dense KV in one dispatch
    (``lm.decode_fused``); see ``_fused_decode_scan`` for semantics."""
    if not supports_fused(cfg):
        raise NotImplementedError(
            f"fused decode unsupported for family {cfg.family}")
    return lm.decode_fused(cfg, params, tokens, kv_caches, cache_len,
                           active, positions, budget, stop_ids,
                           temperature, top_k, top_p, seeds, **kw)


def decode_fused_paged(cfg, params, tokens, kv_pages, page_table,
                       cache_len, active, positions, budget, stop_ids,
                       temperature, top_k, top_p, seeds, **kw):
    """K fused decode+sample steps over paged KV in one dispatch."""
    if not (supports_fused(cfg) and supports_paged(cfg)):
        raise NotImplementedError(
            f"fused paged decode unsupported for family {cfg.family}")
    return lm.decode_fused_paged(cfg, params, tokens, kv_pages,
                                 page_table, cache_len, active,
                                 positions, budget, stop_ids,
                                 temperature, top_k, top_p, seeds, **kw)


# --------------------------------------------- speculative draft–verify
def supports_spec_draft(cfg: ModelConfig) -> bool:
    """Families usable as the *draft* model of speculative decoding.

    The draft runs chained single-token ``lm.decode_step``s on a dense
    KV slab inside the speculative scan, so only dense decoder LMs
    qualify for now (MoE drafting is pointless — the draft should be
    cheap; SSM/hybrid carry non-KV state the scan does not thread).
    The *target* additionally needs ``supports_fused``.
    """
    return cfg.family == Family.DENSE


def verify(cfg, params, tokens, kv_caches, cache_len, **kw):
    """Multi-token target forward over dense KV returning all-position
    logits (B, S, V) — the verify half of speculative decoding."""
    if not supports_fused(cfg):
        raise NotImplementedError(
            f"verify forward unsupported for family {cfg.family}")
    return lm.verify(cfg, params, tokens, kv_caches, cache_len, **kw)


def verify_paged(cfg, params, tokens, kv_pages, page_table, cache_len,
                 **kw):
    """Multi-token target forward over paged KV (all-position logits)."""
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged verify unsupported for family {cfg.family}")
    return lm.verify_paged(cfg, params, tokens, kv_pages, page_table,
                           cache_len, **kw)


def decode_spec_fused(cfg, params, draft_cfg, draft_params, tokens,
                      kv_caches, draft_kv, cache_len, active, positions,
                      budget, stop_ids, temperature, top_k, top_p, seeds,
                      **kw):
    """Fused speculative decode over dense KV: draft–verify rounds with
    on-device acceptance (``lm._spec_decode_scan``)."""
    if not supports_fused(cfg):
        raise NotImplementedError(
            f"speculative decode unsupported for target family "
            f"{cfg.family}")
    if not supports_spec_draft(draft_cfg):
        raise NotImplementedError(
            f"speculative draft unsupported for family {draft_cfg.family}")
    return lm.decode_spec_fused(cfg, params, draft_cfg, draft_params,
                                tokens, kv_caches, draft_kv, cache_len,
                                active, positions, budget, stop_ids,
                                temperature, top_k, top_p, seeds, **kw)


def decode_spec_fused_paged(cfg, params, draft_cfg, draft_params, tokens,
                            kv_pages, page_table, draft_kv, cache_len,
                            active, positions, budget, stop_ids,
                            temperature, top_k, top_p, seeds, **kw):
    """Fused speculative decode with the target on paged KV."""
    if not (supports_fused(cfg) and supports_paged(cfg)):
        raise NotImplementedError(
            f"speculative paged decode unsupported for target family "
            f"{cfg.family}")
    if not supports_spec_draft(draft_cfg):
        raise NotImplementedError(
            f"speculative draft unsupported for family {draft_cfg.family}")
    return lm.decode_spec_fused_paged(cfg, params, draft_cfg,
                                      draft_params, tokens, kv_pages,
                                      page_table, draft_kv, cache_len,
                                      active, positions, budget, stop_ids,
                                      temperature, top_k, top_p, seeds,
                                      **kw)


# ------------------------------------------------------- paged serving
def supports_paged(cfg: ModelConfig) -> bool:
    """Families whose decode can run over a paged KV pool.

    Dense decoder LMs only for now: MoE decode shares the dense KV path
    but scans supersteps (paged xs plumbing not wired), VLM needs M-RoPE
    positions, SSM/hybrid/enc-dec carry non-KV state. Engines fall back
    to the dense slab for unsupported families.
    """
    return cfg.family == Family.DENSE


def init_paged_serve_state(cfg: ModelConfig, n_pages: int, page_size: int,
                           dtype=jnp.bfloat16):
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged KV unsupported for family {cfg.family}")
    return lm.make_paged_kv(cfg, n_pages, page_size, dtype)


def decode_step_paged(cfg, params, tokens, kv_pages, page_table,
                      cache_len, **kw):
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged KV unsupported for family {cfg.family}")
    return lm.decode_step_paged(cfg, params, tokens, kv_pages,
                                page_table, cache_len, **kw)


def prefill_paged(cfg, params, tokens, kv_pages, page_table, start,
                  seq_len, **kw):
    """Suffix prefill into paged KV (prefix-cache hits skip the cached
    prefix; see lm.prefill_paged). Paged families only."""
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged KV unsupported for family {cfg.family}")
    return lm.prefill_paged(cfg, params, tokens, kv_pages, page_table,
                            start, seq_len, **kw)


def init_serve_state(cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16):
    if cfg.family == Family.SSM:
        return ssm_lm.init_serve_state(cfg, batch, dtype)
    if cfg.family == Family.HYBRID:
        return hybrid.init_serve_state(cfg, batch, max_len, dtype)
    if cfg.family == Family.ENCDEC:
        k = jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                       cfg.head_dim), dtype)
        kx = jnp.zeros((cfg.n_layers, batch, cfg.enc_ctx, cfg.n_kv_heads,
                        cfg.head_dim), dtype)
        return ((k, k), (kx, kx))
    return lm.make_kv_caches(cfg, batch, max_len, dtype)
