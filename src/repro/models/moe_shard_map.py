"""shard_map MoE: explicit all-to-all expert parallelism (§Perf cell B).

The einsum-dispatch MoE (moe.py) leaves GSPMD to discover the expert
all-to-all; measured on qwen3-moe prefill it instead emits ~13 GB/layer
of gathers+permutes. This layer takes explicit control:

  per data-shard (shard_map over the expert axis):
    1. route local tokens (global expert ids);
    2. bucket per (group, expert) with group-local capacity
       C = Tg·top_k·cf/E — one-hots stay (G_l, Tg, E, C), ~1 GB;
    3. `jax.lax.all_to_all` sends each expert's buckets to its home
       shard; 4. local expert FFN (E/n_shards experts resident);
    5. inverse all_to_all; local weighted combine.

Wire per layer = 2 × bucket bytes ≈ 2·T_l·k·cf·D·2B — the information-
theoretic minimum for einsum-style expert dispatch.

Used for inference (prefill/decode-prefill paths) when a mesh context
is active and E divides the expert axis; training keeps the einsum path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .moe import MOE_GROUP, _capacity, route


def _group_buckets(xg, idx, weights, E, cap, dtype):
    """xg: (G,Tg,D); idx/weights: (G,Tg,K). -> (send (G,E,C,D), comb)."""
    G, Tg, K = idx.shape
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)         # (G,Tg,K,E)
    pos = (jnp.cumsum(onehot.reshape(G, Tg * K, E), axis=1)
           .reshape(G, Tg, K, E) - 1)
    pos = jnp.sum(pos * onehot, axis=-1)                     # (G,Tg,K)
    keep = pos < cap
    w = weights * keep
    pos_c = jnp.minimum(pos, cap - 1)
    disp = (jax.nn.one_hot(idx, E, dtype=dtype)[..., None]
            * jax.nn.one_hot(pos_c, cap, dtype=dtype)[..., None, :])
    disp = disp * keep[..., None, None].astype(dtype)
    comb = jnp.sum(disp * w[..., None, None].astype(dtype), axis=2)
    disp = jnp.sum(disp, axis=2)                             # (G,Tg,E,C)
    send = jnp.einsum("gtd,gtec->gecd", xg, disp)
    return send, comb


def moe_block_a2a(x: jax.Array, router_w: jax.Array,
                  w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
                  top_k: int, capacity_factor: float,
                  mesh, expert_axis: str = "data",
                  group_size: int = MOE_GROUP,
                  ) -> tuple[jax.Array, jax.Array]:
    """x: (B,S,D) batch-sharded on ``expert_axis``; weights E-sharded.

    Requires B % n_shards == 0 and E % n_shards == 0.
    Returns (out (B,S,D), aux scalar)."""
    from jax.experimental.shard_map import shard_map

    E = router_w.shape[-1]
    n_shards = mesh.shape[expert_axis]
    assert E % n_shards == 0, (E, n_shards)
    E_l = E // n_shards

    def shard_fn(xs, rw, wg, wu, wd):
        Bl, S, D = xs.shape
        weights, idx, aux = route(xs, rw, top_k)
        T = Bl * S
        Tg = min(group_size, T)
        G = T // Tg
        cap = _capacity(Tg, E, top_k, capacity_factor)
        send, comb = _group_buckets(
            xs.reshape(G, Tg, D), idx.reshape(G, Tg, top_k),
            weights.reshape(G, Tg, top_k), E, cap, xs.dtype)
        # (G,E,C,D) -> a2a over experts' home shards. Global expert
        # e = (s, e_l) with s = e // E_l.
        send = send.reshape(G, n_shards, E_l, cap, D)
        recv = jax.lax.all_to_all(send, expert_axis, split_axis=1,
                                  concat_axis=0, tiled=False)
        # recv: (n_shards, G, E_l, C, D) — every shard's buckets for my
        # E_l experts; treat source shards as extra groups.
        h_in = recv.reshape(n_shards * G, E_l, cap, D)
        g = jnp.einsum("gecd,edf->gecf", h_in, wg)
        u = jnp.einsum("gecd,edf->gecf", h_in, wu)
        out = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, wd)
        out = out.reshape(n_shards, G, E_l, cap, D)
        back = jax.lax.all_to_all(out, expert_axis, split_axis=0,
                                  concat_axis=1, tiled=False)
        # back: (G, n_shards, E_l, C, D) -> (G, E, C, D).
        expert_out = back.reshape(G, E, cap, D)
        y = jnp.einsum("gecd,gtec->gtd", expert_out, comb)
        return (y.reshape(Bl, S, D),
                jax.lax.pmean(aux, expert_axis))

    out, aux = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(expert_axis, None, None), P(None, None),
                  P(expert_axis, None, None),
                  P(expert_axis, None, None),
                  P(expert_axis, None, None)),
        out_specs=(P(expert_axis, None, None), P()),
        check_rep=False,
    )(x, router_w, w_gate, w_up, w_down)
    return out, aux
