"""Decoder-only LM assembly: dense / MoE / VLM families.

Parameters are a *flat* dict {path: array} (see base.param_shapes) with
per-layer tensors stacked on axis 0 — the layer loop is a single
``lax.scan`` whose xs are the stacked stacks, keeping the HLO small
(one layer body regardless of depth) and making remat policy uniform.
MoE-interleaved models (llama4: moe_every=2) scan over supersteps of
(dense layer, MoE layer) pairs.

Three entry points per model, all pure functions of (cfg, params, ...):

- ``forward``      full-sequence logits (training / evaluation)
- ``prefill``      logits for the last position + per-layer KV caches
- ``decode_step``  one token against padded KV caches (+ optional
                   multi-adapter LoRA via per-request adapter indices —
                   the paper's serving data plane)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .base import Family, ModelConfig, param_shapes
from .layers import (apply_rope, cross_entropy, decode_attention, embed,
                     gqa_attention, head_rms_norm, mrope_cos_sin,
                     gelu_mlp, rms_norm, rope_cos_sin, suffix_attention,
                     swiglu, unembed)
from .lora_apply import lora_delta
from repro.core.sampling import (SPEC_ACCEPT_FOLD, SPEC_DRAFT_FOLD,
                                 SPEC_RESIDUAL_FOLD)
from repro.distributed.act_sharding import (constrain_attn_merged,
                                            constrain_btd,
                                            constrain_boundary,
                                            constrain_logits,
                                            constrain_residual,
                                            constrain_expert_ecd)
from .moe import moe_block, moe_block_gather


# ----------------------------------------------------------------- init
def init_params(cfg: ModelConfig, key: jax.Array,
                dtype=jnp.bfloat16) -> dict[str, jax.Array]:
    shapes = param_shapes(cfg)
    params: dict[str, jax.Array] = {}
    keys = jax.random.split(key, len(shapes))
    for (path, shape), k in zip(sorted(shapes.items()), keys):
        leaf = path.split("/")[-1]
        if "norm" in leaf:
            params[path] = jnp.ones(shape, dtype)
        elif leaf in ("A_log",):
            # S4D-real init: A in [1, d_state] (mamba1) / [1, 16] (mamba2).
            hi = 16.0
            params[path] = jnp.log(jax.random.uniform(
                k, shape, jnp.float32, 1.0, hi))
        elif leaf in ("ssm_D",):
            params[path] = jnp.ones(shape, jnp.float32)
        elif leaf in ("dt_bias",):
            # Bias such that softplus(dt_bias) spans [1e-3, 1e-1].
            u = jax.random.uniform(k, shape, jnp.float32, 1e-3, 1e-1)
            params[path] = jnp.log(jnp.expm1(u))
        elif leaf.endswith("_bias") or leaf in ("conv_b",):
            params[path] = jnp.zeros(shape, dtype)
        elif leaf in ("enc_pos", "dec_pos"):
            params[path] = (0.02 * jax.random.normal(k, shape)).astype(dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = fan_in ** -0.5
            params[path] = (std * jax.random.normal(k, shape)).astype(dtype)
    return params


# ------------------------------------------------------------ attention
def _qkv_proj(cfg: ModelConfig, x: jax.Array, p: dict, cos, sin,
              lora=None, adapter_idx=None, prefix: str = "",
              lora_backend: str = "einsum"):
    """Normed q/k/v projections (+bias, +LoRA, +qk-norm, +RoPE).

    Shared by the dense and paged attention blocks so the two data
    planes are numerically the same computation up to the KV layout.
    Returns (h, q, k, v) with q (B,S,H,Dh), k/v (B,S,Kh,Dh); ``h`` is the
    post-norm hidden the o-projection's residual pairs with.
    """
    B, S, _ = x.shape
    h = rms_norm(x, p[prefix + "attn_norm"], cfg.norm_eps)

    def proj(name):
        y = jnp.einsum("bsd,de->bse", h, p[prefix + name])
        if cfg.qkv_bias and prefix + name + "_bias" in p:
            y = y + p[prefix + name + "_bias"]
        if lora is not None and name in lora:
            y = y + lora_delta(h, lora[name], adapter_idx,
                               backend=lora_backend)
        return y

    q = proj("q").reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = proj("k").reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = proj("v").reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = head_rms_norm(q, p[prefix + "q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p[prefix + "k_norm"], cfg.norm_eps)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return h, q, k, v


def _o_proj(cfg: ModelConfig, x: jax.Array, out: jax.Array, p: dict,
            lora=None, adapter_idx=None, prefix: str = "",
            lora_backend: str = "einsum") -> jax.Array:
    """Output projection + LoRA + residual. out: (B, S, q_dim)."""
    out = constrain_attn_merged(out)
    o = jnp.einsum("bse,ed->bsd", out, p[prefix + "o"])
    if lora is not None and "o" in lora:
        o = o + lora_delta(out, lora["o"], adapter_idx,
                           backend=lora_backend)
    return constrain_residual(x + o)


def _attn(cfg: ModelConfig, x: jax.Array, p: dict, cos, sin,
          kv_cache=None, cache_len=None, lora=None, adapter_idx=None,
          prefix: str = "", lora_backend: str = "einsum"):
    """Shared attention block.

    Returns (out, new_kv): new_kv is (k, v) for prefill or the updated
    (k_cache, v_cache, ) slices for decode.
    """
    B, S, _ = x.shape
    _, q, k, v = _qkv_proj(cfg, x, p, cos, sin, lora, adapter_idx, prefix,
                           lora_backend)

    if kv_cache is None:
        out = gqa_attention(q, k, v, causal=True)
        new_kv = (k, v)
    else:
        k_cache, v_cache = kv_cache
        # Scatter the new entries at cache_len (decode: S == 1).
        idx = jnp.reshape(cache_len, (B, 1)) + jnp.arange(S)[None]
        bidx = jnp.arange(B)[:, None] + jnp.zeros_like(idx)
        k_cache = k_cache.at[bidx, idx].set(k)
        v_cache = v_cache.at[bidx, idx].set(v)
        out = decode_attention(q, k_cache, v_cache,
                               cache_len + S)
        new_kv = (k_cache, v_cache)

    out = out.reshape(B, S, cfg.q_dim)
    return _o_proj(cfg, x, out, p, lora, adapter_idx, prefix,
                   lora_backend), new_kv


def _attn_paged(cfg: ModelConfig, x: jax.Array, p: dict, cos, sin,
                k_pages: jax.Array, v_pages: jax.Array,
                page_table: jax.Array, cache_len: jax.Array,
                page_idx: jax.Array, page_off: jax.Array,
                lora=None, adapter_idx=None,
                lora_backend: str = "einsum"):
    """Decode attention over paged KV (one layer; S == 1).

    k/v_pages: (n_pages, page, Kh, Dh); page_table: (B, P) physical page
    ids per request; page_idx/page_off: (B,) precomputed write position
    of the new token (page_table[b, cache_len[b]//page], cache_len[b] %
    page). The fresh K/V is scattered into the pages, then attention
    runs over the request's page list via ``kernels.ops.paged_attention``
    (Pallas on TPU, the jnp reference on CPU). Requests never share
    pages, so the batched scatter cannot collide (inactive slots all
    write the reserved trash page 0, which active tables never map).
    """
    from repro.kernels.ops import paged_attention

    B, S, _ = x.shape
    _, q, k, v = _qkv_proj(cfg, x, p, cos, sin, lora, adapter_idx,
                           lora_backend=lora_backend)
    k_pages = k_pages.at[page_idx, page_off].set(k[:, 0])
    v_pages = v_pages.at[page_idx, page_off].set(v[:, 0])
    Kh, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qh = q[:, 0].reshape(B, Kh, G, cfg.head_dim)
    out = paged_attention(qh, k_pages, v_pages, page_table,
                          cache_len + 1)
    out = out.reshape(B, S, cfg.q_dim)
    return _o_proj(cfg, x, out, p, lora, adapter_idx,
                   lora_backend=lora_backend), (k_pages, v_pages)


def _mlp(cfg, x, p, prefix=""):
    h = rms_norm(x, p[prefix + "mlp_norm"], cfg.norm_eps)
    if not cfg.gated_mlp:
        return x + gelu_mlp(h, p[prefix + "up"], p[prefix + "down"])
    return x + swiglu(h, p[prefix + "gate"], p[prefix + "up"],
                      p[prefix + "down"])


def _moe(cfg, x, p):
    from repro.distributed.act_sharding import moe_a2a_mesh
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    a2a = moe_a2a_mesh()
    B, S, _ = x.shape
    # a2a MoE for distributed inference: cuts MoE decode collective
    # 235-560x vs the expert-gather path (whose jnp.take of expert
    # weights is itself the HBM bill); both paths carry heavy per-layer
    # transient buffers on MoE decode (§Perf cell B, iter 4 caveat).
    if a2a is not None:
        mesh, axis = a2a
        ns = mesh.shape[axis]
        if cfg.n_experts % ns == 0 and B % ns == 0:
            from .moe_shard_map import moe_block_a2a
            # Decode: dropless capacity (cf = E/k makes cap == Tg).
            cf = (cfg.n_experts / cfg.top_k if S == 1
                  else cfg.capacity_factor)
            y, aux = moe_block_a2a(h, p["router"], p["w_gate"],
                                   p["w_up"], p["w_down"], cfg.top_k,
                                   cf, mesh, expert_axis=axis)
            if cfg.shared_expert_ff:
                y = y + swiglu(h, p["shared_gate"], p["shared_up"],
                               p["shared_down"])
            return x + y, aux
    if S == 1:
        # Decode: dropless expert-gather (see moe.moe_block_gather).
        y, aux = moe_block_gather(h, p["router"], p["w_gate"],
                                  p["w_up"], p["w_down"], cfg.top_k)
    else:
        y, aux = moe_block(h, p["router"], p["w_gate"], p["w_up"],
                           p["w_down"], cfg.top_k, cfg.capacity_factor)
    if cfg.shared_expert_ff:
        y = y + swiglu(h, p["shared_gate"], p["shared_up"],
                       p["shared_down"])
    return x + y, aux


# ----------------------------------------------------- stacked param views
def _slice_group(params: dict, prefix: str) -> dict:
    n = len(prefix)
    return {k[n:]: v for k, v in params.items() if k.startswith(prefix)}


def _positions(cfg: ModelConfig, tokens_shape, offset, mrope_pos):
    B, S = tokens_shape
    if cfg.mrope:
        assert mrope_pos is not None, "VLM needs (3,B,S) M-RoPE positions"
        return mrope_cos_sin(mrope_pos, cfg.head_dim, cfg.rope_theta,
                             cfg.mrope_sections)
    pos = jnp.arange(S)[None, :] + jnp.reshape(offset, (-1, 1))
    return rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)


# ------------------------------------------------------------- backbone
def _backbone(cfg: ModelConfig, params: dict, x: jax.Array, cos, sin,
              kv_caches=None, cache_len=None, lora=None, adapter_idx=None,
              collect_kv=False, lora_backend: str = "einsum"):
    """Scan over layers. Returns (hidden, new_kv_stack, aux_loss).

    ``collect_kv`` stacks per-layer fresh K/V (prefill). Training leaves
    it False so the scan carries no dead 100-GB KV output to rely on
    DCE for.
    """
    attn_stack = _slice_group(params, "layers/")
    lora_stack = lora  # {proj: (L, slots, din, r) & (L, slots, r, dout)}

    if cfg.family == Family.MOE:
        return _backbone_moe(cfg, params, x, cos, sin, kv_caches,
                             cache_len, lora, adapter_idx, collect_kv,
                             lora_backend)

    def body(carry, xs):
        h = constrain_boundary(carry)
        p = xs["p"]
        kv = (xs["k"], xs["v"]) if kv_caches is not None else None
        lr = xs.get("lora")
        h, new_kv = _attn(cfg, h, p, cos, sin, kv, cache_len, lr,
                          adapter_idx, lora_backend=lora_backend)
        h = constrain_boundary(_mlp(cfg, h, p))
        if kv_caches is None and not collect_kv:
            new_kv = None
        return h, new_kv

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = {"p": attn_stack}
    if kv_caches is not None:
        xs["k"], xs["v"] = kv_caches
    if lora_stack is not None:
        xs["lora"] = lora_stack
    h, kv_out = jax.lax.scan(body, x, xs)
    return h, kv_out, jnp.float32(0)


def _backbone_moe(cfg, params, x, cos, sin, kv_caches, cache_len,
                  lora, adapter_idx, collect_kv=False,
                  lora_backend: str = "einsum"):
    """MoE scan; supersteps of (moe_every) layers, last one MoE."""
    E = cfg.moe_every
    L = cfg.n_layers
    n_super = L // E
    attn_stack = _slice_group(params, "layers/")
    attn_stack = {k: v.reshape((n_super, E) + v.shape[1:])
                  for k, v in attn_stack.items()}
    moe_stack = _slice_group(params, "moe/")
    dense_stack = _slice_group(params, "dense_mlp/")
    if dense_stack:
        n_dense_per = E - 1
        dense_stack = {k: v.reshape((n_super, n_dense_per) + v.shape[1:])
                       for k, v in dense_stack.items()}

    def body(carry, xs):
        h, aux = carry
        h = constrain_boundary(h)
        new_kv = []
        for e in range(E):
            p_attn = {k: v[e] for k, v in xs["attn"].items()}
            kv = ((xs["k"][e], xs["v"][e])
                  if kv_caches is not None else None)
            lr = ({proj: (ab[0][e], ab[1][e])
                   for proj, ab in xs["lora"].items()}
                  if lora is not None else None)
            h, kv_e = _attn(cfg, h, p_attn, cos, sin, kv, cache_len,
                            lr, adapter_idx, lora_backend=lora_backend)
            new_kv.append(kv_e)
            if e == E - 1:
                h, a = _moe(cfg, h, xs["moe"])
                h = constrain_btd(h)
                aux = aux + a
            else:
                p_d = {k: v[e] for k, v in xs["dense"].items()}
                h = _mlp(cfg, h, p_d)
        want_kv = kv_caches is not None or collect_kv
        k_out = (jnp.stack([kv[0] for kv in new_kv]) if want_kv
                 else jnp.float32(0))
        v_out = (jnp.stack([kv[1] for kv in new_kv]) if want_kv
                 else jnp.float32(0))
        return (h, aux), (k_out, v_out)

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = {"attn": attn_stack, "moe": moe_stack}
    if dense_stack:
        xs["dense"] = dense_stack
    if kv_caches is not None:
        k, v = kv_caches
        xs["k"] = k.reshape((n_super, E) + k.shape[1:])
        xs["v"] = v.reshape((n_super, E) + v.shape[1:])
    if lora is not None:
        xs["lora"] = {proj: (a.reshape((n_super, E) + a.shape[1:]),
                             b.reshape((n_super, E) + b.shape[1:]))
                      for proj, (a, b) in lora.items()}
    (h, aux), kv_out = jax.lax.scan(body, (x, jnp.float32(0)), xs)
    if kv_caches is not None or collect_kv:
        k_out, v_out = kv_out
        k_out = k_out.reshape((L,) + k_out.shape[2:])
        v_out = v_out.reshape((L,) + v_out.shape[2:])
        kv_out = (k_out, v_out)
    return h, kv_out, aux


# ----------------------------------------------------------- entry points
def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            mrope_pos: jax.Array | None = None) -> jax.Array:
    """Full-sequence logits (B, S, V)."""
    x = constrain_btd(embed(tokens, params["embed/tok"]))
    cos, sin = _positions(cfg, tokens.shape, 0, mrope_pos)
    h, _, _aux = _backbone(cfg, params, x, cos, sin)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = (params["embed/tok"].T if cfg.tie_embeddings
             else params["lm_head"])
    return constrain_logits(unembed(h, table))


def train_loss(cfg: ModelConfig, params: dict, tokens: jax.Array,
               labels: jax.Array, mrope_pos=None,
               aux_weight: float = 0.01) -> jax.Array:
    x = constrain_btd(embed(tokens, params["embed/tok"]))
    cos, sin = _positions(cfg, tokens.shape, 0, mrope_pos)
    h, _, aux = _backbone(cfg, params, x, cos, sin)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = (params["embed/tok"].T if cfg.tie_embeddings
             else params["lm_head"])
    logits = constrain_logits(unembed(h, table))
    return cross_entropy(logits, labels) + aux_weight * aux


def make_kv_caches(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def make_paged_kv(cfg: ModelConfig, n_pages: int, page_size: int,
                  dtype=jnp.bfloat16):
    """Paged KV pool: (L, n_pages, page, Kh, Dh) ×2.

    Page 0 is reserved as the trash page inactive batch slots write
    into; allocators hand out pages 1..n_pages-1 (engine convention).
    """
    shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads,
             cfg.head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


# ------------------------------------------------------------- sampling
def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array, seeds: jax.Array,
                  positions: jax.Array) -> jax.Array:
    """Batched next-token sampler, one row per batch slot (jit-safe).

    logits (B, V) float; temperature/top_p (B,) float; top_k (B,) int
    (0 = disabled); seeds (B,) uint32-ish int; positions (B,) int token
    index being sampled. Rows with ``temperature <= 0`` are greedy
    argmax — bit-identical to the pre-SamplingParams engine. Stochastic
    rows draw Gumbel noise from ``fold_in(PRNGKey(seed), position)``,
    so a token's randomness depends only on (seed, position): the same
    request resamples identically across batch compositions, dense vs
    paged KV, einsum vs kernel LoRA backends, and squash re-execution.

    top-k keeps the k best logits; top-p keeps the smallest sorted
    prefix whose cumulative probability reaches top_p (the best token
    always survives both masks).
    """
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    # Rank every vocab entry within its row (0 = best).
    order = jnp.argsort(scaled, axis=-1)[:, ::-1]
    ranks = jnp.argsort(order, axis=-1)
    keep_k = ranks < jnp.where(top_k > 0, top_k, V)[:, None]
    # Nucleus: keep entries whose *preceding* cumulative mass < top_p.
    probs = jax.nn.softmax(scaled, axis=-1)
    sorted_p = jnp.take_along_axis(probs, order, axis=-1)
    cum = jnp.cumsum(sorted_p, axis=-1)
    keep_p_sorted = (cum - sorted_p) < top_p[:, None]
    keep_p = jnp.take_along_axis(keep_p_sorted, ranks, axis=-1)
    masked = jnp.where(keep_k & keep_p, scaled, -jnp.inf)
    keys = jax.vmap(lambda s, p: jax.random.fold_in(
        jax.random.PRNGKey(s), p))(seeds.astype(jnp.uint32),
                                   positions.astype(jnp.uint32))
    gumbel = jax.vmap(lambda k: jax.random.gumbel(k, (V,)))(keys)
    sampled = jnp.argmax(masked + gumbel, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)


# ------------------------------------------------- fused decode hot loop
def _fused_decode_scan(decode_one, tokens, cache_len, active, positions,
                       kv, budget, stop_ids, temperature, top_k, top_p,
                       seeds, max_ctx: int, n_steps: int,
                       all_greedy: bool):
    """Run ``n_steps`` decode+sample+advance iterations on device.

    One ``lax.scan`` whose body is: decode one token for every batch
    slot, pick the next token (argmax when the whole batch is greedy —
    bit-identical to the two-dispatch engine loop — else the seeded
    batched sampler), advance ``cache_len``/``positions`` for active
    rows, and fold the per-row finish conditions into an on-device
    done-mask so a row that hits its budget / a stop token / the
    context bound stops emitting *inside* the horizon. The host syncs
    one ``(n_steps, B)`` token block + emit-mask instead of one (B, V)
    logits round-trip per token.

    Semantics mirror the single-step engine loop exactly (the parity
    suite asserts token-identity): ``tokens`` is overwritten for every
    row including inactive ones, ``cache_len`` advances by the *pre*-
    done-check active mask, and rows past their end keep decoding
    masked garbage whose emissions are dropped via the emit mask.

    decode_one: (tokens (B,1), kv, cache_len) -> (logits (B,V), kv').
    Returns ((tokens', kv', cache_len', active', positions'),
             toks (n_steps, B) int32, emits (n_steps, B) bool).
    """

    def body(carry, _):
        tokens, kv, cache_len, active, positions = carry
        logits, kv = decode_one(tokens, kv, cache_len)
        if all_greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = sample_tokens(logits, temperature, top_k, top_p,
                                seeds, positions)
        emit = active
        step = active.astype(jnp.int32)
        new_len = cache_len + step
        new_pos = positions + step
        # Finish conditions, verbatim from the engine bookkeeping:
        # budget exhausted (req.done), a SamplingParams stop id, or the
        # context bound generated + input_len >= max_len - 1 — with
        # cache_len == input_len + generated - 1, that is new_len + 1.
        hit_stop = (nxt[:, None] == stop_ids).any(axis=-1)
        done_now = emit & ((new_pos >= budget) | hit_stop
                           | (new_len + 1 >= max_ctx - 1))
        carry = (nxt[:, None], kv, new_len, emit & ~done_now, new_pos)
        return carry, (nxt, emit)

    init = (tokens, kv, cache_len, active, positions)
    carry, (toks, emits) = jax.lax.scan(body, init, None, length=n_steps)
    return carry, toks, emits


def decode_fused(cfg: ModelConfig, params: dict, tokens: jax.Array,
                 kv_caches, cache_len: jax.Array, active: jax.Array,
                 positions: jax.Array, budget: jax.Array,
                 stop_ids: jax.Array, temperature: jax.Array,
                 top_k: jax.Array, top_p: jax.Array, seeds: jax.Array,
                 *, n_steps: int, all_greedy: bool, max_ctx: int,
                 lora=None, adapter_idx=None,
                 lora_backend: str = "einsum"):
    """Fused multi-step decode over the dense KV slab (see
    ``_fused_decode_scan``). active (B,) bool; positions (B,) the
    output index each row samples next; budget (B,) max output tokens;
    stop_ids (B, n_stop) int32 padded with -1 (n_stop may be 0)."""

    def decode_one(tok, kv, clen):
        return decode_step(cfg, params, tok, kv, clen, lora=lora,
                           adapter_idx=adapter_idx,
                           lora_backend=lora_backend)

    return _fused_decode_scan(decode_one, tokens, cache_len, active,
                              positions, kv_caches, budget, stop_ids,
                              temperature, top_k, top_p, seeds, max_ctx,
                              n_steps, all_greedy)


def decode_fused_paged(cfg: ModelConfig, params: dict, tokens: jax.Array,
                       kv_pages, page_table: jax.Array,
                       cache_len: jax.Array, active: jax.Array,
                       positions: jax.Array, budget: jax.Array,
                       stop_ids: jax.Array, temperature: jax.Array,
                       top_k: jax.Array, top_p: jax.Array,
                       seeds: jax.Array, *, n_steps: int,
                       all_greedy: bool, max_ctx: int, lora=None,
                       adapter_idx=None, lora_backend: str = "einsum"):
    """Fused multi-step decode over the paged KV pool. The page table
    is read-only across the horizon: the engine pre-allocates pages
    covering every write the scan can make, so ``cache_len // page``
    always lands on a mapped page (done rows keep overwriting the slot
    one past their final token, which attention masks by length)."""

    def decode_one(tok, kv, clen):
        return decode_step_paged(cfg, params, tok, kv, page_table, clen,
                                 lora=lora, adapter_idx=adapter_idx,
                                 lora_backend=lora_backend)

    return _fused_decode_scan(decode_one, tokens, cache_len, active,
                              positions, kv_pages, budget, stop_ids,
                              temperature, top_k, top_p, seeds, max_ctx,
                              n_steps, all_greedy)


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            mrope_pos=None, lora=None, adapter_idx=None, last_pos=None,
            lora_backend: str = "einsum"):
    """Returns (last-position logits (B, V), (k_stack, v_stack)).

    ``last_pos`` (B,) selects the position whose logits are returned —
    needed for right-padded prefill batches (defaults to S-1).
    ``lora_backend="kernel"`` routes the LoRA deltas through the Pallas
    sgmv kernel (each request's row is one contiguous token run).
    """
    x = constrain_btd(embed(tokens, params["embed/tok"]))
    cos, sin = _positions(cfg, tokens.shape, 0, mrope_pos)
    h, kv, _ = _backbone(cfg, params, x, cos, sin, lora=lora,
                         adapter_idx=adapter_idx, collect_kv=True,
                         lora_backend=lora_backend)
    if last_pos is None:
        h_last = h[:, -1:]
    else:
        h_last = jnp.take_along_axis(
            h, jnp.reshape(last_pos, (-1, 1, 1)).astype(jnp.int32), axis=1)
    h_last = rms_norm(h_last, params["final_norm"], cfg.norm_eps)
    table = (params["embed/tok"].T if cfg.tie_embeddings
             else params["lm_head"])
    return constrain_logits(unembed(h_last, table)[:, 0]), kv


def decode_step(cfg: ModelConfig, params: dict, tokens: jax.Array,
                kv_caches, cache_len: jax.Array, mrope_pos=None,
                lora=None, adapter_idx=None,
                lora_backend: str = "einsum"):
    """One decode step.

    tokens: (B, 1); kv_caches: (k, v) each (L, B, Smax, Kh, Dh);
    cache_len: (B,) valid lengths. Returns (logits (B,V), new caches).
    ``lora_backend="kernel"`` routes the per-token LoRA deltas through
    the Pallas bgmv kernel.
    """
    x = constrain_btd(embed(tokens, params["embed/tok"]))
    if cfg.mrope:
        cos, sin = _positions(cfg, tokens.shape, cache_len, mrope_pos)
    else:
        cos, sin = _positions(cfg, tokens.shape, cache_len, None)
    h, kv, _ = _backbone(cfg, params, x, cos, sin, kv_caches=kv_caches,
                         cache_len=cache_len, lora=lora,
                         adapter_idx=adapter_idx,
                         lora_backend=lora_backend)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = (params["embed/tok"].T if cfg.tie_embeddings
             else params["lm_head"])
    return constrain_logits(unembed(h, table)[:, 0]), kv


def decode_step_paged(cfg: ModelConfig, params: dict, tokens: jax.Array,
                      kv_pages, page_table: jax.Array,
                      cache_len: jax.Array, lora=None, adapter_idx=None,
                      lora_backend: str = "einsum"):
    """One decode step over a paged KV pool (dense-family scan).

    tokens: (B, 1); kv_pages: (k_pages, v_pages) each (L, n_pages,
    page, Kh, Dh); page_table: (B, P) physical page ids per request;
    cache_len: (B,) valid lengths. Returns (logits (B, V), new kv_pages).

    The same ``lax.scan`` layer loop as ``decode_step``; only the KV
    residency differs — fixed-size pages indirected through the page
    table instead of a dense (B, max_len) slab, so HBM holds exactly the
    pages requests allocated (DESIGN §2).
    """
    x = constrain_btd(embed(tokens, params["embed/tok"]))
    cos, sin = _positions(cfg, tokens.shape, cache_len, None)
    k_pages, v_pages = kv_pages
    page = k_pages.shape[2]
    B = tokens.shape[0]
    # Write position of the new token, shared across layers.
    page_idx = page_table[jnp.arange(B), cache_len // page]
    page_off = cache_len % page
    attn_stack = _slice_group(params, "layers/")

    def body(carry, xs):
        h = constrain_boundary(carry)
        p = xs["p"]
        lr = xs.get("lora")
        h, (kp, vp) = _attn_paged(cfg, h, p, cos, sin, xs["kp"],
                                  xs["vp"], page_table, cache_len,
                                  page_idx, page_off, lr, adapter_idx,
                                  lora_backend)
        h = constrain_boundary(_mlp(cfg, h, p))
        return h, (kp, vp)

    xs = {"p": attn_stack, "kp": k_pages, "vp": v_pages}
    if lora is not None:
        xs["lora"] = lora
    h, (k_out, v_out) = jax.lax.scan(body, x, xs)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = (params["embed/tok"].T if cfg.tie_embeddings
             else params["lm_head"])
    return constrain_logits(unembed(h, table)[:, 0]), (k_out, v_out)


def prefill_paged(cfg: ModelConfig, params: dict, tokens: jax.Array,
                  kv_pages, page_table: jax.Array, start: jax.Array,
                  seq_len: jax.Array, lora=None, adapter_idx=None,
                  lora_backend: str = "einsum"):
    """Suffix prefill straight into paged KV (prefix-cache data plane).

    tokens: (B, S) right-padded *suffix* token ids — the part of each
    prompt not covered by cached prefix pages; start: (B,) absolute
    position of tokens[:, 0] (== the cached prefix length, 0 on a cache
    miss); seq_len: (B,) valid suffix lengths (>= 1 — the engine caps
    prefix matches at L-1 so the last prompt position always prefills);
    page_table: (B, P) physical pages covering positions 0..start+S-1,
    with cached-prefix pages mapped read-only by convention (suffix
    positions land in the request's private pages, so the scatter never
    writes a shared page).

    Per layer: project the suffix q/k/v (RoPE at the absolute offset),
    scatter K/V into the pages at positions start..start+seq_len-1
    (padding redirected to trash page 0), gather the request's whole
    page list back to (B, P*page, Kh, Dh), and run offset-causal
    attention over it — the cached prefix participates as keys without
    being recomputed. Returns (last-valid-position logits (B, V),
    kv_pages'). On a miss row (start == 0) this computes exactly what
    ``prefill`` + the host page scatter produced, so one code path
    serves hits and misses.
    """
    B, S = tokens.shape
    x = constrain_btd(embed(tokens, params["embed/tok"]))
    cos, sin = _positions(cfg, tokens.shape, start, None)
    k_pages, v_pages = kv_pages
    page = k_pages.shape[2]
    P = page_table.shape[1]
    pos = start[:, None] + jnp.arange(S)[None, :]            # (B, S) abs
    valid = jnp.arange(S)[None, :] < seq_len[:, None]        # (B, S)
    page_idx = jnp.take_along_axis(page_table, pos // page, axis=1)
    page_idx = jnp.where(valid, page_idx, 0)                 # pad → trash
    page_off = pos % page
    attn_stack = _slice_group(params, "layers/")

    def body(carry, xs):
        h0 = constrain_boundary(carry)
        p = xs["p"]
        lr = xs.get("lora")
        _, q, k, v = _qkv_proj(cfg, h0, p, cos, sin, lr, adapter_idx,
                               lora_backend=lora_backend)
        kp = xs["kp"].at[page_idx, page_off].set(k)
        vp = xs["vp"].at[page_idx, page_off].set(v)
        kf = kp[page_table].reshape(B, P * page, cfg.n_kv_heads,
                                    cfg.head_dim)
        vf = vp[page_table].reshape(B, P * page, cfg.n_kv_heads,
                                    cfg.head_dim)
        out = suffix_attention(q, kf, vf, pos)
        out = out.reshape(B, S, cfg.q_dim)
        h0 = _o_proj(cfg, h0, out, p, lr, adapter_idx,
                     lora_backend=lora_backend)
        h0 = constrain_boundary(_mlp(cfg, h0, p))
        return h0, (kp, vp)

    xs = {"p": attn_stack, "kp": k_pages, "vp": v_pages}
    if lora is not None:
        xs["lora"] = lora
    h, (k_out, v_out) = jax.lax.scan(body, x, xs)
    h_last = jnp.take_along_axis(
        h, jnp.reshape(seq_len - 1, (-1, 1, 1)).astype(jnp.int32), axis=1)
    h_last = rms_norm(h_last, params["final_norm"], cfg.norm_eps)
    table = (params["embed/tok"].T if cfg.tie_embeddings
             else params["lm_head"])
    return constrain_logits(unembed(h_last, table)[:, 0]), (k_out, v_out)


# ---------------------------------------- speculative decoding (draft–verify)
def verify(cfg: ModelConfig, params: dict, tokens: jax.Array,
           kv_caches, cache_len: jax.Array, seq_len: jax.Array | None = None,
           lora=None, adapter_idx=None, lora_backend: str = "einsum"):
    """Multi-token target forward over the dense KV slab.

    The verify half of speculative decoding: score ``S`` already-chosen
    tokens per row in one dispatch, returning logits for *every*
    position (``prefill``/``prefill_paged`` keep only the last). Row
    ``b``'s tokens sit at absolute positions ``cache_len[b] ..
    cache_len[b]+S-1``; their K/V is scattered into the slab at those
    positions (the same per-row-offset scatter ``_attn`` does for S==1)
    and attention runs offset-causal via ``suffix_attention``, so
    position j attends exactly the keys the single-step decode path
    would see — numerics match ``decode_step`` per position.

    tokens: (B, S); kv_caches: (k, v) each (L, B, Smax, Kh, Dh);
    cache_len: (B,) valid lengths; seq_len: optional (B,) valid token
    counts (< S positions are right-padding: their K/V writes are
    dropped and their logits are garbage the caller ignores — used for
    the draft-KV catch-up path). Returns (logits (B, S, V), kv').
    """
    B, S = tokens.shape
    x = constrain_btd(embed(tokens, params["embed/tok"]))
    cos, sin = _positions(cfg, tokens.shape, cache_len, None)
    pos = cache_len[:, None] + jnp.arange(S)[None, :]        # (B, S) abs
    Smax = kv_caches[0].shape[2]
    valid = pos < Smax
    if seq_len is not None:
        valid = valid & (jnp.arange(S)[None, :] < seq_len[:, None])
    idx = jnp.where(valid, pos, Smax)                        # OOB → dropped
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S))
    attn_stack = _slice_group(params, "layers/")

    def body(carry, xs):
        h0 = constrain_boundary(carry)
        p = xs["p"]
        lr = xs.get("lora")
        _, q, k, v = _qkv_proj(cfg, h0, p, cos, sin, lr, adapter_idx,
                               lora_backend=lora_backend)
        kc = xs["k"].at[bidx, idx].set(k, mode="drop")
        vc = xs["v"].at[bidx, idx].set(v, mode="drop")
        out = suffix_attention(q, kc, vc, pos)
        out = out.reshape(B, S, cfg.q_dim)
        h0 = _o_proj(cfg, h0, out, p, lr, adapter_idx,
                     lora_backend=lora_backend)
        h0 = constrain_boundary(_mlp(cfg, h0, p))
        return h0, (kc, vc)

    xs = {"p": attn_stack, "k": kv_caches[0], "v": kv_caches[1]}
    if lora is not None:
        xs["lora"] = lora
    h, (k_out, v_out) = jax.lax.scan(body, x, xs)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = (params["embed/tok"].T if cfg.tie_embeddings
             else params["lm_head"])
    return constrain_logits(unembed(h, table)), (k_out, v_out)


def verify_paged(cfg: ModelConfig, params: dict, tokens: jax.Array,
                 kv_pages, page_table: jax.Array, cache_len: jax.Array,
                 seq_len: jax.Array | None = None, lora=None,
                 adapter_idx=None, lora_backend: str = "einsum"):
    """Multi-token target forward over paged KV — ``verify`` with the
    ``prefill_paged`` page-table scatter/gather: K/V lands in the
    request's private pages at positions ``cache_len..cache_len+S-1``
    (invalid/overflow positions redirect to trash page 0), the whole
    page list is gathered back and ``suffix_attention`` applies the
    per-row offset-causal mask. Returns all-position logits
    (B, S, V) + kv_pages'."""
    B, S = tokens.shape
    x = constrain_btd(embed(tokens, params["embed/tok"]))
    cos, sin = _positions(cfg, tokens.shape, cache_len, None)
    k_pages, v_pages = kv_pages
    page = k_pages.shape[2]
    P = page_table.shape[1]
    pos = cache_len[:, None] + jnp.arange(S)[None, :]        # (B, S) abs
    valid = pos < P * page
    if seq_len is not None:
        valid = valid & (jnp.arange(S)[None, :] < seq_len[:, None])
    page_idx = jnp.take_along_axis(page_table,
                                   jnp.minimum(pos // page, P - 1), axis=1)
    page_idx = jnp.where(valid, page_idx, 0)                 # pad → trash
    page_off = pos % page
    attn_stack = _slice_group(params, "layers/")

    def body(carry, xs):
        h0 = constrain_boundary(carry)
        p = xs["p"]
        lr = xs.get("lora")
        _, q, k, v = _qkv_proj(cfg, h0, p, cos, sin, lr, adapter_idx,
                               lora_backend=lora_backend)
        kp = xs["kp"].at[page_idx, page_off].set(k)
        vp = xs["vp"].at[page_idx, page_off].set(v)
        kf = kp[page_table].reshape(B, P * page, cfg.n_kv_heads,
                                    cfg.head_dim)
        vf = vp[page_table].reshape(B, P * page, cfg.n_kv_heads,
                                    cfg.head_dim)
        out = suffix_attention(q, kf, vf, pos)
        out = out.reshape(B, S, cfg.q_dim)
        h0 = _o_proj(cfg, h0, out, p, lr, adapter_idx,
                     lora_backend=lora_backend)
        h0 = constrain_boundary(_mlp(cfg, h0, p))
        return h0, (kp, vp)

    xs = {"p": attn_stack, "kp": k_pages, "vp": v_pages}
    if lora is not None:
        xs["lora"] = lora
    h, (k_out, v_out) = jax.lax.scan(body, x, xs)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    table = (params["embed/tok"].T if cfg.tie_embeddings
             else params["lm_head"])
    return constrain_logits(unembed(h, table)), (k_out, v_out)


def _spec_keys(seeds, positions, fold: int):
    """(seed, position, stream) keys — ``sample_tokens``' base key with
    the spec stream tag folded in. positions (B,) or (B, S)."""
    def one(s, p):
        k = jax.random.fold_in(jax.random.PRNGKey(s), p)
        return jax.random.fold_in(k, fold)
    if positions.ndim == 1:
        return jax.vmap(one)(seeds.astype(jnp.uint32),
                             positions.astype(jnp.uint32))
    return jax.vmap(jax.vmap(one, in_axes=(None, 0)))(
        seeds.astype(jnp.uint32), positions.astype(jnp.uint32))


def _spec_filtered(logits, temperature, top_k, top_p):
    """temperature/top-k/top-p masking identical to ``sample_tokens``,
    plus the renormalized probabilities of the kept set (what the
    rejection rule needs). logits (..., V); params (...) leading-shaped.
    Returns (masked scaled logits, filtered probs)."""
    V = logits.shape[-1]
    scaled = logits / jnp.maximum(temperature, 1e-6)[..., None]
    order = jnp.argsort(scaled, axis=-1)[..., ::-1]
    ranks = jnp.argsort(order, axis=-1)
    keep_k = ranks < jnp.where(top_k > 0, top_k, V)[..., None]
    probs = jax.nn.softmax(scaled, axis=-1)
    sorted_p = jnp.take_along_axis(probs, order, axis=-1)
    cum = jnp.cumsum(sorted_p, axis=-1)
    keep_p_sorted = (cum - sorted_p) < top_p[..., None]
    keep_p = jnp.take_along_axis(keep_p_sorted, ranks, axis=-1)
    masked = jnp.where(keep_k & keep_p, scaled, -jnp.inf)
    return masked, jax.nn.softmax(masked, axis=-1)


def _draft_propose(logits, temperature, top_k, top_p, seeds, positions):
    """Draft proposal for one chained draft step: greedy rows take the
    draft argmax, stochastic rows Gumbel-sample the filtered draft
    distribution from the SPEC_DRAFT stream. Returns (tokens (B,),
    filtered draft probs (B, V) — the ``q`` of the rejection rule)."""
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    masked, qprobs = _spec_filtered(logits, temperature, top_k, top_p)
    keys = _spec_keys(seeds, positions, SPEC_DRAFT_FOLD)
    gumbel = jax.vmap(lambda k: jax.random.gumbel(k, (V,)))(keys)
    sampled = jnp.argmax(masked + gumbel, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy), qprobs


def _spec_decode_scan(draft_one, verify_multi, tokens, cache_len, active,
                      positions, kv, draft_kv, budget, stop_ids,
                      temperature, top_k, top_p, seeds, max_ctx: int,
                      n_rounds: int, spec_k: int, all_greedy: bool):
    """Fused draft–verify decode: ``n_rounds`` speculative rounds on
    device, emitting up to ``spec_k + 1`` tokens per row per round.

    Each round, per row: (1) the draft model runs ``spec_k + 1`` chained
    single-token steps on its own KV (one past the last proposal so the
    draft cache holds every accepted token's entry when all drafts
    land), proposing ``d_1..d_spec_k``; (2) the target scores
    ``[t0, d_1..d_spec_k]`` in ONE multi-token ``verify`` dispatch,
    writing target KV for all spec_k+1 positions; (3) the accept mask,
    correction/bonus token, and per-row cache_len rollback are computed
    on device — logits never leave the device. Greedy rows accept
    ``d_j`` iff it equals the target argmax given the accepted prefix,
    and every emitted token *is* a target argmax, so greedy output is
    bit-identical to ``_fused_decode_scan``; stochastic rows use
    rejection sampling (accept w.p. ``min(1, p/q)``, resample the
    residual ``max(p-q, 0)`` on reject) with every draw keyed on
    (seed, position) spec streams, so replay/squash re-execution is
    deterministic and each emitted token is exactly target-distributed.

    Rollback: both caches advance by the per-row emitted count only —
    entries written past it (rejected drafts) are garbage that the next
    round's writes at the same positions overwrite before attention can
    see them (the same argument the non-spec loop makes for done rows).
    Per-token finish semantics (budget / stop id / context bound) are
    replayed emission-by-emission inside the round, verbatim from
    ``_fused_decode_scan``, so a row that finishes mid-round stops
    emitting at the identical token.

    draft_one: (tokens (B,1), draft_kv, clen) -> (logits (B,V), kv').
    verify_multi: (tokens (B,S), kv, clen) -> (logits (B,S,V), kv').
    Returns ((tokens', kv', draft_kv', cache_len', active', positions'),
             toks (n_rounds*(spec_k+1), B), emits (same), n_acc
             (n_rounds, B) accepted-draft counts for the meter).
    """
    K = spec_k
    B = tokens.shape[0]

    def round_body(carry, _):
        tokens, kv, dkv, cache_len, active, positions = carry

        def dstep(dc, j):
            tok, dkv = dc
            dlogits, dkv = draft_one(tok, dkv, cache_len + j)
            if all_greedy:
                nxt = jnp.argmax(dlogits, axis=-1).astype(jnp.int32)
                out = (nxt,)
            else:
                nxt, qp = _draft_propose(dlogits, temperature, top_k,
                                         top_p, seeds, positions + j)
                out = (nxt, qp)
            return (nxt[:, None], dkv), out

        (_, dkv), douts = jax.lax.scan(dstep, (tokens, dkv),
                                       jnp.arange(K + 1))
        d_check = douts[0][:K].T                 # (B, K) = d_1..d_K
        vt = jnp.concatenate([tokens, d_check], axis=1)      # (B, K+1)
        vlogits, kv = verify_multi(vt, kv, cache_len)        # (B, K+1, V)

        tgt = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)
        acc = d_check == tgt[:, :K]              # greedy accept (B, K)
        if not all_greedy:
            qprobs = jnp.swapaxes(douts[1][:K], 0, 1)        # (B, K, V)
            _, pprobs = _spec_filtered(
                vlogits[:, :K],
                jnp.broadcast_to(temperature[:, None], (B, K)),
                jnp.broadcast_to(top_k[:, None], (B, K)),
                jnp.broadcast_to(top_p[:, None], (B, K)))
            p_d = jnp.take_along_axis(pprobs, d_check[..., None],
                                      axis=-1)[..., 0]
            q_d = jnp.take_along_axis(qprobs, d_check[..., None],
                                      axis=-1)[..., 0]
            pos_mat = positions[:, None] + jnp.arange(K)[None, :]
            u = jax.vmap(jax.vmap(jax.random.uniform))(
                _spec_keys(seeds, pos_mat, SPEC_ACCEPT_FOLD))
            # u < min(1, p/q)  ⇔  u*q < p (q > 0 on the proposal support)
            acc_s = u * jnp.maximum(q_d, 1e-30) < p_d
            acc = jnp.where((temperature > 0.0)[:, None], acc_s, acc)
        n_acc = jnp.cumprod(acc.astype(jnp.int32), axis=1).sum(axis=1)

        # Correction (first reject) / bonus (all accepted) token.
        corr = tgt[jnp.arange(B), n_acc]         # argmax(L_n)
        if not all_greedy:
            V = vlogits.shape[-1]
            resid = jnp.maximum(pprobs - qprobs, 0.0)
            rsum = resid.sum(axis=-1, keepdims=True)
            resid = jnp.where(rsum > 0, resid / jnp.maximum(rsum, 1e-30),
                              pprobs)
            rg = jax.vmap(jax.vmap(lambda k: jax.random.gumbel(k, (V,))))(
                _spec_keys(seeds, pos_mat, SPEC_RESIDUAL_FOLD))
            resid_tok = jnp.argmax(
                jnp.log(jnp.maximum(resid, 1e-38)) + rg,
                axis=-1).astype(jnp.int32)       # (B, K)
            bonus = sample_tokens(vlogits[:, K], temperature, top_k,
                                  top_p, seeds, positions + K)
            corr_s = jnp.where(
                n_acc < K,
                resid_tok[jnp.arange(B), jnp.minimum(n_acc, K - 1)], bonus)
            corr = jnp.where(temperature > 0.0, corr_s, corr)

        # Emission slot j holds d_{j+1} while accepted, else the
        # correction/bonus at slot n_acc (slots past it are masked).
        jj = jnp.arange(K + 1)[None, :]
        e = jnp.where(jj < n_acc[:, None],
                      jnp.pad(d_check, ((0, 0), (0, 1))), corr[:, None])

        # Per-emission finish conditions, verbatim from
        # _fused_decode_scan's done-mask (budget / stop / context).
        m = active
        toks_list, emits_list = [], []
        for j in range(K + 1):
            ej = e[:, j]
            emit_j = m & (jj[0, j] <= n_acc)
            new_pos = positions + j + 1
            new_len = cache_len + j + 1
            hit_stop = (ej[:, None] == stop_ids).any(axis=-1)
            done_j = emit_j & ((new_pos >= budget) | hit_stop
                               | (new_len + 1 >= max_ctx - 1))
            m = m & ~done_j
            toks_list.append(ej)
            emits_list.append(emit_j)
        toks_r = jnp.stack(toks_list)            # (K+1, B)
        emits_r = jnp.stack(emits_list)          # (K+1, B)
        cnt = emits_r.astype(jnp.int32).sum(axis=0)
        new_tok = e[jnp.arange(B), jnp.maximum(cnt - 1, 0)]
        tokens = jnp.where(cnt > 0, new_tok, tokens[:, 0])[:, None]
        carry = (tokens, kv, dkv, cache_len + cnt, m, positions + cnt)
        return carry, (toks_r, emits_r, jnp.where(active, n_acc, 0))

    init = (tokens, kv, draft_kv, cache_len, active, positions)
    carry, (toks, emits, accs) = jax.lax.scan(round_body, init, None,
                                              length=n_rounds)
    # Flatten rounds × emission slots to the step-major (n, B) block
    # the engine drain walks, like the non-spec scan's output.
    toks = toks.reshape(n_rounds * (K + 1), B)
    emits = emits.reshape(n_rounds * (K + 1), B)
    return carry, toks, emits, accs


def decode_spec_fused(cfg: ModelConfig, params: dict,
                      draft_cfg: ModelConfig, draft_params: dict,
                      tokens: jax.Array, kv_caches, draft_kv,
                      cache_len: jax.Array, active: jax.Array,
                      positions: jax.Array, budget: jax.Array,
                      stop_ids: jax.Array, temperature: jax.Array,
                      top_k: jax.Array, top_p: jax.Array,
                      seeds: jax.Array, *, spec_k: int, n_rounds: int,
                      all_greedy: bool, max_ctx: int, lora=None,
                      adapter_idx=None, lora_backend: str = "einsum"):
    """Speculative fused decode over the dense KV slab: base-weights
    draft (no LoRA — the adapters ride along at verify time only),
    multi-token target ``verify``, on-device accept/rollback."""

    def draft_one(tok, dkv, clen):
        return decode_step(draft_cfg, draft_params, tok, dkv, clen)

    def verify_multi(toks, kv, clen):
        return verify(cfg, params, toks, kv, clen, lora=lora,
                      adapter_idx=adapter_idx, lora_backend=lora_backend)

    return _spec_decode_scan(draft_one, verify_multi, tokens, cache_len,
                             active, positions, kv_caches, draft_kv,
                             budget, stop_ids, temperature, top_k, top_p,
                             seeds, max_ctx, n_rounds, spec_k, all_greedy)


def decode_spec_fused_paged(cfg: ModelConfig, params: dict,
                            draft_cfg: ModelConfig, draft_params: dict,
                            tokens: jax.Array, kv_pages,
                            page_table: jax.Array, draft_kv,
                            cache_len: jax.Array, active: jax.Array,
                            positions: jax.Array, budget: jax.Array,
                            stop_ids: jax.Array, temperature: jax.Array,
                            top_k: jax.Array, top_p: jax.Array,
                            seeds: jax.Array, *, spec_k: int,
                            n_rounds: int, all_greedy: bool, max_ctx: int,
                            lora=None, adapter_idx=None,
                            lora_backend: str = "einsum"):
    """Speculative fused decode with the target on paged KV (the draft
    keeps a dense slab — it is small and adapter-free). The engine
    pre-allocates pages covering every write a round can make
    (``cache_len + spec_k + 1``) and shrinks back after readback."""

    def draft_one(tok, dkv, clen):
        return decode_step(draft_cfg, draft_params, tok, dkv, clen)

    def verify_multi(toks, kv, clen):
        return verify_paged(cfg, params, toks, kv, page_table, clen,
                            lora=lora, adapter_idx=adapter_idx,
                            lora_backend=lora_backend)

    return _spec_decode_scan(draft_one, verify_multi, tokens, cache_len,
                             active, positions, kv_pages, draft_kv,
                             budget, stop_ids, temperature, top_k, top_p,
                             seeds, max_ctx, n_rounds, spec_k, all_greedy)
