"""Model configuration shared by all assigned architectures.

One dataclass covers dense / MoE / SSM / hybrid / enc-dec / VLM families;
family-specific fields are ignored elsewhere. Configs are constructed in
``repro.configs.<arch>`` and consumed by ``repro.models.lm`` (decoder-only
assembly), ``repro.models.hybrid`` (zamba2), ``repro.models.encdec``
(whisper).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace
from typing import Optional


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    ENCDEC = "encdec"       # audio backbone (whisper): enc-dec transformer
    VLM = "vlm"             # vision backbone (qwen2-vl): M-RoPE decoder


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    vocab_size: int
    # Attention (unused for attn-free SSM).
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope: bool = False              # 3-axis multimodal RoPE (qwen2-vl)
    mrope_sections: tuple = (16, 24, 24)   # t/h/w split of head_dim/2
    # MLP.
    d_ff: int = 0
    gated_mlp: bool = True   # False: GPT-BigCode-style GELU up/down
    # MoE.
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    shared_expert_ff: int = 0
    moe_every: int = 1               # 2 => MoE on odd layers (llama4)
    capacity_factor: float = 1.25
    # SSM (mamba).
    ssm_version: int = 0             # 1 = mamba1, 2 = mamba2
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64           # mamba2
    dt_rank: int = 0                 # mamba1 (0 => ceil(d_model/16))
    ssm_chunk: int = 128             # mamba2 SSD chunk length
    # Hybrid (zamba2): one *shared* attention+MLP block applied every
    # ``attn_every`` mamba layers.
    attn_every: int = 0
    # Enc-dec (whisper).
    n_enc_layers: int = 0
    enc_ctx: int = 1500              # stubbed audio frames
    # Misc.
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 32768
    # Execution knobs.
    scan_layers: bool = True
    remat: bool = True
    # LoRA serving.
    lora_ranks: tuple = (8, 16, 32, 64, 128)
    lora_target: tuple = ("q", "k", "v", "o")

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)
        if self.ssm_version == 1 and not self.dt_rank:
            object.__setattr__(self, "dt_rank",
                               -(-self.d_model // 16))

    @property
    def attn_free(self) -> bool:
        return self.family == Family.SSM

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.family != Family.MOE:
            return False
        return (layer_idx % self.moe_every) == (self.moe_every - 1)

    # Parameter counting (documentation + roofline MODEL_FLOPS).
    def param_count(self) -> int:
        return sum(int(np.prod(s)) for s in _param_shapes(self).values())

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE counts top_k experts only)."""
        total = self.param_count()
        if self.family != Family.MOE or not self.n_experts:
            return total
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.is_moe_layer(i))
        per_expert = 3 * self.d_model * self.d_ff_expert
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return total - inactive

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        shrink = dict(
            n_layers=min(self.n_layers, 4 if self.attn_every == 0 else 6),
            d_model=128,
            vocab_size=512,
            d_ff=256 if self.d_ff else 0,
            max_seq_len=256,
            rope_theta=1e4,
            scan_layers=self.scan_layers,
            remat=False,
        )
        if self.n_heads:
            shrink.update(n_heads=4, head_dim=32,
                          n_kv_heads=max(1, min(self.n_kv_heads, 2)))
            if self.mrope:
                # Rescale t/h/w frequency sections to the reduced
                # head_dim/2 while keeping the 2:3:3 ratio.
                half = 16
                shrink.update(mrope_sections=(
                    half * 2 // 8, half * 3 // 8, half * 3 // 8))
        if self.n_experts:
            shrink.update(n_experts=8, top_k=min(self.top_k, 2),
                          d_ff_expert=64,
                          shared_expert_ff=64 if self.shared_expert_ff else 0)
        if self.ssm_version:
            shrink.update(d_state=16, ssm_head_dim=32, ssm_chunk=32)
        if self.n_enc_layers:
            shrink.update(n_enc_layers=2, enc_ctx=16)
        if self.attn_every:
            shrink.update(attn_every=3)
        shrink.update(overrides)
        return replace(self, **shrink)


import numpy as np  # noqa: E402  (used by param_count)


def _param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    """Flat {path: shape} map — single source of truth for init/sharding."""
    D, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    shapes: dict[str, tuple] = {"embed/tok": (V, D), "final_norm": (D,)}
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (D, V)

    def attn_shapes(prefix: str):
        s = {
            f"{prefix}attn_norm": (D,),
            f"{prefix}q": (D, cfg.q_dim),
            f"{prefix}k": (D, cfg.kv_dim),
            f"{prefix}v": (D, cfg.kv_dim),
            f"{prefix}o": (cfg.q_dim, D),
        }
        if cfg.qkv_bias:
            s[f"{prefix}q_bias"] = (cfg.q_dim,)
            s[f"{prefix}k_bias"] = (cfg.kv_dim,)
            s[f"{prefix}v_bias"] = (cfg.kv_dim,)
        if cfg.qk_norm:
            s[f"{prefix}q_norm"] = (cfg.head_dim,)
            s[f"{prefix}k_norm"] = (cfg.head_dim,)
        return s

    def mlp_shapes(prefix: str, ff: int):
        s = {f"{prefix}mlp_norm": (D,),
             f"{prefix}up": (D, ff),
             f"{prefix}down": (ff, D)}
        if cfg.gated_mlp:
            s[f"{prefix}gate"] = (D, ff)
        return s

    def ssm_shapes(prefix: str):
        Di, N = cfg.d_inner, cfg.d_state
        if cfg.ssm_version == 1:
            return {f"{prefix}ssm_norm": (D,),
                    f"{prefix}in_proj": (D, 2 * Di),
                    f"{prefix}conv_w": (cfg.d_conv, Di),
                    f"{prefix}conv_b": (Di,),
                    f"{prefix}x_proj": (Di, cfg.dt_rank + 2 * N),
                    f"{prefix}dt_proj": (cfg.dt_rank, Di),
                    f"{prefix}dt_bias": (Di,),
                    f"{prefix}A_log": (Di, N),
                    f"{prefix}ssm_D": (Di,),
                    f"{prefix}out_proj": (Di, D)}
        H = cfg.n_ssm_heads
        conv_dim = Di + 2 * N          # x, B, C all convolved (mamba2)
        return {f"{prefix}ssm_norm": (D,),
                f"{prefix}in_proj": (D, 2 * Di + 2 * N + H),
                f"{prefix}conv_w": (cfg.d_conv, conv_dim),
                f"{prefix}conv_b": (conv_dim,),
                f"{prefix}dt_bias": (H,),
                f"{prefix}A_log": (H,),
                f"{prefix}ssm_D": (H,),
                f"{prefix}gate_norm": (Di,),
                f"{prefix}out_proj": (Di, D)}

    if cfg.family == Family.SSM:
        for k, v in ssm_shapes("layers/").items():
            shapes[k] = (L,) + v
        return shapes

    if cfg.family == Family.HYBRID:
        for k, v in ssm_shapes("layers/").items():
            shapes[k] = (L,) + v
        # One *shared* attention+MLP block (zamba2).
        shapes.update(attn_shapes("shared/"))
        shapes.update(mlp_shapes("shared/", cfg.d_ff))
        return shapes

    if cfg.family == Family.ENCDEC:
        Le = cfg.n_enc_layers
        for k, v in attn_shapes("enc/").items():
            shapes[k] = (Le,) + v
        for k, v in mlp_shapes("enc/", cfg.d_ff).items():
            shapes[k] = (Le,) + v
        shapes["enc_final_norm"] = (D,)
        shapes["enc_pos"] = (cfg.enc_ctx, D)
        for k, v in attn_shapes("dec/").items():
            shapes[k] = (L,) + v
        for k, v in attn_shapes("dec/x").items():     # cross-attention
            shapes[k] = (L,) + v
        for k, v in mlp_shapes("dec/", cfg.d_ff).items():
            shapes[k] = (L,) + v
        shapes["dec_pos"] = (cfg.max_seq_len, D)
        return shapes

    # Dense / MoE / VLM decoder-only.
    for k, v in attn_shapes("layers/").items():
        shapes[k] = (L,) + v
    if cfg.family == Family.MOE:
        n_moe = sum(1 for i in range(L) if cfg.is_moe_layer(i))
        n_dense = L - n_moe
        Fe, E = cfg.d_ff_expert, cfg.n_experts
        shapes["moe/router"] = (n_moe, D, E)
        shapes["moe/norm"] = (n_moe, D)
        shapes["moe/w_gate"] = (n_moe, E, D, Fe)
        shapes["moe/w_up"] = (n_moe, E, D, Fe)
        shapes["moe/w_down"] = (n_moe, E, Fe, D)
        if cfg.shared_expert_ff:
            shapes["moe/shared_gate"] = (n_moe, D, cfg.shared_expert_ff)
            shapes["moe/shared_up"] = (n_moe, D, cfg.shared_expert_ff)
            shapes["moe/shared_down"] = (n_moe, cfg.shared_expert_ff, D)
        if n_dense:
            for k, v in mlp_shapes("dense_mlp/", cfg.d_ff).items():
                shapes[k] = (n_dense,) + v
    else:
        for k, v in mlp_shapes("layers/", cfg.d_ff).items():
            shapes[k] = (L,) + v
    return shapes


def param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    return _param_shapes(cfg)
