"""State-space mixers: Mamba1 (falcon-mamba) and Mamba2 (zamba2).

Both reduce to an elementwise linear recurrence over time:

    h_t = decay_t * h_{t-1} + u_t

computed with a *chunked* scan: a sequential ``lax.scan`` over chunks of
``chunk`` steps carrying the state, with a parallel
``associative_scan`` inside each chunk. This bounds the materialised
(B, chunk, *state) tensors (the full-T associative scan would need
O(T·d_inner·d_state) memory — infeasible at 32k/500k context) while
keeping per-chunk parallelism for the TPU vector units. The fully
sequential form (chunk=1) and the SSD matmul form are kept in
kernels/ref.py as oracles.

Decode is the O(1) single-step update — the reason the long_500k shape
is assigned to these families.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import (constrain_ssm_bth,
                                            constrain_ssm_bthp,
                                            constrain_ssm_channels)


# ------------------------------------------------------- linear recurrence
def _assoc_combine(a, b):
    """(d, u) elements; b is later in time."""
    return a[0] * b[0], a[1] * b[0] + b[1]


def chunked_linear_recurrence(decay: jax.Array, u: jax.Array,
                              h0: jax.Array, chunk: int,
                              ) -> tuple[jax.Array, jax.Array]:
    """h_t = decay_t * h_{t-1} + u_t for t in [0, T).

    decay, u: (B, T, *S); h0: (B, *S). Returns (h (B,T,*S), h_T).
    T is padded up to a multiple of ``chunk`` internally.
    """
    B, T = u.shape[0], u.shape[1]
    state_shape = u.shape[2:]
    pad = (-T) % chunk
    if pad:
        decay = jnp.pad(decay, [(0, 0), (0, pad)] + [(0, 0)] * len(state_shape),
                        constant_values=1.0)
        u = jnp.pad(u, [(0, 0), (0, pad)] + [(0, 0)] * len(state_shape))
    n_chunks = (T + pad) // chunk
    d_c = decay.reshape((B, n_chunks, chunk) + state_shape)
    u_c = u.reshape((B, n_chunks, chunk) + state_shape)
    # scan over chunks (time-major for scan axis 0).
    d_c = jnp.moveaxis(d_c, 1, 0)
    u_c = jnp.moveaxis(u_c, 1, 0)

    def step(h, inputs):
        d, uu = inputs                                   # (B, chunk, *S)
        dd, uu_acc = jax.lax.associative_scan(
            _assoc_combine, (d, uu), axis=1)
        h_all = dd * h[:, None] + uu_acc                 # (B, chunk, *S)
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(step, h0, (d_c, u_c))
    h_seq = jnp.moveaxis(h_chunks, 0, 1).reshape(
        (B, n_chunks * chunk) + state_shape)
    return h_seq[:, :T], h_last


def _mamba1_fused_scan(dt, A, xc, B_ssm, C_ssm, h0, chunk):
    """Selective scan with chunk-local materialisation.

    dt/xc: (B,T,Di); A: (Di,N); B/C: (B,T,N); h0: (B,Di,N).
    Returns (y (B,T,Di), h_T). decay/u exist only at (B,chunk,Di,N);
    the chunk body is rematted (backward recomputes them).
    """
    B, T, Di = dt.shape
    N = A.shape[-1]
    pad = (-T) % chunk
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        B_ssm = jnp.pad(B_ssm, ((0, 0), (0, pad), (0, 0)))
        C_ssm = jnp.pad(C_ssm, ((0, 0), (0, pad), (0, 0)))
    nc = (T + pad) // chunk

    def c(a):
        return jnp.moveaxis(
            a.reshape((B, nc, chunk) + a.shape[2:]), 1, 0)

    def step(h, inp):
        dt_c, x_c, b_c, c_c = inp            # (B,chunk,...)
        decay = jnp.exp(dt_c[..., None] * A)             # (B,Q,Di,N)
        u = (dt_c * x_c)[..., None] * b_c[:, :, None, :]
        dd, uu = jax.lax.associative_scan(_assoc_combine, (decay, u),
                                          axis=1)
        h_all = dd * h[:, None] + uu
        y_c = jnp.einsum("bqin,bqn->bqi", h_all, c_c)
        return h_all[:, -1], y_c

    step = jax.checkpoint(step)
    h_last, y = jax.lax.scan(step, h0, (c(dt), c(xc), c(B_ssm), c(C_ssm)))
    y = jnp.moveaxis(y, 0, 1).reshape(B, T + pad, Di)[:, :T]
    return y, h_last


# ------------------------------------------------------------- conv1d
def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,C); w: (K,C); b: (C,)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, k:k + x.shape[1], :] * w[k] for k in range(K))
    return y + b


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Decode-time conv. x_t: (B,C); conv_state: (B,K-1,C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return y, window[:, 1:, :]


# ------------------------------------------------------------- mamba1
def mamba1_seq(x: jax.Array, p: dict, d_state: int, dt_rank: int,
               chunk: int = 128,
               h0: jax.Array | None = None,
               conv_state: jax.Array | None = None,
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence Mamba1 mixer.

    x: (B,S,D) — already normed. Returns (y (B,S,D), h_T, conv_tail).
    """
    B, S, _ = x.shape
    xz = constrain_ssm_channels(
        jnp.einsum("bsd,de->bse", x, p["in_proj"]))
    x_in, z = jnp.split(xz, 2, axis=-1)                  # (B,S,Di)
    if conv_state is not None:
        x_cat = jnp.concatenate([conv_state, x_in], axis=1)
        x_conv = causal_conv1d(x_cat, p["conv_w"], p["conv_b"])[
            :, conv_state.shape[1]:]
    else:
        x_conv = causal_conv1d(x_in, p["conv_w"], p["conv_b"])
    x_conv = constrain_ssm_channels(jax.nn.silu(x_conv))
    # Tail of raw conv inputs, handed to decode as its conv_state.
    conv_tail = x_in[:, -(p["conv_w"].shape[0] - 1):, :]

    dbc = jnp.einsum("bsi,ie->bse", x_conv, p["x_proj"])
    dt_raw = dbc[..., :dt_rank]
    B_ssm = dbc[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    C_ssm = dbc[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_raw, p["dt_proj"])
        + p["dt_bias"]).astype(jnp.float32)             # (B,S,Di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # (Di,N)
    if h0 is None:
        h0 = jnp.zeros((B, A.shape[0], d_state), jnp.float32)
    # Chunk-fused selective scan: decay/u are built *inside* the chunk
    # loop so the (B,S,Di,N) f32 tensors never materialise at full
    # sequence length (that cost ~98 GB/device on train_4k); the chunk
    # body is rematted so backward recomputes instead of stacking.
    y = _mamba1_fused_scan(dt, A, x_conv.astype(jnp.float32),
                           B_ssm, C_ssm, h0, chunk)
    y, h_last = y
    y = y + x_conv.astype(jnp.float32) * p["ssm_D"]
    y = constrain_ssm_channels(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype))
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, h_last, conv_tail


def mamba1_step(x_t: jax.Array, p: dict, d_state: int, dt_rank: int,
                h: jax.Array, conv_state: jax.Array,
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Decode step. x_t: (B,D); h: (B,Di,N); conv_state: (B,K-1,Di)."""
    xz = jnp.einsum("bd,de->be", x_t, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_state = conv1d_step(x_in, conv_state, p["conv_w"],
                                     p["conv_b"])
    x_conv = jax.nn.silu(x_conv)
    dbc = jnp.einsum("bi,ie->be", x_conv, p["x_proj"])
    dt_raw = dbc[..., :dt_rank]
    B_ssm = dbc[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    C_ssm = dbc[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(jnp.einsum("br,ri->bi", dt_raw, p["dt_proj"])
                         + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt[..., None] * A)                  # (B,Di,N)
    u = (dt * x_conv.astype(jnp.float32))[..., None] * B_ssm[:, None, :]
    h = decay * h + u
    y = jnp.einsum("bin,bn->bi", h, C_ssm)
    y = y + x_conv.astype(jnp.float32) * p["ssm_D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    return jnp.einsum("bi,id->bd", y, p["out_proj"]), h, conv_state


# ------------------------------------------------------------- mamba2
def _mamba2_split(zxbcdt: jax.Array, d_inner: int, d_state: int,
                  n_heads: int):
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner:2 * d_inner]
    B_ssm = zxbcdt[..., 2 * d_inner:2 * d_inner + d_state]
    C_ssm = zxbcdt[..., 2 * d_inner + d_state:2 * d_inner + 2 * d_state]
    dt = zxbcdt[..., 2 * d_inner + 2 * d_state:]
    return z, x, B_ssm, C_ssm, dt


def ssd_chunked(xh: jax.Array, dt: jax.Array, A: jax.Array,
                B_ssm: jax.Array, C_ssm: jax.Array, chunk: int,
                h0: jax.Array | None = None,
                ) -> tuple[jax.Array, jax.Array]:
    """Mamba2 SSD algorithm (matmul/chunked form — MXU-native).

    xh: (B,T,H,P); dt: (B,T,H); A: (H,) negative; B/C: (B,T,N).
    Returns (y (B,T,H,P), h_T (B,H,P,N)).

    Never materialises per-timestep states: within each chunk of Q
    steps the output is an attention-like (Q×Q) masked matmul; across
    chunks only the (B,H,P,N) boundary states flow through a scan.
    Memory: O(B·T·Q·H + B·nc·H·P·N) instead of O(B·T·H·P·N) — the
    difference between 0.1 GB and 68 GB per chip at train_4k.
    """
    Bb, T, H, Pd = xh.shape
    N = B_ssm.shape[-1]
    pad = (-T) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ssm = jnp.pad(B_ssm, ((0, 0), (0, pad), (0, 0)))
        C_ssm = jnp.pad(C_ssm, ((0, 0), (0, pad), (0, 0)))
    Tp = T + pad
    nc, Q = Tp // chunk, chunk

    def c(a, tail):
        return a.reshape((Bb, nc, Q) + tail)

    xc = c(xh, (H, Pd))
    dtc = c(dt, (H,))
    Bc = c(B_ssm, (N,))
    Cc = c(C_ssm, (N,))
    dA = dtc * A                                   # (B,nc,Q,H), negative
    L = jnp.cumsum(dA, axis=2)                     # log-decay from start

    # Intra-chunk: Y[t] = sum_{s<=t} exp(L_t-L_s)·(C_t·B_s)·dt_s·x_s
    CB = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)
    ddecay = jnp.exp(L[:, :, :, None, :] - L[:, :, None, :, :])
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    W = CB[..., None] * ddecay * mask[None, None, :, :, None]
    xdt = xc * dtc[..., None]                      # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", W, xdt)

    # Chunk-boundary states.
    decay_to_end = jnp.exp(L[:, :, -1:, :] - L)    # (B,nc,Q,H)
    S_c = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, decay_to_end, xdt)
    chunk_decay = jnp.exp(L[:, :, -1, :])          # (B,nc,H)

    if h0 is None:
        h0 = jnp.zeros((Bb, H, Pd, N), jnp.float32)

    def step(h, inp):
        s_c, cd = inp                              # (B,H,P,N), (B,H)
        h_start = h
        h_end = cd[..., None, None] * h + s_c
        return h_end, h_start

    (h_last, h_starts) = jax.lax.scan(
        step, h0, (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_starts = jnp.moveaxis(h_starts, 0, 1)        # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Cc, jnp.exp(L), h_starts)
    y = (y_intra + y_inter).reshape(Bb, Tp, H, Pd)
    return y[:, :T], h_last


def mamba2_seq(x: jax.Array, p: dict, d_state: int, head_dim: int,
               chunk: int = 128, h0: jax.Array | None = None,
               ) -> tuple[jax.Array, jax.Array]:
    """Full-sequence Mamba2 mixer (SSD chunked form).

    x: (B,S,D). Returns (y (B,S,D), h_T (B,H,P,N)).
    """
    from .layers import rms_norm
    B, S, _ = x.shape
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    d_inner = (p["out_proj"].shape[0])
    H = d_inner // head_dim
    z, xs, B_ssm, C_ssm, dt = _mamba2_split(zxbcdt, d_inner, d_state, H)
    xbc = jnp.concatenate([xs, B_ssm, C_ssm], axis=-1)
    xbc = jax.nn.silu(causal_conv1d(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_inner]
    B_ssm = xbc[..., d_inner:d_inner + d_state].astype(jnp.float32)
    C_ssm = xbc[..., d_inner + d_state:].astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    dt = constrain_ssm_bth(dt)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,)
    xh = xs.reshape(B, S, H, head_dim).astype(jnp.float32)
    xh = constrain_ssm_bthp(xh)
    y, h_last = ssd_chunked(xh, dt, A, B_ssm, C_ssm, chunk, h0)
    y = y + xh * p["ssm_D"][:, None]
    y = y.reshape(B, S, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["gate_norm"])
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"]), h_last


def mamba2_step(x_t: jax.Array, p: dict, d_state: int, head_dim: int,
                h: jax.Array, conv_state: jax.Array,
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Decode step. x_t: (B,D); h: (B,H,P,N); conv_state: (B,K-1,Ci)."""
    from .layers import rms_norm
    B = x_t.shape[0]
    zxbcdt = jnp.einsum("bd,de->be", x_t, p["in_proj"])
    d_inner = p["out_proj"].shape[0]
    H = d_inner // head_dim
    z, xs, B_ssm, C_ssm, dt = _mamba2_split(zxbcdt, d_inner, d_state, H)
    xbc = jnp.concatenate([xs, B_ssm, C_ssm], axis=-1)
    xbc, conv_state = conv1d_step(xbc, conv_state, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_inner]
    B_ssm = xbc[..., d_inner:d_inner + d_state].astype(jnp.float32)
    C_ssm = xbc[..., d_inner + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)                                      # (B,H)
    xh = xs.reshape(B, H, head_dim).astype(jnp.float32)
    u = (dt[..., None] * xh)[..., None] * B_ssm[:, None, None, :]
    h = decay[..., None, None] * h + u
    y = jnp.einsum("bhpn,bn->bhp", h, C_ssm)
    y = y + xh * p["ssm_D"][:, None]
    y = y.reshape(B, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x_t.dtype), p["gate_norm"])
    return jnp.einsum("bi,id->bd", y, p["out_proj"]), h, conv_state
