"""Model substrate: configs, layers, families, uniform api."""
from .base import Family, ModelConfig, param_shapes
from . import api
