"""Zamba2-style hybrid: Mamba2 backbone + one shared attention block.

38 Mamba2 layers; a single *shared* (attention + MLP) transformer block
is applied after every ``attn_every``-th mamba layer, reusing the same
weights at each application (zamba2's parameter-sharing trick).

The layer loop scans over *periods* of ``attn_every`` layers (the
natural zamba2 repeat unit): the scan body holds attn_every mamba
mixers + one shared-block application, so the HLO stays ~attn_every×
smaller than an unrolled stack (the unrolled version took >14 min to
compile at 512 devices), while KV caches exist only at the shared-block
sites — (n_sites, B, S, kh, dh), not (L, ...), which matters enormously
at long_500k. Layers beyond the last full period run unrolled without
attention.

Serve state: (ssm (L,B,H,P,N) f32, conv (L,B,K-1,C), kv (n_sites,...)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ModelConfig
from .layers import cross_entropy, embed, rms_norm, rope_cos_sin, unembed
from .lm import _attn, _mlp
from repro.distributed.act_sharding import (constrain_boundary,
                                            constrain_btd, constrain_logits)
from .ssm import mamba2_seq, mamba2_step


def attn_sites(cfg: ModelConfig) -> list[int]:
    return [i for i in range(cfg.n_layers)
            if (i + 1) % cfg.attn_every == 0]


def _layer_stack(params: dict) -> dict:
    return {k.split("/", 1)[1]: v for k, v in params.items()
            if k.startswith("layers/")}


def _shared_params(params: dict) -> dict:
    return {k.split("/", 1)[1]: v for k, v in params.items()
            if k.startswith("shared/")}


def _split_periods(cfg: ModelConfig, stack: dict):
    """(scanned (n_per, E, ...), tail (n_tail, ...)) views of the stack."""
    E = cfg.attn_every
    n_per = cfg.n_layers // E
    n_scan = n_per * E
    scanned = {k: v[:n_scan].reshape((n_per, E) + v.shape[1:])
               for k, v in stack.items()}
    tail = {k: v[n_scan:] for k, v in stack.items()}
    return scanned, tail, n_per, cfg.n_layers - n_scan


def _mamba_block(cfg, x, p, decode, ssm_state=None, conv_state=None):
    h_in = rms_norm(x, p["ssm_norm"], cfg.norm_eps)
    if decode:
        y, h, cstate = mamba2_step(h_in[:, 0], p, cfg.d_state,
                                   cfg.ssm_head_dim, ssm_state,
                                   conv_state)
        return x + y[:, None], h, cstate
    y, h = mamba2_seq(h_in, p, cfg.d_state, cfg.ssm_head_dim,
                      cfg.ssm_chunk)
    zx = jnp.einsum("bsd,de->bse", h_in, p["in_proj"])
    xbc_raw = zx[..., cfg.d_inner:2 * cfg.d_inner + 2 * cfg.d_state]
    cstate = xbc_raw[:, -(cfg.d_conv - 1):, :]
    return x + y, h, cstate


def _run(cfg: ModelConfig, params: dict, x: jax.Array, cos, sin,
         ssm_states=None, conv_states=None, kv_caches=None,
         cache_len=None, decode: bool = False, lora=None,
         adapter_idx=None, need_state: bool = True,
         lora_backend: str = "einsum"):
    """Period-scanned driver. States are stacked arrays (see module doc).

    Returns (x, ssm (L,...), conv (L,...), kv (n_sites,...) or None).
    """
    shared = _shared_params(params)
    stack = _layer_stack(params)
    scanned, tail, n_per, n_tail = _split_periods(cfg, stack)
    E = cfg.attn_every
    serving = ssm_states is not None

    def period(x, xs):
        x = constrain_boundary(x) if not decode else x
        new_ssm, new_conv = [], []
        for e in range(E):
            p = {k: v[e] for k, v in xs["p"].items()}
            x, h, c = _mamba_block(
                cfg, x, p, decode,
                xs["ssm"][e] if serving else None,
                xs["conv"][e] if serving else None)
            new_ssm.append(h)
            new_conv.append(c)
        kv = (xs["k"], xs["v"]) if kv_caches is not None else None
        lr = ({proj: (a, b) for proj, (a, b) in xs["lora"].items()}
              if lora is not None else None)
        x, kv_new = _attn(cfg, x, shared, cos, sin, kv, cache_len, lr,
                          adapter_idx, lora_backend=lora_backend)
        x = _mlp(cfg, x, shared)
        if not need_state:
            return x, None      # train/forward: no dead state stacks
        ys = {"ssm": jnp.stack(new_ssm), "conv": jnp.stack(new_conv)}
        if kv_caches is not None or not decode:
            ys["k"], ys["v"] = kv_new
        return x, ys

    def body(carry, xs):
        return period(carry, xs)

    if cfg.remat and not decode:
        body = jax.checkpoint(body)

    xs = {"p": scanned}
    if serving:
        n_scan = n_per * E
        xs["ssm"] = ssm_states[:n_scan].reshape(
            (n_per, E) + ssm_states.shape[1:])
        xs["conv"] = conv_states[:n_scan].reshape(
            (n_per, E) + conv_states.shape[1:])
    if kv_caches is not None:
        xs["k"], xs["v"] = kv_caches
    if lora is not None:
        xs["lora"] = lora      # (n_sites, slots, din, r) stacks

    x, ys = jax.lax.scan(body, x, xs)

    # Tail layers (no attention site).
    tail_ssm, tail_conv = [], []
    for i in range(n_tail):
        p = {k: v[i] for k, v in tail.items()}
        x, h, c = _mamba_block(
            cfg, x, p, decode,
            ssm_states[n_per * E + i] if serving else None,
            conv_states[n_per * E + i] if serving else None)
        tail_ssm.append(h)
        tail_conv.append(c)

    if not need_state:
        return x, None, None, None
    ssm_out = ys["ssm"].reshape((n_per * E,) + ys["ssm"].shape[2:])
    conv_out = ys["conv"].reshape((n_per * E,) + ys["conv"].shape[2:])
    if n_tail:
        ssm_out = jnp.concatenate([ssm_out, jnp.stack(tail_ssm)])
        conv_out = jnp.concatenate([conv_out, jnp.stack(tail_conv)])
    kv_out = (ys.get("k"), ys.get("v")) if "k" in ys else None
    return x, ssm_out, conv_out, kv_out


def _head(cfg, params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = (params["embed/tok"].T if cfg.tie_embeddings
             else params["lm_head"])
    return unembed(x, table)


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            mrope_pos=None) -> jax.Array:
    x = constrain_btd(embed(tokens, params["embed/tok"]))
    pos = jnp.arange(tokens.shape[1])[None, :]
    cos, sin = rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)
    x, *_ = _run(cfg, params, x, cos, sin, need_state=False)
    return constrain_logits(_head(cfg, params, x))


def train_loss(cfg, params, tokens, labels, mrope_pos=None,
               aux_weight=0.0):
    return cross_entropy(forward(cfg, params, tokens), labels)


def init_serve_state(cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16):
    n_sites = len(attn_sites(cfg))
    conv_dim = cfg.d_inner + 2 * cfg.d_state
    ssm = jnp.zeros((cfg.n_layers, batch, cfg.n_ssm_heads,
                     cfg.ssm_head_dim, cfg.d_state), jnp.float32)
    conv = jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1, conv_dim),
                     dtype)
    kv = (jnp.zeros((n_sites, batch, max_len, cfg.n_kv_heads,
                     cfg.head_dim), dtype),
          jnp.zeros((n_sites, batch, max_len, cfg.n_kv_heads,
                     cfg.head_dim), dtype))
    return ssm, conv, kv


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            kv_max_len: int | None = None, lora=None, adapter_idx=None,
            lora_backend: str = "einsum"):
    """Returns (last logits (B,V), (ssm, conv, kv) serve state)."""
    B, S = tokens.shape
    x = embed(tokens, params["embed/tok"])
    pos = jnp.arange(S)[None, :]
    cos, sin = rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)
    x, ssm, conv, kv = _run(cfg, params, x, cos, sin, lora=lora,
                            adapter_idx=adapter_idx,
                            lora_backend=lora_backend)
    if kv_max_len is not None and kv_max_len > S:
        k, v = kv
        pad = ((0, 0), (0, 0), (0, kv_max_len - S), (0, 0), (0, 0))
        kv = (jnp.pad(k, pad), jnp.pad(v, pad))
    return _head(cfg, params, x[:, -1:])[:, 0], (ssm, conv, kv)


def decode_step(cfg: ModelConfig, params: dict, tokens: jax.Array,
                state, cache_len: jax.Array, lora=None, adapter_idx=None,
                lora_backend: str = "einsum"):
    """tokens (B,1); state = (ssm, conv, (k,v)); cache_len (B,)."""
    ssm, conv, kv = state
    x = embed(tokens, params["embed/tok"])
    cos, sin = rope_cos_sin(jnp.reshape(cache_len, (-1, 1)),
                            cfg.head_dim, cfg.rope_theta)
    x, ssm, conv, kv = _run(cfg, params, x, cos, sin, ssm_states=ssm,
                            conv_states=conv, kv_caches=kv,
                            cache_len=cache_len, decode=True, lora=lora,
                            adapter_idx=adapter_idx,
                            lora_backend=lora_backend)
    return _head(cfg, params, x)[:, 0], (ssm, conv, kv)
