"""Multi-adapter LoRA application: backend dispatch + reference path.

The serving data plane applies, per request b with adapter index
idx[b]:

    y[b] = x[b] @ W  +  (x[b] @ A[idx[b]]) @ B[idx[b]]

A: (n_slots, d_in, r_max), B: (n_slots, r_max, d_out) — adapter *slots*
are fixed device buffers managed by the Chameleon cache (weights of
evicted adapters are overwritten in place; ranks < r_max are
zero-padded so one static shape serves every rank).

``lora_delta`` dispatches on ``backend``: ``"kernel"`` routes through
the fused Pallas bgmv (decode, S == 1) / sgmv (prefill, S > 1) kernels
in repro.kernels.ops — the gather + two skinny matmuls in one kernel
invocation, scalar-prefetched adapter indices, no materialised
(B, din, r) gather; ``"einsum"`` is the pure-jnp oracle both CI parity
jobs and the CPU engine run. The engine resolves its
``EngineConfig.lora_backend`` knob once (kernel on TPU, einsum
elsewhere under ``auto``) so jit caches stay coherent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lora_delta(x: jax.Array, ab: tuple[jax.Array, jax.Array],
               adapter_idx: jax.Array, scale: float = 1.0,
               backend: str = "einsum") -> jax.Array:
    """x: (B, S, d_in); ab = (A (n,din,r), B (n,r,dout)); idx: (B,)."""
    A, Bm = ab
    if backend == "kernel":
        from repro.kernels.ops import lora_delta_kernel
        return lora_delta_kernel(x, A, Bm, adapter_idx, scale=scale)
    A_sel = jnp.take(A, adapter_idx, axis=0)        # (B, din, r)
    B_sel = jnp.take(Bm, adapter_idx, axis=0)       # (B, r, dout)
    t = jnp.einsum("bsd,bdr->bsr", x, A_sel)
    return scale * jnp.einsum("bsr,bro->bso", t, B_sel)


def init_lora_slots(key, n_slots: int, n_layers: int, d_model: int,
                    q_dim: int, kv_dim: int, r_max: int,
                    dtype=jnp.bfloat16) -> dict:
    """Zero-initialised adapter slot buffers for q/k/v/o projections."""
    def z(*shape):
        return jnp.zeros(shape, dtype)
    return {
        "q": (z(n_layers, n_slots, d_model, r_max),
              z(n_layers, n_slots, r_max, q_dim)),
        "k": (z(n_layers, n_slots, d_model, r_max),
              z(n_layers, n_slots, r_max, kv_dim)),
        "v": (z(n_layers, n_slots, d_model, r_max),
              z(n_layers, n_slots, r_max, kv_dim)),
        "o": (z(n_layers, n_slots, q_dim, r_max),
              z(n_layers, n_slots, r_max, d_model)),
    }


def random_lora_weights(key, rank: int, r_max: int, n_layers: int,
                        d_model: int, q_dim: int, kv_dim: int,
                        dtype=jnp.bfloat16) -> dict:
    """One adapter's weights (rank-r content, zero-padded to r_max)."""
    out = {}
    dims = {"q": (d_model, q_dim), "k": (d_model, kv_dim),
            "v": (d_model, kv_dim), "o": (q_dim, d_model)}
    keys = jax.random.split(key, len(dims))
    for (name, (din, dout)), k in zip(dims.items(), keys):
        ka, kb = jax.random.split(k)
        a = jnp.zeros((n_layers, din, r_max), dtype)
        b = jnp.zeros((n_layers, r_max, dout), dtype)
        a = a.at[:, :, :rank].set(
            (din ** -0.5) * jax.random.normal(ka, (n_layers, din, rank)
                                              ).astype(dtype))
        # LoRA-B starts at zero in fine-tuning; for serving tests we use
        # random B so the delta is observable.
        b = b.at[:, :rank, :].set(
            (rank ** -0.5) * jax.random.normal(kb, (n_layers, rank, dout)
                                               ).astype(dtype))
        out[name] = (a, b)
    return out


def write_adapter_to_slot(slots: dict, adapter: dict, slot: int,
                          shardings: dict | None = None) -> dict:
    """Functional slot update (engine: cache-fill on load).

    ``shardings`` ({proj: (A_sharding, B_sharding)}, per-adapter-weight
    layout): commit the host weights to the sharded slot layout *before*
    the slot write, so each device of a mesh engine receives only its
    slice of the LoRA-B tensor — the upload path never materialises the
    full weight on every device.
    """
    out = {}
    for name, (a_s, b_s) in slots.items():
        a_w, b_w = adapter[name]
        if shardings is not None:
            sh_a, sh_b = shardings[name]
            a_w = jax.device_put(a_w, sh_a)
            b_w = jax.device_put(b_w, sh_b)
        out[name] = (a_s.at[:, slot].set(a_w), b_s.at[:, slot].set(b_w))
    return out
