"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv/mel frontend is a stub: the encoder
consumes precomputed frame embeddings (B, enc_ctx, D). Positions are
learned embeddings (no RoPE); the decoder has causal self-attention
plus cross-attention to the encoder output. Norms are RMS (modernised
from Whisper's LayerNorm — backbone-only fidelity, noted in DESIGN.md).

Serve path: ``encode`` runs once; ``prefill`` consumes the decoder
prompt and builds (self-KV, cross-KV) caches; ``decode_step`` extends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ModelConfig
from .layers import cross_entropy, embed, gqa_attention, rms_norm, swiglu, unembed


def _grp(params, prefix):
    return {k[len(prefix):]: v for k, v in params.items()
            if k.startswith(prefix) and not k[len(prefix):].startswith("x")}


def _grp_cross(params):
    return {k[len("dec/x"):]: v for k, v in params.items()
            if k.startswith("dec/x")}


def _self_attn(cfg, x, p, causal, kv_cache=None, cache_len=None):
    B, S, D = x.shape
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", h, p["q"]).reshape(
        B, S, cfg.n_heads, cfg.head_dim)
    k = jnp.einsum("bsd,de->bse", h, p["k"]).reshape(
        B, S, cfg.n_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,de->bse", h, p["v"]).reshape(
        B, S, cfg.n_kv_heads, cfg.head_dim)
    if kv_cache is None:
        out = gqa_attention(q, k, v, causal=causal)
        new_kv = (k, v)
    else:
        kc, vc = kv_cache
        idx = jnp.reshape(cache_len, (B, 1)) + jnp.arange(S)[None]
        bidx = jnp.arange(B)[:, None] + jnp.zeros_like(idx)
        kc = kc.at[bidx, idx].set(k)
        vc = vc.at[bidx, idx].set(v)
        out = gqa_attention(q, kc, vc, causal=False,
                            kv_len=cache_len + S)
        new_kv = (kc, vc)
    out = out.reshape(B, S, cfg.q_dim)
    return x + jnp.einsum("bse,ed->bsd", out, p["o"]), new_kv


def _cross_attn(cfg, x, p, kx, vx):
    """kx, vx: precomputed encoder K/V (B, Senc, Kh, Dh)."""
    B, S, D = x.shape
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", h, p["q"]).reshape(
        B, S, cfg.n_heads, cfg.head_dim)
    out = gqa_attention(q, kx, vx, causal=False)
    out = out.reshape(B, S, cfg.q_dim)
    return x + jnp.einsum("bse,ed->bsd", out, p["o"])


def _mlp(cfg, x, p):
    h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    return x + swiglu(h, p["gate"], p["up"], p["down"])


# ---------------------------------------------------------------- encoder
def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, enc_ctx, D) stub embeddings -> encoder states."""
    x = frames + params["enc_pos"][None, : frames.shape[1]]
    enc = _grp(params, "enc/")

    def body(h, p):
        h, _ = _self_attn(cfg, h, p, causal=False)
        h = _mlp(cfg, h, p)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, enc)
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def cross_kv(cfg: ModelConfig, params: dict, enc_out: jax.Array):
    """Per-decoder-layer cross K/V from encoder output."""
    B, Se, D = enc_out.shape
    xp = _grp_cross(params)
    k = jnp.einsum("bsd,lde->lbse", enc_out, xp["k"]).reshape(
        cfg.n_layers, B, Se, cfg.n_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,lde->lbse", enc_out, xp["v"]).reshape(
        cfg.n_layers, B, Se, cfg.n_kv_heads, cfg.head_dim)
    return k, v


# ---------------------------------------------------------------- decoder
def _decoder(cfg, params, x, kx, vx, kv_caches=None, cache_len=None):
    dec = _grp(params, "dec/")
    xdec = _grp_cross(params)

    def body(h, xs):
        p, pxq, pxo, pxn, kxl, vxl = (xs["p"], xs["xq"], xs["xo"],
                                      xs["xn"], xs["kx"], xs["vx"])
        kv = (xs["k"], xs["v"]) if kv_caches is not None else None
        h, new_kv = _self_attn(cfg, h, p, causal=True, kv_cache=kv,
                               cache_len=cache_len)
        px = {"attn_norm": pxn, "q": pxq, "o": pxo}
        h = _cross_attn(cfg, h, px, kxl, vxl)
        h = _mlp(cfg, h, p)
        return h, new_kv

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = {"p": dec, "xq": xdec["q"], "xo": xdec["o"],
          "xn": xdec["attn_norm"], "kx": kx, "vx": vx}
    if kv_caches is not None:
        xs["k"], xs["v"] = kv_caches
    x, kv_out = jax.lax.scan(body, x, xs)
    return x, kv_out


def _head(cfg, params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = (params["embed/tok"].T if cfg.tie_embeddings
             else params["lm_head"])
    return unembed(x, table)


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            frames: jax.Array) -> jax.Array:
    """Teacher-forced decoder logits (training)."""
    enc_out = encode(cfg, params, frames)
    kx, vx = cross_kv(cfg, params, enc_out)
    x = embed(tokens, params["embed/tok"]) \
        + params["dec_pos"][None, : tokens.shape[1]]
    x, _ = _decoder(cfg, params, x, kx, vx)
    return _head(cfg, params, x)


def train_loss(cfg, params, tokens, labels, frames, aux_weight=0.0):
    return cross_entropy(forward(cfg, params, tokens, frames), labels)


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            frames: jax.Array, lora=None, adapter_idx=None,
            lora_backend: str = "einsum"):
    enc_out = encode(cfg, params, frames)
    kx, vx = cross_kv(cfg, params, enc_out)
    x = embed(tokens, params["embed/tok"]) \
        + params["dec_pos"][None, : tokens.shape[1]]
    x, kv = _decoder(cfg, params, x, kx, vx)
    return _head(cfg, params, x[:, -1:])[:, 0], (kv, (kx, vx))


def decode_step(cfg: ModelConfig, params: dict, tokens: jax.Array,
                state, cache_len: jax.Array, lora=None, adapter_idx=None,
                lora_backend: str = "einsum"):
    """tokens (B,1); state = ((k,v) self caches (L,B,Smax,..), (kx,vx))."""
    kv, (kx, vx) = state
    pos = jnp.reshape(cache_len, (-1, 1))                  # (B, 1)
    x = embed(tokens, params["embed/tok"]) + params["dec_pos"][pos]
    x, kv = _decoder(cfg, params, x, kx, vx, kv_caches=kv,
                     cache_len=cache_len)
    return _head(cfg, params, x)[:, 0], (kv, (kx, vx))
