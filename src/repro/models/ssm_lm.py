"""Falcon-Mamba-style attention-free LM (Mamba1 stack, scan over layers).

Serve state is O(1) in sequence length: per-layer (ssm_state (B,Di,N),
conv_state (B,K-1,Di)) — this is why the long_500k cell runs for this
family while pure-attention archs skip it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import (constrain_boundary,
                                            constrain_btd,
                                            constrain_logits)

from .base import ModelConfig
from .layers import cross_entropy, embed, rms_norm, unembed
from .ssm import mamba1_seq, mamba1_step


def _stack(params: dict) -> dict:
    return {k.split("/", 1)[1]: v for k, v in params.items()
            if k.startswith("layers/")}


def _head(cfg, params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = (params["embed/tok"].T if cfg.tie_embeddings
             else params["lm_head"])
    return unembed(x, table)


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array,
            mrope_pos=None) -> jax.Array:
    x = constrain_btd(embed(tokens, params["embed/tok"]))

    def body(h, p):
        h = constrain_boundary(h)
        y, _, _ = mamba1_seq(rms_norm(h, p["ssm_norm"], cfg.norm_eps),
                             p, cfg.d_state, cfg.dt_rank, cfg.ssm_chunk)
        return constrain_boundary(h + y), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, _stack(params))
    return constrain_logits(_head(cfg, params, x))


def train_loss(cfg, params, tokens, labels, mrope_pos=None,
               aux_weight=0.0):
    return cross_entropy(forward(cfg, params, tokens), labels)


def init_serve_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    L = cfg.n_layers
    ssm = jnp.zeros((L, batch, cfg.d_inner, cfg.d_state), jnp.float32)
    conv = jnp.zeros((L, batch, cfg.d_conv - 1, cfg.d_inner), dtype)
    return ssm, conv


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            lora=None, adapter_idx=None, lora_backend: str = "einsum"):
    """Returns (last logits (B,V), (ssm_states, conv_states))."""
    x = embed(tokens, params["embed/tok"])

    def body(h, p):
        h = constrain_boundary(h)
        y, h_last, conv_tail = mamba1_seq(
            rms_norm(h, p["ssm_norm"], cfg.norm_eps), p,
            cfg.d_state, cfg.dt_rank, cfg.ssm_chunk)
        return constrain_boundary(h + y), (h_last, conv_tail)

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ssm, conv) = jax.lax.scan(body, x, _stack(params))
    return _head(cfg, params, x[:, -1:])[:, 0], (ssm, conv)


def decode_step(cfg: ModelConfig, params: dict, tokens: jax.Array,
                state, cache_len=None, lora=None, adapter_idx=None,
                lora_backend: str = "einsum"):
    """tokens (B,1); state = (ssm (L,B,Di,N), conv (L,B,K-1,Di))."""
    ssm, conv = state
    x = embed(tokens, params["embed/tok"])[:, 0]         # (B,D)

    def body(h, xs):
        p, s, c = xs
        y, s, c = mamba1_step(rms_norm(h, p["ssm_norm"], cfg.norm_eps),
                              p, cfg.d_state, cfg.dt_rank, s, c)
        return h + y, (s, c)

    x, (ssm, conv) = jax.lax.scan(body, x, (_stack(params), ssm, conv))
    logits = _head(cfg, params, x[:, None])[:, 0]
    return logits, (ssm, conv)
