"""Sharding policy: parameter/state paths → PartitionSpecs.

One rule table serves every (family × step-kind). Axis conventions
(DESIGN §4):

- ``model``  — tensor parallelism: attention heads / FFN hidden /
               expert-FFN hidden / vocab. Non-divisible dims (40 heads
               over 16) compile via GSPMD padding; the §Perf log
               replaces padding with better splits where it matters.
- ``data``   — batch parallelism; for *training* also FSDP (params +
               AdamW moments sharded over data — ZeRO-3 style); for MoE
               the expert dimension (128 experts / 16 = 8 per chip).
- ``pod``    — second-level data axis (multi-pod): batch + FSDP.

Inference shards weights over "model" only (plus experts over "data")
— weights must be resident, not gathered per step; training adds FSDP
axes since the weight all-gather amortises over a 4096-token step.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.base import Family, ModelConfig


def _axes(mesh: Mesh):
    names = mesh.axis_names
    pod = ("pod",) if "pod" in names else ()
    return pod, "data", "model"


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


# Weight tensors silently degrading to replicated (a 40-head model on a
# 16-way "model" axis, say) used to be invisible; warn once per
# (label, shape, spec) so a misfit shows up in logs without spamming a
# per-step path. Batch/state fits (B=1 buckets legitimately drop
# "data") stay silent — only callers that pass ``warn_label`` opt in.
_FIT_WARNED: set = set()


def fit_spec(shape: tuple, spec: P, mesh: Mesh, *,
             warn_label: str | None = None) -> P:
    """Drop axes that do not divide their dimension.

    pjit *input* shardings require exact divisibility (GSPMD padding
    only applies inside the computation), so every spec passes through
    this fitter. Tuples are trimmed left-to-right: ("pod","data") on a
    dim of size 2 keeps ("pod",). With ``warn_label`` set, each axis
    dropped from that tensor warns once via ``warnings.warn``.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    dropped = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for a in axes:
            sz = _axis_size(mesh, a)
            if dim % (prod * sz) == 0:
                kept.append(a)
                prod *= sz
            else:
                dropped.append((a, dim, sz))
        if not kept:
            out.append(None)
        elif isinstance(entry, tuple) and len(axes) > 1:
            # A multi-axis tuple stays a tuple even when trimmed to one
            # axis — ("pod","data") on dim 2 keeps ("pod",) — while a
            # singleton entry normalises to its scalar form.
            out.append(tuple(kept))
        else:
            out.append(kept[0])
    while out and out[-1] is None:
        out.pop()
    if dropped and warn_label is not None:
        key = (warn_label, tuple(shape), str(spec))
        if key not in _FIT_WARNED:
            _FIT_WARNED.add(key)
            detail = ", ".join(
                f"'{a}' (size {sz}) on dim {dim}" for a, dim, sz in dropped)
            warnings.warn(
                f"fit_spec[{warn_label}]: shape {tuple(shape)} spec "
                f"{spec} drops non-dividing mesh axes: {detail}; the "
                f"dimension stays replicated", stacklevel=2)
    return P(*out)


def param_spec(path: str, shape: tuple, cfg: ModelConfig, mesh: Mesh,
               kind: str) -> P:
    """PartitionSpec for one parameter. ``kind``: train|prefill|decode."""
    pod, data, model = _axes(mesh)
    train = kind == "train"
    # FSDP axes used only in training.
    fsdp = (pod + (data,)) if train else ()
    fsdp1 = fsdp if train else None     # spec entry helper

    leaf = path.split("/")[-1]
    stacked = shape[0] == cfg.n_layers and len(shape) > 1 \
        or path.startswith(("layers/", "moe/", "dense_mlp/", "enc/",
                            "dec/"))

    def sp(*entries):
        # Strip trailing Nones.
        out = list(entries)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    # ---- embeddings / head -------------------------------------------------
    if path == "embed/tok":                      # (V, D)
        return sp(model, fsdp if fsdp else None)
    if path == "lm_head":                        # (D, V)
        return sp(fsdp if fsdp else None, model)
    if leaf in ("final_norm", "enc_final_norm"):
        return P()
    if leaf in ("enc_pos", "dec_pos"):
        return P()

    # Layer-stacked tensors: first axis is the layer stack (replicated).
    L = None  # placeholder for the stacked layer axis

    # ---- MoE ---------------------------------------------------------------
    if path.startswith("moe/"):
        if leaf == "router":                     # (nm, D, E)
            return sp(L, fsdp if fsdp else None, None)
        if leaf == "norm":
            return P()
        if leaf in ("w_gate", "w_up"):           # (nm, E, D, Fe)
            if not train:
                # Token-parallel inference: experts over data, FFN
                # unsharded (see act_sharding.constrain_expert_ecd).
                return sp(L, data, None, None)
            return sp(L, data, fsdp and pod or None, model)
        if leaf == "w_down":                     # (nm, E, Fe, D)
            if not train:
                return sp(L, data, None, None)
            return sp(L, data, model, fsdp and pod or None)
        if leaf in ("shared_gate", "shared_up"):  # (nm, D, F)
            return sp(L, fsdp if fsdp else None, model)
        if leaf == "shared_down":                # (nm, F, D)
            if not train:
                return sp(L)                # replicated: see "down"
            return sp(L, model, fsdp if fsdp else None)

    # ---- attention ---------------------------------------------------------
    if leaf == "q":                              # (..., D, q_dim)
        return sp(*( [L] if stacked else [] ),
                  fsdp if fsdp else None, model)
    if leaf in ("k", "v"):                       # (..., D, kv_dim)
        return sp(*( [L] if stacked else [] ),
                  fsdp if fsdp else None, model)
    if leaf == "o":                              # (..., q_dim, D)
        if not train:
            # Inference: column-parallel (output D over model). Row-
            # parallel would shard the contraction and psum partials —
            # a different FP reduction order per mesh shape. Keeping
            # every contraction dim unsharded makes sharded inference
            # bit-identical to single-device (token parity, DESIGN §4)
            # at the cost of the pre-projection head all-gather.
            return sp(*( [L] if stacked else [] ), None, model)
        return sp(*( [L] if stacked else [] ),
                  model, fsdp if fsdp else None)
    if leaf.endswith("_bias"):
        return P()
    if leaf.endswith("norm") or "norm" in leaf:
        return P()

    # ---- dense MLP ---------------------------------------------------------
    if leaf in ("gate", "up"):                   # (..., D, F)
        return sp(*( [L] if stacked else [] ),
                  fsdp if fsdp else None, model)
    if leaf == "down":                           # (..., F, D)
        if not train:
            # Inference keeps the down projection *replicated*, not
            # column-parallel: with the long F contraction, XLA's local
            # matmul blocks differently at width D/tp than at width D
            # (observed 3e-5 drift on CPU at K=256), so even an
            # unsharded-contraction split breaks bit-exact token parity.
            # Replicated weights make the local matmul shape identical
            # to single-device — deterministic by construction.
            return sp(*( [L] if stacked else [] ))
        return sp(*( [L] if stacked else [] ),
                  model, fsdp if fsdp else None)

    # ---- SSM ---------------------------------------------------------------
    if leaf == "in_proj":                        # (L, D, E*)
        return sp(L, fsdp if fsdp else None, model)
    if leaf == "out_proj":                       # (L, Di, D)
        if not train:
            return sp(L, None, model)       # column-parallel: see "o"
        return sp(L, model, fsdp if fsdp else None)
    if leaf in ("conv_w",):                      # (L, K, conv_dim)
        return sp(L, None, model)
    if leaf in ("conv_b",):                      # (L, conv_dim)
        return sp(L, model)
    if leaf == "x_proj":                         # (L, Di, dt+2N)
        return sp(L, model, None)
    if leaf == "dt_proj":                        # (L, dt_rank, Di)
        return sp(L, None, model)
    if leaf in ("dt_bias", "A_log", "ssm_D"):    # (L, Di|H[,N])
        return sp(L, model) if len(shape) >= 2 else P()

    return P()


def batch_spec(kind: str, mesh: Mesh) -> P:
    pod, data, model = _axes(mesh)
    return P(pod + (data,))


def param_shardings(cfg: ModelConfig, params_or_shapes: dict, mesh: Mesh,
                    kind: str) -> dict:
    out = {}
    for path, v in params_or_shapes.items():
        shape = v if isinstance(v, tuple) else v.shape
        spec = fit_spec(shape, param_spec(path, shape, cfg, mesh, kind),
                        mesh, warn_label=path)
        out[path] = NamedSharding(mesh, spec)
    return out


def opt_shardings(param_sh: dict, mesh: Mesh) -> dict:
    """AdamW moments follow their parameter's sharding (ZeRO-ish: the
    params are already FSDP-sharded in training, so moments are too)."""
    out = {"step": NamedSharding(mesh, P())}
    for path, sh in param_sh.items():
        out[f"m/{path}"] = sh
        out[f"v/{path}"] = sh
    return out


# ------------------------------------------------------------- activations
def kv_cache_spec(mesh: Mesh, shape: tuple) -> P:
    """(L, B, S, Kh, Dh): batch over data axes; kv heads over model
    when divisible, else *sequence*-sharded KV (each chip holds S/tp of
    every head — the right layout for MQA/GQA with few kv heads)."""
    pod, data, model = _axes(mesh)
    L, B, S, Kh, Dh = shape
    tp = _axis_size(mesh, model)
    if Kh % tp == 0:
        spec = P(None, pod + (data,), None, model)
    elif S % tp == 0:
        spec = P(None, pod + (data,), model, None)
    else:
        spec = P(None, pod + (data,))
    return fit_spec(shape, spec, mesh)


def kv_pages_spec(mesh: Mesh, shape: tuple) -> P:
    """Paged KV pool (L, n_pages, page, Kh, Dh): the *page* axis shards
    over "data" (each device owns n_pages/d physical pages — per-device
    HBM sizing, DESIGN §4), kv heads over "model" when divisible. The
    host-side page table stays global: page indices address the logical
    pool and GSPMD routes the gather."""
    pod, data, model = _axes(mesh)
    L, n_pages, page, Kh, Dh = shape
    tp = _axis_size(mesh, model)
    head = model if Kh % tp == 0 else None
    return fit_spec(shape, P(None, pod + (data,), None, head), mesh)


def ssm_state_spec(mesh: Mesh, shape: tuple) -> P:
    """(L, B, Di, N): batch over data, d_inner over model."""
    pod, data, model = _axes(mesh)
    return fit_spec(shape, P(None, pod + (data,), model), mesh)


def conv_state_spec(mesh: Mesh, shape: tuple) -> P:
    """(L, B, K-1, C): batch over data, channels over model."""
    pod, data, model = _axes(mesh)
    return fit_spec(shape, P(None, pod + (data,), None, model), mesh)


def lora_spec(proj: str, which: str, mesh: Mesh) -> P:
    """LoRA slot buffers: A (L, slots, din, r) replicated on din/r;
    B (L, slots, r, dout) with dout over model (matches the projection
    output sharding so the delta adds without a reshard)."""
    pod, data, model = _axes(mesh)
    if which == "a":
        return P(None, None, None, None)
    # Projection output dims are model-sharded at inference (q/k/v over
    # heads, o column-parallel); the down projection's output is
    # replicated, but its LoRA delta contracts only over r (a single
    # K-block), so a sharded B adds without a reduction-order change.
    return P(None, None, None, model)
