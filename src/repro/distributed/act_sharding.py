"""Activation sharding constraints (GSPMD propagation anchors).

Sharding propagation through scan-over-layers + remat reliably loses
the batch sharding of activations (the recompute path resolves to
replicated), which silently turns a 16-way batch-parallel program into
a replicated one. The launcher/dry-run installs the mesh's batch axes
here; the model code calls ``constrain_*`` at layer boundaries, which
is a no-op when nothing is installed (tests, single-device engine).
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: tuple | None = None
_MODEL_AXIS: str | None = None
_EXPERT_AXIS: str | None = None
_MODEL_SIZE: int = 1
_SEQ_SHARD: bool = False
_MOE_TOKEN_PARALLEL: bool = False
_MESH = None
_EXACT: bool = False


@contextlib.contextmanager
def activation_sharding(batch_axes: tuple, model_axis: str = "model",
                        expert_axis: str = "data", model_size: int = 1,
                        seq_shard_boundary: bool = False,
                        moe_token_parallel: bool = False,
                        mesh=None, exact_reductions: bool = False):
    """``seq_shard_boundary``: shard the inter-layer residual stream's
    sequence dim over the model axis (Megatron-style sequence
    parallelism). This is what bounds remat memory: the saved per-layer
    carries shrink by the TP degree (25 GB -> 1.6 GB per chip for a
    14B model at 64k tokens/chip); XLA re-gathers S where attention/MLP
    need it.

    ``exact_reductions`` (the serving engine's mode): constrain
    activations so no einsum ever contracts over a sharded dim —
    FFN hidden and merged attention heads are gathered *before* their
    down/out projections instead of row-parallel psum'd after. Every
    FP reduction then keeps the single-device order, making sharded
    inference token-identical to mesh=1 (DESIGN §4)."""
    global _BATCH_AXES, _MODEL_AXIS, _EXPERT_AXIS, _MODEL_SIZE, \
        _SEQ_SHARD, _MOE_TOKEN_PARALLEL, _MESH, _EXACT
    prev = (_BATCH_AXES, _MODEL_AXIS, _EXPERT_AXIS, _MODEL_SIZE,
            _SEQ_SHARD, _MOE_TOKEN_PARALLEL, _MESH, _EXACT)
    _BATCH_AXES, _MODEL_AXIS, _EXPERT_AXIS = (batch_axes, model_axis,
                                              expert_axis)
    _MODEL_SIZE, _SEQ_SHARD = model_size, seq_shard_boundary
    _MOE_TOKEN_PARALLEL = moe_token_parallel
    _MESH = mesh
    _EXACT = exact_reductions
    try:
        yield
    finally:
        (_BATCH_AXES, _MODEL_AXIS, _EXPERT_AXIS, _MODEL_SIZE,
         _SEQ_SHARD, _MOE_TOKEN_PARALLEL, _MESH, _EXACT) = prev


def moe_a2a_mesh():
    """(mesh, expert_axis) when the shard_map a2a MoE should be used
    (inference under an installed mesh), else None."""
    if _MOE_TOKEN_PARALLEL and _MESH is not None:
        return _MESH, _EXPERT_AXIS
    return None


def _wsc(x, spec):
    if _BATCH_AXES is None:
        return x
    if _MESH is not None:
        # Mesh installed explicitly (the serving engine does not run
        # its jits under a ``with mesh:`` scope): resolve the raw spec
        # to a NamedSharding, fitted to this value's shape so uneven
        # dims (a B=1 prefill bucket on a 2-way data axis) degrade to
        # replicated instead of erroring.
        from jax.sharding import NamedSharding
        from repro.distributed.sharding import fit_spec
        fitted = fit_spec(x.shape, spec, _MESH)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(_MESH, fitted))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_btd(x):
    """(B, S, D) activations: batch over data axes."""
    return _wsc(x, P(_BATCH_AXES, None, None))


def constrain_boundary(x):
    """Inter-layer residual (B, S, D): batch over data; sequence over
    the model axis when sequence-parallel boundaries are enabled."""
    if (_SEQ_SHARD and x.ndim == 3 and _MODEL_SIZE > 1
            and x.shape[1] % _MODEL_SIZE == 0):
        return _wsc(x, P(_BATCH_AXES, _MODEL_AXIS, None))
    return _wsc(x, P(_BATCH_AXES, None, None))


def constrain_bd(x):
    """(B, D) decode activations."""
    return _wsc(x, P(_BATCH_AXES, None))


def constrain_logits(x):
    """(B, S, V) or (B, V) logits: batch over data, vocab over model.

    Exact mode gathers the vocab dim instead: sampling consumes these
    (sort / cumsum / top-k over V), and a model-sharded vocab turns
    those into distributed scans with a different FP order than
    single-device — the unembed einsum still runs column-parallel, the
    all-gather after it is elementwise."""
    v = None if _EXACT else _MODEL_AXIS
    if x.ndim == 2:
        return _wsc(x, P(_BATCH_AXES, v))
    return _wsc(x, P(_BATCH_AXES, None, v))


def constrain_heads(x):
    """Attention head tensors — (B, S, H, D) q/k/v or (B, S, Kh, G, D)
    grouped query: heads over the model axis (the serving fused scan
    otherwise replicates the per-head compute, DESIGN §4)."""
    if x.ndim == 4:
        return _wsc(x, P(_BATCH_AXES, None, _MODEL_AXIS, None))
    if x.ndim == 5:
        return _wsc(x, P(_BATCH_AXES, None, _MODEL_AXIS, None, None))
    return x


def constrain_ffn_hidden(x):
    """(B, S, F) MLP hidden: F over model — matches the gate/up column
    sharding so SwiGLU runs fully sharded until the down projection.
    Exact mode gathers F here instead, so the down projection contracts
    an unsharded dim in single-device FP order (no psum of partials)."""
    if _EXACT:
        return _wsc(x, P(_BATCH_AXES, None, None))
    return _wsc(x, P(_BATCH_AXES, None, _MODEL_AXIS))


def constrain_attn_merged(x):
    """(B, S, q_dim) attention output after heads merge, feeding the o
    projection. Exact mode only: gather the head shards so the o-proj
    contraction runs unsharded (see ``exact_reductions``); otherwise a
    no-op — training relies on GSPMD propagation (row-parallel o)."""
    if _EXACT:
        return _wsc(x, P(_BATCH_AXES, None, None))
    return x


def constrain_residual(x):
    """(B, S, D) residual stream *mid-layer* (between the attention
    residual and the MLP norm). Exact mode only: the column-parallel
    o projection leaves D model-sharded, and the next rms_norm would
    psum its mean-square over the shards — a different FP reduction
    order per mesh shape. Gathering here keeps every norm reduction in
    single-device order; outside exact mode propagation stands."""
    if _EXACT:
        return _wsc(x, P(_BATCH_AXES, None, None))
    return x


def constrain_ssm_channels(x):
    """(B, S, C) SSM activations: channels over model, S *full* — the
    time recurrence is sequential in S, so sequence sharding inside the
    mixer forces pathological resharding (observed: 48 GB/layer of
    collectives on falcon train before this anchor)."""
    return _wsc(x, P(_BATCH_AXES, None, _MODEL_AXIS))


def constrain_ssm_bthp(x):
    """SSM activations (B, T, H, P): heads over the model axis."""
    return _wsc(x, P(_BATCH_AXES, None, _MODEL_AXIS, None))


def constrain_ssm_bth(x):
    """(B, T, H) per-head scalars: heads over the model axis."""
    return _wsc(x, P(_BATCH_AXES, None, _MODEL_AXIS))


def constrain_moe_groups(x):
    """Group-major MoE tensors (G, ...): groups follow batch sharding
    (the (B,S)->(G,Tg) reshape is layout-compatible, but GSPMD tends to
    replicate through it without an anchor). Skipped under sequence-
    parallel boundaries: there S carries the model-axis sharding and a
    batch-only anchor would force an S all-gather per layer."""
    if _SEQ_SHARD:
        return x
    return _wsc(x, P(_BATCH_AXES, *([None] * (x.ndim - 1))))


def constrain_moe_local(x):
    """Pre-all-to-all bucket tensor (G, E, C, D): still group-sharded
    (local to each data shard). Forcing this anchor *before* the
    expert-sharded anchor turns the reshard into a clean all-to-all —
    fused into the dispatch einsum, GSPMD falls back to all-gathering
    operands (measured 14.9 GB/layer vs ~0.5 GB for the a2a)."""
    return _wsc(x, P(_BATCH_AXES, None, None, None))


def constrain_expert_ecd(x):
    """MoE dispatch buckets (G, E, C, D): experts over the expert axis
    (the group dim gives up its batch sharding here — this reshard is
    the MoE all-to-all). In token-parallel mode (inference) the bucket
    dim C also shards over the model axis — each chip runs its experts
    on 1/TP of their tokens with *unsharded* expert FFN weights, so no
    down-projection psum exists at all (it was 10 GB wire/layer on
    qwen3-moe prefill)."""
    if _MOE_TOKEN_PARALLEL:
        return _wsc(x, P(None, _EXPERT_AXIS, _MODEL_AXIS, None))
    return _wsc(x, P(None, _EXPERT_AXIS, None, None))


def constrain_expert_ecf(x):
    """MoE hidden (G, E, C, F): experts over data; hidden over model
    (TP mode) or tokens over model (token-parallel inference)."""
    if _MOE_TOKEN_PARALLEL:
        return _wsc(x, P(None, _EXPERT_AXIS, _MODEL_AXIS, None))
    return _wsc(x, P(None, _EXPERT_AXIS, None, _MODEL_AXIS))
