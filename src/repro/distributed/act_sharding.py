"""Activation sharding constraints (GSPMD propagation anchors).

Sharding propagation through scan-over-layers + remat reliably loses
the batch sharding of activations (the recompute path resolves to
replicated), which silently turns a 16-way batch-parallel program into
a replicated one. The launcher/dry-run installs the mesh's batch axes
here; the model code calls ``constrain_*`` at layer boundaries, which
is a no-op when nothing is installed (tests, single-device engine).
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: tuple | None = None
_MODEL_AXIS: str | None = None
_EXPERT_AXIS: str | None = None
_MODEL_SIZE: int = 1
_SEQ_SHARD: bool = False
_MOE_TOKEN_PARALLEL: bool = False
_MESH = None


@contextlib.contextmanager
def activation_sharding(batch_axes: tuple, model_axis: str = "model",
                        expert_axis: str = "data", model_size: int = 1,
                        seq_shard_boundary: bool = False,
                        moe_token_parallel: bool = False,
                        mesh=None):
    """``seq_shard_boundary``: shard the inter-layer residual stream's
    sequence dim over the model axis (Megatron-style sequence
    parallelism). This is what bounds remat memory: the saved per-layer
    carries shrink by the TP degree (25 GB -> 1.6 GB per chip for a
    14B model at 64k tokens/chip); XLA re-gathers S where attention/MLP
    need it."""
    global _BATCH_AXES, _MODEL_AXIS, _EXPERT_AXIS, _MODEL_SIZE, \
        _SEQ_SHARD, _MOE_TOKEN_PARALLEL, _MESH
    prev = (_BATCH_AXES, _MODEL_AXIS, _EXPERT_AXIS, _MODEL_SIZE,
            _SEQ_SHARD, _MOE_TOKEN_PARALLEL, _MESH)
    _BATCH_AXES, _MODEL_AXIS, _EXPERT_AXIS = (batch_axes, model_axis,
                                              expert_axis)
    _MODEL_SIZE, _SEQ_SHARD = model_size, seq_shard_boundary
    _MOE_TOKEN_PARALLEL = moe_token_parallel
    _MESH = mesh
    try:
        yield
    finally:
        (_BATCH_AXES, _MODEL_AXIS, _EXPERT_AXIS, _MODEL_SIZE,
         _SEQ_SHARD, _MOE_TOKEN_PARALLEL, _MESH) = prev


def moe_a2a_mesh():
    """(mesh, expert_axis) when the shard_map a2a MoE should be used
    (inference under an installed mesh), else None."""
    if _MOE_TOKEN_PARALLEL and _MESH is not None:
        return _MESH, _EXPERT_AXIS
    return None


def _wsc(x, spec):
    if _BATCH_AXES is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_btd(x):
    """(B, S, D) activations: batch over data axes."""
    return _wsc(x, P(_BATCH_AXES, None, None))


def constrain_boundary(x):
    """Inter-layer residual (B, S, D): batch over data; sequence over
    the model axis when sequence-parallel boundaries are enabled."""
    if (_SEQ_SHARD and x.ndim == 3 and _MODEL_SIZE > 1
            and x.shape[1] % _MODEL_SIZE == 0):
        return _wsc(x, P(_BATCH_AXES, _MODEL_AXIS, None))
    return _wsc(x, P(_BATCH_AXES, None, None))


def constrain_bd(x):
    """(B, D) decode activations."""
    return _wsc(x, P(_BATCH_AXES, None))


def constrain_logits(x):
    """(B, S, V): batch over data, vocab over model."""
    return _wsc(x, P(_BATCH_AXES, None, _MODEL_AXIS))


def constrain_ssm_channels(x):
    """(B, S, C) SSM activations: channels over model, S *full* — the
    time recurrence is sequential in S, so sequence sharding inside the
    mixer forces pathological resharding (observed: 48 GB/layer of
    collectives on falcon train before this anchor)."""
    return _wsc(x, P(_BATCH_AXES, None, _MODEL_AXIS))


def constrain_ssm_bthp(x):
    """SSM activations (B, T, H, P): heads over the model axis."""
    return _wsc(x, P(_BATCH_AXES, None, _MODEL_AXIS, None))


def constrain_ssm_bth(x):
    """(B, T, H) per-head scalars: heads over the model axis."""
    return _wsc(x, P(_BATCH_AXES, None, _MODEL_AXIS))


def constrain_moe_groups(x):
    """Group-major MoE tensors (G, ...): groups follow batch sharding
    (the (B,S)->(G,Tg) reshape is layout-compatible, but GSPMD tends to
    replicate through it without an anchor). Skipped under sequence-
    parallel boundaries: there S carries the model-axis sharding and a
    batch-only anchor would force an S all-gather per layer."""
    if _SEQ_SHARD:
        return x
    return _wsc(x, P(_BATCH_AXES, *([None] * (x.ndim - 1))))


def constrain_moe_local(x):
    """Pre-all-to-all bucket tensor (G, E, C, D): still group-sharded
    (local to each data shard). Forcing this anchor *before* the
    expert-sharded anchor turns the reshard into a clean all-to-all —
    fused into the dispatch einsum, GSPMD falls back to all-gathering
    operands (measured 14.9 GB/layer vs ~0.5 GB for the a2a)."""
    return _wsc(x, P(_BATCH_AXES, None, None, None))


def constrain_expert_ecd(x):
    """MoE dispatch buckets (G, E, C, D): experts over the expert axis
    (the group dim gives up its batch sharding here — this reshard is
    the MoE all-to-all). In token-parallel mode (inference) the bucket
    dim C also shards over the model axis — each chip runs its experts
    on 1/TP of their tokens with *unsharded* expert FFN weights, so no
    down-projection psum exists at all (it was 10 GB wire/layer on
    qwen3-moe prefill)."""
    if _MOE_TOKEN_PARALLEL:
        return _wsc(x, P(None, _EXPERT_AXIS, _MODEL_AXIS, None))
    return _wsc(x, P(None, _EXPERT_AXIS, None, None))


def constrain_expert_ecf(x):
    """MoE hidden (G, E, C, F): experts over data; hidden over model
    (TP mode) or tokens over model (token-parallel inference)."""
    if _MOE_TOKEN_PARALLEL:
        return _wsc(x, P(None, _EXPERT_AXIS, _MODEL_AXIS, None))
    return _wsc(x, P(None, _EXPERT_AXIS, None, _MODEL_AXIS))
