"""Elastic scaling: re-mesh plans after node loss or scale events.

At 1000+ nodes, failures are routine; the recovery loop (DESIGN §5)
needs a *plan*: given the surviving chip count, pick the largest valid
mesh that keeps the model axis intact (TP degree is fixed by the
weight sharding; shrinking it would change every weight shard) and
shrinks the data/pod axes, then rescale the data pipeline.

Checkpoints are logical (training/checkpoint.py), so restoring onto the
new mesh is just providing new shardings — no reshard pass needed.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax


@dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axes: tuple
    n_devices: int
    global_batch: int

    def make(self):
        return jax.make_mesh(self.shape, self.axes)


def plan_after_failure(current_shape: tuple, axes: tuple,
                       surviving_devices: int, global_batch: int,
                       tokens_per_device_min: int = 1) -> MeshPlan:
    """Largest mesh ≤ surviving_devices that preserves the model axis.

    Only the leading data-like axes shrink (pod first, then data). The
    global batch is kept when it still divides the new data extent,
    else reduced to the largest multiple that fits (the optimizer's
    schedule is step-based, so batch changes are logged, not fatal).
    """
    model = current_shape[-1]
    if surviving_devices < model:
        raise ValueError(
            f"cannot keep TP={model} with {surviving_devices} devices")
    data_total = surviving_devices // model
    if len(current_shape) == 3:
        pod = min(current_shape[0], max(1, data_total
                                        // current_shape[1]))
        data = data_total // pod
        shape = (pod, data, model)
    else:
        shape = (data_total, model)
    n_dev = 1
    for s in shape:
        n_dev *= s
    data_extent = n_dev // model
    batch = global_batch
    if batch % data_extent != 0:
        batch = max(data_extent,
                    (global_batch // data_extent) * data_extent)
    return MeshPlan(shape=shape, axes=axes[-len(shape):], n_devices=n_dev,
                    global_batch=batch)


def scale_out_plan(current_shape: tuple, axes: tuple, new_devices: int,
                   global_batch: int) -> MeshPlan:
    """Grow the data axes when capacity arrives (same constraints)."""
    return plan_after_failure(current_shape, axes, new_devices,
                              global_batch)
