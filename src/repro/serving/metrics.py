"""Serving metrics: TTFT / TBT / E2E percentiles, slowdown, SLO attainment.

Definitions follow the paper (§2): TTFT = arrival → first output token
(queueing + adapter load + prefill); TBT = time between subsequent
tokens; throughput = highest load sustained without violating the TTFT
SLO; SLO = 5× the low-load latency (§2, §5.1). Slowdown = response time
/ isolated response time (Fig. 7).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


#: Registry of every gauge a serving tier can export through
#: ``metrics()`` (``cache_stats`` / ``sched_stats``) or the gateway's
#: ``_gauges()``: name -> (unit, one-line meaning). docs/OPERATIONS.md
#: documents each entry with its healthy range and the overload symptom
#: it diagnoses; ``tests/test_gateway.py`` asserts the doc covers every
#: name here and that live systems emit no gauge missing from this
#: table — add the gauge HERE and to the doc when you add one to a
#: tier.
GAUGES: dict = {
    # Adapter cache (all tiers).
    "hit_rate": ("ratio", "adapter-cache hit fraction"),
    "hits": ("count", "adapter-cache hits"),
    "misses": ("count", "adapter-cache misses (each one is an H2D load)"),
    "evictions": ("count", "adapters evicted from device"),
    "gb_loaded": ("GB", "total adapter bytes moved host->device"),
    "link_busy_frac": ("ratio", "PCIe/NVLink busy fraction (sim tier)"),
    # Scheduler / engine control plane.
    "bypassed": ("count", "requests admitted via the bypass lane"),
    "squashed": ("count", "bypassers squashed on misprediction"),
    "queues": ("count", "Chameleon MLQ queue count after adaptation"),
    "deferred": ("count", "placements deferred while the adapter loads"),
    "cancelled": ("count", "requests cancelled before completion"),
    "expired": ("count", "requests that hit their deadline"),
    "async_loads": ("count", "adapter loads overlapped with decode"),
    "pressure": ("requests", "scheduler backlog + in-flight (routing signal)"),
    "batch_occupancy_mean": ("ratio", "mean continuous-batch slot occupancy"),
    # Paged KV plane.
    "kv_pages_used": ("pages", "KV pages currently allocated"),
    "kv_pages_total": ("pages", "KV pages in the pool"),
    "kv_page_util": ("ratio", "KV page utilisation"),
    "preempted": ("count", "requests preempted out of pages"),
    # Prefix cache.
    "prefix_hit_rate": ("ratio", "prompt tokens served from the radix tree"),
    "prefix_hit_tokens": ("tokens", "prompt tokens reused"),
    "prefix_lookup_tokens": ("tokens", "prompt tokens looked up"),
    "prefix_hits": ("count", "requests with a non-empty prefix match"),
    "prefix_shared_pages": ("pages", "pages shared via refcounting"),
    "prefix_nodes": ("count", "radix-tree nodes resident"),
    "prefix_evictions": ("count", "radix-tree leaves evicted"),
    "cow_forks": ("count", "copy-on-write page forks"),
    # Sharded engine.
    "mesh_shape": ("(data,model)", "serving mesh shape"),
    "n_devices": ("count", "devices in the serving mesh"),
    "per_shard_pages_used": ("pages", "KV pages used per data shard"),
    "per_shard_pages_total": ("pages", "KV pages per data shard"),
    "per_shard_lora_slot_bytes": ("bytes", "LoRA arena bytes on one device"),
    "collective_frac": ("ratio", "wall-time fraction spent in collectives"),
    "collective_dispatches": ("count", "jit dispatches containing collectives"),
    # Disaggregated prefill/decode (serving/disagg.py). The first five
    # are per-engine (chunked prefill + KV handoff endpoints); the rest
    # are cluster-level, injected by DisaggCluster.metrics().
    "chunked_prefills": ("count", "prefill chunks executed (chunked prefill)"),
    "kv_exports": ("count", "requests whose KV was exported (handoff src)"),
    "kv_imports": ("count", "requests whose KV was imported (handoff dst)"),
    "kv_handoff_gb": ("GB", "KV bytes scattered in on the import side"),
    "migrating": ("requests", "requests currently in the MIGRATING window"),
    "prefill_nodes": ("count", "replicas currently in the prefill role"),
    "decode_nodes": ("count", "replicas currently in the decode role"),
    "spilled_prefills": ("count", "requests spilled back to decode replicas"),
    "role_rebalances": ("count", "replicas moved across roles (autoscaler)"),
    "prefill_util": ("ratio", "mean batch-slot occupancy, prefill tier"),
    "decode_util": ("ratio", "mean batch-slot occupancy, decode tier"),
    "handoffs": ("count", "KV shipments delivered prefill->decode"),
    "handoff_gb": ("GB", "KV bytes moved over the inter-replica link"),
    "handoff_wait_s": ("seconds", "mean export->import handoff latency"),
    "handoffs_inflight": ("requests", "shipments on the modeled link now"),
    "handoffs_dropped": ("count", "shipments cancelled/expired in flight"),
    # Speculative draft–verify decoding (engine, spec_decode=True).
    "spec_accept_rate": ("ratio", "draft tokens accepted by the target"),
    "spec_drafted_tokens": ("tokens", "draft tokens proposed"),
    "spec_accepted_tokens": ("tokens", "draft tokens verified accepted"),
    "spec_draft_dispatches": ("count", "draft-model forward passes"),
    "spec_verify_dispatches": ("count", "multi-token target verifies"),
    "spec_dispatches": ("count", "fused speculative blocks launched"),
    "spec_k_eff": ("tokens", "current EWMA-adapted draft length"),
    # Gateway (serving/gateway.py).
    "gw_submitted": ("count", "requests submitted through the gateway"),
    "gw_admitted": ("count", "requests admitted (incl. degraded)"),
    "gw_rejected": ("count", "requests refused by admission control"),
    "gw_degraded": ("count", "requests admitted with a reduced max_new_tokens"),
    "gw_queued": ("requests", "requests currently held in gateway lanes"),
    "gw_inflight": ("requests", "requests dispatched into the wrapped tier"),
    "gw_reject_rate": ("ratio", "rejected / submitted"),
    "gw_degrade_rate": ("ratio", "degraded / submitted"),
    "gw_queue_wait_est_s": ("seconds", "current backlog drain estimate"),
}


@dataclass
class RequestRecord:
    req_id: int
    adapter_id: int
    rank: int
    input_len: int
    output_len: int
    arrival: float
    ttft: float
    e2e: float
    tbt_mean: float
    tbt_p99: float
    slowdown: float
    squashes: int = 0
    bypassed: bool = False
    # Latency breakdown (TTFT = queue_wait + load_wait + prefill time).
    queue_wait: float = 0.0        # arrival -> first admission
    load_wait: float = 0.0         # stalled on the adapter H2D transfer


@dataclass
class RunMetrics:
    records: list[RequestRecord] = field(default_factory=list)
    horizon: float = 0.0
    n_submitted: int = 0
    cache_stats: dict = field(default_factory=dict)
    sched_stats: dict = field(default_factory=dict)

    # ------------------------------------------------------------------
    def _arr(self, attr: str) -> np.ndarray:
        return np.array([getattr(r, attr) for r in self.records],
                        dtype=np.float64)

    def percentile(self, attr: str, q: float) -> float:
        a = self._arr(attr)
        return float(np.percentile(a, q)) if len(a) else float("nan")

    def p99_ttft(self) -> float:
        return self.percentile("ttft", 99)

    def p50_ttft(self) -> float:
        return self.percentile("ttft", 50)

    def p99_tbt(self) -> float:
        a = self._arr("tbt_p99")
        return float(np.percentile(a, 99)) if len(a) else float("nan")

    def p99_slowdown(self) -> float:
        return self.percentile("slowdown", 99)

    def completed(self) -> int:
        return len(self.records)

    def goodput_tokens_per_s(self) -> float:
        if self.horizon <= 0:
            return 0.0
        tok = sum(r.input_len + r.output_len for r in self.records)
        return tok / self.horizon

    def slo_attainment(self, ttft_slo: float) -> float:
        a = self._arr("ttft")
        if not len(a):
            return 0.0
        return float((a <= ttft_slo).mean())

    def violates_slo(self, ttft_slo: float, percentile: float = 99.0) -> bool:
        return self.percentile("ttft", percentile) > ttft_slo

    def per_rank_p99_ttft(self) -> dict[int, float]:
        out: dict[int, float] = {}
        ranks = sorted({r.rank for r in self.records})
        for rk in ranks:
            vals = [r.ttft for r in self.records if r.rank == rk]
            out[rk] = float(np.percentile(vals, 99)) if vals else float("nan")
        return out

    def timeline_p99_ttft(self, bucket_s: float = 10.0,
                          ) -> list[tuple[float, float]]:
        """(bucket_end_time, p99 TTFT of requests arriving in bucket)."""
        if not self.records:
            return []
        out = []
        t_max = max(r.arrival for r in self.records)
        edges = np.arange(0.0, t_max + bucket_s, bucket_s)
        for lo, hi in zip(edges[:-1], edges[1:]):
            vals = [r.ttft for r in self.records if lo <= r.arrival < hi]
            if vals:
                out.append((float(hi), float(np.percentile(vals, 99))))
        return out

    def summary(self) -> dict:
        return {
            "completed": self.completed(),
            "submitted": self.n_submitted,
            "p50_ttft": self.p50_ttft(),
            "p99_ttft": self.p99_ttft(),
            "p99_tbt": self.p99_tbt(),
            "p99_slowdown": self.p99_slowdown(),
            "goodput_tok_s": self.goodput_tokens_per_s(),
            **{f"cache_{k}": v for k, v in self.cache_stats.items()},
            **{f"sched_{k}": v for k, v in self.sched_stats.items()},
        }


def merge_metrics(per_node: list[RunMetrics],
                  n_submitted: int | None = None) -> RunMetrics:
    """Aggregate per-node RunMetrics into one cluster-level view.

    Records concatenate (percentiles are then cluster-wide over every
    request), the horizon is the slowest node's, and cache/scheduler
    counters sum — with hit_rate recomputed from the summed hit/miss
    counts rather than averaged (nodes see different traffic volumes),
    and non-additive gauges (busy fractions, pressure) reported as the
    worst node's value instead of a meaningless sum.
    """
    # Non-additive gauges: report the worst node instead of a sum.
    # kv_page_util / batch_occupancy_mean are fractions of per-node
    # capacity; kv_pages_used/total and preempted counts stay additive.
    # collective_frac (sharded engines) is a wall-time fraction.
    ratio_gauges = ("link_busy_frac", "pressure", "kv_page_util",
                    "batch_occupancy_mean", "prefix_hit_rate",
                    "collective_frac", "gw_reject_rate",
                    "gw_degrade_rate", "gw_queue_wait_est_s",
                    "spec_accept_rate", "spec_k_eff")
    merged = RunMetrics(
        n_submitted=(n_submitted if n_submitted is not None
                     else sum(m.n_submitted for m in per_node)))
    hits = misses = 0
    summed: dict[str, float] = {}
    sched: dict[str, float] = {}
    for m in per_node:
        merged.records.extend(m.records)
        merged.horizon = max(merged.horizon, m.horizon)
        hits += int(m.cache_stats.get("hits", 0))
        misses += int(m.cache_stats.get("misses", 0))
        for k, v in m.cache_stats.items():
            if k in ("hit_rate", "hits", "misses") \
                    or not isinstance(v, (int, float)):
                continue
            summed[k] = (max(summed.get(k, 0), v) if k in ratio_gauges
                         else summed.get(k, 0) + v)
        for k, v in m.sched_stats.items():
            if not isinstance(v, (int, float)):
                continue
            sched[k] = (max(sched.get(k, 0), v) if k in ratio_gauges
                        else sched.get(k, 0) + v)
    merged.cache_stats = {
        "hit_rate": hits / max(hits + misses, 1),
        "hits": hits, "misses": misses, **summed}
    merged.sched_stats = sched
    return merged


def slo_from_lowload(cost_model, trace_like, multiplier: float = 5.0,
                     stat: float = 99.0) -> tuple[float, float]:
    """Paper SLO: 5× the low-load TTFT and TBT.

    Computed analytically from the cost model over the trace's request
    population (requests executed alone, warm adapter for TBT, cold for
    TTFT). ``stat`` picks the low-load reference percentile: the SLO is
    compared against *P99* TTFT (Fig. 10), so the reference must be the
    low-load P99 — a 5×-mean SLO would sit below the isolated latency
    of the largest requests and be unattainable at any load.
    """
    reqs = trace_like.requests if hasattr(trace_like, "requests") else trace_like
    ttfts, tbts = [], []
    for r in reqs[: min(len(reqs), 512)]:
        rank = getattr(r, "rank", None)
        if rank is None:
            rank = 32
        ttfts.append(cost_model.isolated_ttft(r.input_len, rank))
        tbts.append(cost_model.decode_time(1, r.input_len, [rank]))
    return (multiplier * float(np.percentile(ttfts, stat)),
            multiplier * float(np.percentile(tbts, stat)))
