"""The unified serving surface: request handles and the ServingSystem
protocol (DESIGN §3).

Every tier — the real JAX engine (``ChameleonEngine``, single- or
mesh-sharded), the real-engine cluster (``EngineCluster``), the
discrete-event simulator (``NodeSimulator``) and its cluster
(``Cluster``), plus the multi-tenant ``Gateway`` that wraps any of
them — serves requests through the same four verbs:

    handle = system.submit(req, sampling=..., on_token=..., ttl=...)
    system.step()            # one iteration (prefill admission + decode)
    system.busy()            # work queued or in flight?
    system.drain()           # run the queue dry

``submit`` is non-blocking and returns a ``RequestHandle`` — the
caller's end of the request: streamed tokens (iterator and/or a
per-token callback), lifecycle state, ``cancel()``, and a ``result()``
carrying tokens plus the latency breakdown (queue wait, adapter-load
wait, TTFT, TBT, E2E).

Lifecycle (see ``core.request.RequestState``):

    QUEUED ──> LOADING ──> RUNNING ──> FINISHED
       │          │           │
       │          │           ├──────> EXPIRED    (deadline passed)
       └──────────┴─────────────────> CANCELLED  (handle.cancel())

REJECTED is a fourth terminal state produced only by gateway admission
control — the request never reaches a scheduler, but its handle still
resolves (with a ``decision`` trace and ``retry_after`` hint).

Every tier is single-threaded and driven by ``step()``; a handle
therefore *pumps* its owning system while the caller blocks on
``stream()`` / ``result()``. Token delivery is position-keyed so a
squash/requeue that re-executes a request's prefix never re-streams
tokens the caller already consumed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Callable, Iterator, Optional, Protocol,
                    runtime_checkable)

import numpy as np

from repro.core.request import Request, RequestState
from repro.core.sampling import SamplingParams

#: One drain bound for every tier. ``drain()`` exits as soon as the
#: system goes idle, so the cap only matters as a hang backstop — but
#: the historical split (DES cluster 2M, engine cluster 10k) meant a
#: long trace could silently under-drain the engine tier and report a
#: truncated run as complete. A DES step is microseconds and an engine
#: step milliseconds; 2M bounds both at minutes of wall time while
#: being unreachable by any healthy workload in this repo.
DRAIN_MAX_STEPS = 2_000_000


@dataclass
class RequestResult:
    """Terminal snapshot of one request: tokens + latency breakdown."""

    req_id: int
    adapter_id: int
    state: RequestState
    tokens: list = field(default_factory=list)
    # Latency breakdown (seconds; None where the phase never happened).
    queue_wait: Optional[float] = None      # arrival -> first admission
    adapter_load_wait: float = 0.0          # stalled on the H2D transfer
    ttft: Optional[float] = None            # arrival -> first token
    e2e: Optional[float] = None             # arrival -> terminal
    tbts: list = field(default_factory=list)
    squashes: int = 0

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)

    @property
    def finished(self) -> bool:
        return self.state is RequestState.FINISHED

    @property
    def tbt_mean(self) -> float:
        return float(np.mean(self.tbts)) if self.tbts else 0.0

    @property
    def tbt_p99(self) -> float:
        return float(np.percentile(self.tbts, 99)) if self.tbts else 0.0


class RequestHandle:
    """The caller's end of a submitted request.

    Created by ``ServingSystem.submit``; the system pushes tokens into
    it as they are produced (``_push``), the caller reads them via the
    ``tokens`` buffer, the blocking ``stream()`` iterator, or the
    ``on_token`` callback supplied at submit time.
    """

    def __init__(self, req: Request, system: "ServingSystem",
                 on_token: Optional[Callable[[int], None]] = None):
        self.req = req
        self._system = system
        self._on_token = on_token
        self._tokens: list[int] = []
        #: Cluster tiers set this to the replica index the request was
        #: routed to (subsumes the node index the old cluster ``submit``
        #: returned); single-node systems leave it None.
        self.node: Optional[int] = None
        #: Gateway tiers attach the admission decision
        #: (``serving.gateway.GatewayDecision``) here, and on rejection
        #: the suggested retry-after seconds; None everywhere else.
        self.decision = None
        self.retry_after: Optional[float] = None

    # -- identity / state ------------------------------------------------
    @property
    def req_id(self) -> int:
        return self.req.req_id

    @property
    def adapter_id(self) -> int:
        return self.req.adapter_id

    @property
    def state(self) -> RequestState:
        return self.req.state

    @property
    def done(self) -> bool:
        """Terminal: FINISHED, CANCELLED, EXPIRED or REJECTED."""
        return self.req.terminal

    # -- token delivery (system side) ------------------------------------
    def _push(self, pos: int, token: int) -> None:
        """Deliver the token at position ``pos`` (0-based over the
        request's output). Positions already delivered are dropped —
        that is what keeps a squashed request's re-executed prefix from
        re-streaming."""
        if pos < len(self._tokens):
            return
        self._tokens.append(int(token))
        if self._on_token is not None:
            self._on_token(int(token))

    # -- consumption (caller side) ---------------------------------------
    @property
    def tokens(self) -> list[int]:
        """Tokens streamed so far (a copy; safe to mutate)."""
        return list(self._tokens)

    def stream(self, max_steps: int = 100_000) -> Iterator[int]:
        """Yield tokens as they are produced, pumping the owning system
        (``system.step()``) while none are buffered. Ends when the
        request reaches a terminal state (or ``max_steps`` elapses,
        which raises — a stuck system should be loud)."""
        served = 0
        steps = 0
        while True:
            while served < len(self._tokens):
                yield self._tokens[served]
                served += 1
            if self.done:
                return
            if steps >= max_steps:
                raise TimeoutError(
                    f"request {self.req_id} still {self.state.value} "
                    f"after {max_steps} steps")
            self._system.step()
            steps += 1

    def __iter__(self) -> Iterator[int]:
        return self.stream()

    def cancel(self) -> bool:
        """Request cancellation. Queued/LOADING requests cancel
        immediately; RUNNING ones at the next step boundary (a jit'd
        decode cannot be interrupted mid-call). Returns True if the
        request will terminate as CANCELLED, False if it already
        reached a terminal state."""
        return self._system.cancel(self)

    def result(self, max_steps: int = 100_000) -> RequestResult:
        """Block (pumping the system) until terminal; return the final
        tokens and latency breakdown."""
        for _ in self.stream(max_steps=max_steps):
            pass
        req = self.req
        return RequestResult(
            req_id=req.req_id, adapter_id=req.adapter_id,
            state=req.state, tokens=self.tokens,
            queue_wait=req.queue_wait(),
            adapter_load_wait=req.adapter_load_wait,
            ttft=req.ttft(), e2e=req.e2e(),
            tbts=list(req.preserved_tbts), squashes=req.squash_count)


@runtime_checkable
class ServingSystem(Protocol):
    """What every serving tier implements (DESIGN §3).

    ``metrics()`` returns a ``RunMetrics`` on single-node systems and a
    ``(merged, per_node)`` tuple on clusters; everything else is
    uniform. ``build_system`` in ``serving.systems`` is the factory.
    """

    def submit(self, req: Request, *,
               sampling: Optional[SamplingParams] = None,
               on_token: Optional[Callable[[int], None]] = None,
               ttl: Optional[float] = None) -> RequestHandle: ...

    def step(self) -> None: ...

    def busy(self) -> bool: ...

    def drain(self, max_steps: int = DRAIN_MAX_STEPS) -> None: ...

    def cancel(self, handle: RequestHandle) -> bool: ...

    def queue_pressure(self) -> float: ...

    def stats(self) -> dict: ...

    def metrics(self): ...


def prepare_request(req: Request, system: "ServingSystem", now: float,
                    sampling: Optional[SamplingParams],
                    on_token: Optional[Callable[[int], None]],
                    ttl: Optional[float]) -> RequestHandle:
    """Shared submit-side plumbing: attach sampling, stamp the arrival,
    arm the deadline, build the handle. Systems call this before
    enqueueing with their scheduler."""
    if sampling is not None:
        req.sampling = sampling
    # Interactive submits (the default arrival_time=0.0) arrive *now*
    # on the system's clock — without this, queue_wait/TTFT/E2E would
    # be measured from the clock epoch (e.g. engine construction + jit
    # compiles), not from submission. Trace replays carry explicit
    # arrival times and are untouched.
    if req.arrival_time == 0.0 and now > 0.0:
        req.arrival_time = now
    if ttl is not None and req.deadline is None:
        req.deadline = now + ttl
    return RequestHandle(req, system, on_token=on_token)
