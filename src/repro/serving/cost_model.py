"""Analytic per-iteration latency model for the serving simulator.

Structure (what the DES charges time for):

- ``prefill(tokens, ranks)``   — compute-bound: base FLOPs at mfu_prefill
                                 + decoupled LoRA compute at eff_adapter
                                 + per-layer adapter launch overhead.
- ``decode(batch, kv_tokens)`` — memory-bound: one full weight sweep +
                                 KV reads at hbm efficiency + LoRA BGMV
                                 per request + fixed iteration overhead.
- ``adapter_load(bytes)``      — host→device link at link_gbps
                                 (FIFO-contended in the simulator).

Calibration targets (paper Fig. 2, Llama-7B on A40, medium request):
rank-128 adapter load ≈ 17.5 % of TTFT and load+compute ≈ 60 %; decode
iteration ≈ tens of ms (TBT SLO 150 ms). The defaults below hit those
ratios; see EXPERIMENTS.md §Calibration for the verification table.

Presets: A40 (paper main), A100-80G (paper §5.5), TPU v5e (the target
platform of this reproduction — used for roofline-consistent serving
projections).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.lora import adapter_bytes


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_tflops: float          # dense bf16
    hbm_gbps: float
    link_gbps: float            # host->device effective (PCIe / DMA)
    hbm_gb: float

    @property
    def peak_flops(self) -> float:
        return self.peak_tflops * 1e12

    @property
    def hbm_bps(self) -> float:
        return self.hbm_gbps * 1e9

    @property
    def link_bps(self) -> float:
        return self.link_gbps * 1e9


A40 = HardwareSpec("a40", peak_tflops=149.7, hbm_gbps=696.0,
                   link_gbps=25.0, hbm_gb=48.0)
A100_80G = HardwareSpec("a100-80g", peak_tflops=311.8, hbm_gbps=2039.0,
                        link_gbps=20.0, hbm_gb=80.0)
TPU_V5E = HardwareSpec("tpu-v5e", peak_tflops=197.0, hbm_gbps=819.0,
                       link_gbps=100.0, hbm_gb=16.0)

HW_PRESETS = {h.name: h for h in (A40, A100_80G, TPU_V5E)}


@dataclass(frozen=True)
class ModelSpec:
    name: str
    n_params: float             # total parameters
    n_layers: int
    d_model: int
    n_kv_heads: int
    head_dim: int
    n_proj_adapted: int = 4
    dtype_bytes: int = 2

    @property
    def param_bytes(self) -> float:
        return self.n_params * self.dtype_bytes

    @property
    def kv_bytes_per_token(self) -> int:
        return (2 * self.n_layers * self.n_kv_heads * self.head_dim
                * self.dtype_bytes)


LLAMA_7B = ModelSpec("llama-7b", n_params=6.74e9, n_layers=32, d_model=4096,
                     n_kv_heads=32, head_dim=128)
LLAMA_13B = ModelSpec("llama-13b", n_params=13.0e9, n_layers=40, d_model=5120,
                      n_kv_heads=40, head_dim=128)
LLAMA_30B = ModelSpec("llama-30b", n_params=32.5e9, n_layers=60, d_model=6656,
                      n_kv_heads=52, head_dim=128)

MODEL_PRESETS = {m.name: m for m in (LLAMA_7B, LLAMA_13B, LLAMA_30B)}


@dataclass(frozen=True)
class CostModel:
    hw: HardwareSpec = A40
    model: ModelSpec = LLAMA_7B
    mfu_prefill: float = 0.75       # base-model prefill efficiency
    eff_adapter: float = 0.035      # decoupled LoRA GEMM efficiency (tiny K)
    adapter_launch_us: float = 40.0 # per layer·proj: launch + index gather
    hbm_eff: float = 0.80           # achieved fraction of HBM bandwidth
    decode_overhead_us: float = 500.0   # scheduler + kernel launches / iter
    prefill_overhead_us: float = 500.0
    bgmv_per_req_us: float = 12.0   # decode-time LoRA matvec per request
    link_latency_us: float = 150.0  # per-transfer setup cost
    per_tensor_us: float = 10.0     # S-LoRA loads adapters tensor-by-tensor:
                                    # n_layers x n_proj x 2 small H2D copies
                                    # dominate load latency (paper Fig. 2)

    # ---------------------------------------------------------------- prefill
    def prefill_time(self, seq_lens: list[int], ranks: list[int]) -> float:
        """One prefill iteration over the given requests (batched)."""
        total_tokens = sum(seq_lens)
        base_flops = 2.0 * self.model.n_params * total_tokens
        t = base_flops / (self.hw.peak_flops * self.mfu_prefill)
        # Rank padding: batched multi-adapter GEMMs (SGMV) execute every
        # request at the *largest* rank in the batch (CaraServe [25], the
        # paper's own §1 motivation) — smaller-rank requests pay the
        # padded cost.
        pad_rank = max(ranks) if ranks else 0
        for s, r in zip(seq_lens, ranks):
            lora_flops = (2.0 * self.model.n_layers
                          * self.model.n_proj_adapted
                          * 2 * (2.0 * self.model.d_model * pad_rank) * s)
            t += lora_flops / (self.hw.peak_flops * self.eff_adapter)
        if ranks:
            t += (self.model.n_layers * self.model.n_proj_adapted
                  * self.adapter_launch_us * 1e-6)
        return t + self.prefill_overhead_us * 1e-6

    # ---------------------------------------------------------------- decode
    def decode_time(self, batch_size: int, kv_tokens: int,
                    ranks: list[int]) -> float:
        """One decode iteration (1 token per running request)."""
        if batch_size == 0:
            return 0.0
        bytes_moved = (self.model.param_bytes
                       + kv_tokens * self.model.kv_bytes_per_token)
        t = bytes_moved / (self.hw.hbm_bps * self.hbm_eff)
        # BGMV is rank-padded across the batch like SGMV (see prefill).
        pad_rank = max(ranks) if ranks else 16
        t += len(ranks) * (self.bgmv_per_req_us * max(1.0, pad_rank / 16.0)
                           ) * 1e-6
        return t + self.decode_overhead_us * 1e-6

    # ------------------------------------------------------------ adapter IO
    def adapter_load_time(self, rank: int) -> float:
        nbytes = adapter_bytes(rank, self.model.d_model, self.model.n_layers,
                               self.model.n_proj_adapted,
                               self.model.dtype_bytes)
        n_tensors = self.model.n_layers * self.model.n_proj_adapted * 2
        return (nbytes / self.hw.link_bps
                + n_tensors * self.per_tensor_us * 1e-6
                + self.link_latency_us * 1e-6)

    def adapter_load_bytes(self, rank: int) -> int:
        return adapter_bytes(rank, self.model.d_model, self.model.n_layers,
                             self.model.n_proj_adapted, self.model.dtype_bytes)

    # ------------------------------------------------------------- isolated
    def isolated_time(self, input_len: int, output_len: int,
                      rank: int, cold_adapter: bool = True) -> float:
        """E2E latency of the request alone on an idle node (slowdown ref).

        Closed form: decode_time(1, kv, [r]) is affine in kv, so the sum
        over kv = input+1 .. input+output-1 is an arithmetic series.
        """
        t = self.adapter_load_time(rank) if cold_adapter else 0.0
        t += self.prefill_time([input_len], [rank])
        n = max(0, output_len - 1)
        if n:
            a = (self.model.param_bytes / (self.hw.hbm_bps * self.hbm_eff)
                 + self.bgmv_per_req_us * 1e-6
                 + self.decode_overhead_us * 1e-6)
            b = self.model.kv_bytes_per_token / (self.hw.hbm_bps
                                                 * self.hbm_eff)
            kv_sum = n * input_len + n * (n + 1) // 2
            t += n * a + b * kv_sum
        return t

    def isolated_ttft(self, input_len: int, rank: int,
                      cold_adapter: bool = True) -> float:
        t = self.adapter_load_time(rank) if cold_adapter else 0.0
        return t + self.prefill_time([input_len], [rank])

    def with_hw(self, hw: HardwareSpec) -> "CostModel":
        return replace(self, hw=hw)

    def with_model(self, model: ModelSpec) -> "CostModel":
        return replace(self, model=model)
