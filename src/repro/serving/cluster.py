"""Cluster-level routing over Chameleon nodes (paper §6: node-level
Chameleon composes with cluster schedulers like Llumnix/dLoRA).

A ``Cluster`` owns N independent NodeSimulators (each with its own
pool/cache/scheduler) and a routing policy that assigns arriving
requests to nodes:

- ``round_robin``       — baseline;
- ``least_loaded``      — fewest outstanding requests;
- ``adapter_affinity``  — prefer the node where the request's adapter
  is (or was recently) resident, falling back to least-loaded when the
  affinity target is overloaded. This is the cluster policy the
  Chameleon cache makes profitable: affinity concentrates an adapter's
  requests where its weights already live, raising hit rates without
  the load-imbalance trap (the fallback bound) the paper warns about
  for dLoRA-style clustering.

The DES runs nodes independently (no cross-node migration — the paper
treats migration as out of scope) and merges metrics.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .metrics import RunMetrics
from .systems import NodeConfig, build_node
from .trace import Trace, TraceConfig, synthesize


@dataclass
class ClusterConfig:
    n_nodes: int = 4
    system: str = "chameleon"
    policy: str = "adapter_affinity"   # round_robin | least_loaded | ...
    affinity_overload_factor: float = 1.5
    node: NodeConfig = field(default_factory=NodeConfig)


class Cluster:
    def __init__(self, cfg: ClusterConfig):
        self.cfg = cfg
        self.nodes = []
        self.adapters = None
        for i in range(cfg.n_nodes):
            node_cfg = NodeConfig(**{**cfg.node.__dict__,
                                     "seed": cfg.node.seed + i})
            sim, adapters, cost = build_node(cfg.system, node_cfg)
            self.nodes.append(sim)
            self.adapters = adapters
        self._rr = 0
        self._affinity: dict[int, int] = {}     # adapter -> node hint
        self._outstanding = np.zeros(cfg.n_nodes, int)

    # ---------------------------------------------------------- routing
    def _route(self, req) -> int:
        n = self.cfg.n_nodes
        if self.cfg.policy == "round_robin":
            self._rr = (self._rr + 1) % n
            return self._rr
        if self.cfg.policy == "least_loaded":
            return int(np.argmin(self._outstanding))
        # adapter_affinity
        hint = self._affinity.get(req.adapter_id)
        least = int(np.argmin(self._outstanding))
        if hint is None:
            self._affinity[req.adapter_id] = least
            return least
        if (self._outstanding[hint]
                > self.cfg.affinity_overload_factor
                * max(1, self._outstanding[least])):
            # Affinity target overloaded: spill and move the hint
            # (dLoRA's imbalance trap, bounded).
            self._affinity[req.adapter_id] = least
            return least
        return hint

    # ------------------------------------------------------------- run
    def run(self, trace: Trace) -> tuple[RunMetrics, list[RunMetrics]]:
        """Split the trace by routing policy, run nodes, merge metrics.

        Routing decisions use arrival order with an outstanding-count
        estimate decayed by each node's mean service rate (the DES runs
        nodes independently afterwards, so the estimate mirrors what a
        real router would know: queue depths at arrival time).
        """
        per_node: list[list] = [[] for _ in range(self.cfg.n_nodes)]
        # Outstanding estimate: arrivals minus estimated completions.
        finish_heaps = [list() for _ in range(self.cfg.n_nodes)]
        import heapq
        for req in sorted(trace.requests, key=lambda r: r.arrival_time):
            for i in range(self.cfg.n_nodes):
                h = finish_heaps[i]
                while h and h[0] <= req.arrival_time:
                    heapq.heappop(h)
                    self._outstanding[i] -= 1
            node = self._route(req)
            per_node[node].append(req)
            self._outstanding[node] += 1
            est_service = 1.0 + 0.01 * req.output_len
            heapq.heappush(finish_heaps[node],
                           req.arrival_time + est_service)

        merged = RunMetrics(n_submitted=trace.n)
        node_metrics = []
        for sim, reqs in zip(self.nodes, per_node):
            sub = Trace(requests=reqs, config=trace.config)
            m = sim.run(sub)
            node_metrics.append(m)
            merged.records.extend(m.records)
            merged.horizon = max(merged.horizon, m.horizon)
        hits = sum(s.cache.stats.hits for s in self.nodes)
        misses = sum(s.cache.stats.misses for s in self.nodes)
        merged.cache_stats = {
            "hit_rate": hits / max(hits + misses, 1),
            "gb_loaded": sum(s.cache.stats.bytes_loaded
                             for s in self.nodes) / 1e9,
        }
        return merged, node_metrics


def run_cluster(policy: str, rps: float, n_nodes: int = 4,
                duration: float = 120.0, seed: int = 0,
                system: str = "chameleon"):
    cfg = ClusterConfig(n_nodes=n_nodes, system=system, policy=policy)
    cluster = Cluster(cfg)
    trace = synthesize(
        TraceConfig(rps=rps, duration_s=duration, seed=seed),
        list(cluster.adapters.values()))
    return cluster.run(trace)
