"""Cluster-level routing over Chameleon nodes (paper §6: node-level
Chameleon composes with cluster schedulers like Llumnix/dLoRA).

Two data planes share one routing brain (DESIGN §3):

- ``Cluster``        — N independent NodeSimulators (DES, calibrated
  cost model): production-scale traffic in seconds of wall time;
- ``EngineCluster``  — N real ``ChameleonEngine`` replicas (jit'd
  prefill/decode on real tokens) sharing one ``AdapterCatalog``, so
  the paper's cluster story is exercised against real batched
  execution, not only the simulator.

Routing policies (``Router``):

- ``round_robin`` / ``random``  — baselines;
- ``least_loaded``              — lowest queue-pressure signal;
- ``adapter_affinity``          — prefer a node where the request's
  adapter is (or was recently) resident; first-touch adapters place on
  the least-loaded node; a *consistent hash* (rendezvous) of the
  adapter id is the fallback whenever no load feed is available, so
  routing stays deterministic and cache-friendly even when the
  frontend cannot scrape queue depths; when the affinity target is
  overloaded relative to the least-loaded node, spill to least-loaded
  (the bounded fallback that avoids dLoRA's imbalance trap). Affinity
  is the cluster policy the Chameleon cache makes profitable: it
  raises hit rates and cuts host->device adapter traffic without
  load-imbalance pathologies.
- ``prefix_affinity``             — consistent hash of the prompt's
  first KV page of token ids, so same-preamble requests land where the
  radix prefix tree (PR 6) is warm; spills to least-loaded like
  adapter_affinity when the target is overloaded. Promptless requests
  fall back to adapter-keyed hashing.

Nodes run independently (no cross-node migration — the paper treats
migration as out of scope; the *disaggregated* cluster in
``serving/disagg.py`` relaxes exactly this, migrating each request
once, prefill→decode, over an explicit KV handoff plane) and metrics
merge via ``metrics.merge_metrics``.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import numpy as np

from .handles import DRAIN_MAX_STEPS
from .metrics import RunMetrics, merge_metrics
from .systems import NodeConfig, build_node
from .trace import Trace, TraceConfig, synthesize

POLICIES = ("round_robin", "random", "least_loaded", "adapter_affinity",
            "prefix_affinity")


def _stable_hash(key: str) -> int:
    return int.from_bytes(hashlib.md5(key.encode()).digest()[:8], "big")


def prefix_route_key(req, page_size: int = 16):
    """Routing key for ``prefix_affinity``: the prompt's first KV page
    of token ids. Two requests sharing a system prompt/few-shot
    preamble agree on this key, so a consistent hash of it lands them
    on the same replica — the one whose radix tree (PR 6) already holds
    their preamble's pages. Requests without real prompt tokens fall
    back to adapter-keyed routing (None)."""
    if req.prompt is None or len(req.prompt) == 0:
        return None
    return tuple(req.prompt[:page_size])


class Router:
    """Routing policy shared by the DES cluster and the engine cluster.

    The caller supplies, per decision, the live per-node load signal
    (queue pressure) and optionally per-node residency of the request's
    adapter; the router owns only policy state (RR counter, RNG,
    affinity hints, rendezvous hash).
    """

    def __init__(self, policy: str, n_nodes: int,
                 overload_factor: float = 1.5, seed: int = 0):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        self.policy = policy
        self.n = n_nodes
        self.overload_factor = overload_factor
        self.rng = np.random.default_rng(seed)
        self._rr = 0
        self._hint: dict[int, int] = {}         # adapter -> last node

    def _hash_key(self, key: str, nodes=None) -> int:
        """Rendezvous (highest-random-weight) hash: deterministic,
        uniform, and adding/removing a node only remaps ~1/N keys."""
        nodes = range(self.n) if nodes is None else nodes
        return max(nodes, key=lambda nd: _stable_hash(f"{key}:n{nd}"))

    def _hash_node(self, adapter_id: int, nodes=None) -> int:
        return self._hash_key(f"a{adapter_id}", nodes)

    def route(self, adapter_id: int, loads=None,
              resident=None, prefix_key=None) -> int:
        """Pick a node.

        ``loads``: per-node queue-pressure signal, or None when the
        frontend has no load feed (then affinity degrades to pure
        consistent hashing — still deterministic and cache-friendly);
        ``resident``: optional per-node bool, adapter currently cached;
        ``prefix_key``: ``prefix_route_key(req)`` output, consumed only
        by the ``prefix_affinity`` policy.
        """
        if self.policy == "round_robin":
            node = self._rr
            self._rr = (self._rr + 1) % self.n
            return node
        if self.policy == "random":
            return int(self.rng.integers(0, self.n))
        if loads is not None:
            loads = np.asarray(loads, dtype=float)
            least = int(np.argmin(loads))
        elif self.policy == "least_loaded":
            raise ValueError("least_loaded routing needs a load signal")
        # adapter_affinity: live residency beats the stale hint beats
        # load-based (or hash-based, without a load feed) placement.
        if self.policy == "least_loaded":
            return least
        if self.policy == "prefix_affinity":
            # Consistent hash of the prompt's first page of token ids:
            # same-preamble requests converge on the replica whose radix
            # tree already holds their prefix pages, so the suffix-only
            # prefill (PR 6) actually fires cluster-wide. Promptless
            # requests degrade to adapter-keyed hashing; an overloaded
            # target spills to least-loaded exactly like
            # adapter_affinity (warmth is worthless behind a deep queue).
            target = (self._hash_key(f"p{_stable_hash(repr(prefix_key))}")
                      if prefix_key is not None
                      else self._hash_node(adapter_id))
            if loads is not None and loads[target] \
                    > self.overload_factor * max(1.0, loads[least]):
                target = least
            return target
        target = None
        if resident is not None:
            res_nodes = [i for i, r in enumerate(resident) if r]
            if res_nodes:
                target = (min(res_nodes, key=lambda i: loads[i])
                          if loads is not None
                          else self._hash_node(adapter_id, res_nodes))
        if target is None:
            target = self._hint.get(adapter_id)
        if target is None:
            # First touch: the adapter is resident nowhere, so there is
            # no locality to honour — place by load when we can see it,
            # by consistent hash when we cannot.
            target = least if loads is not None \
                else self._hash_node(adapter_id)
        if loads is not None and loads[target] \
                > self.overload_factor * max(1.0, loads[least]):
            # Affinity target overloaded: spill and move the hint
            # (dLoRA's imbalance trap, bounded).
            target = least
        self._hint[adapter_id] = target
        return target


# ===================================================================
# Simulator-backed cluster (DES nodes, calibrated cost model)
# ===================================================================
@dataclass
class ClusterConfig:
    n_nodes: int = 4
    system: str = "chameleon"
    policy: str = "adapter_affinity"   # see POLICIES
    affinity_overload_factor: float = 1.5
    node: NodeConfig = field(default_factory=NodeConfig)


class Cluster:
    def __init__(self, cfg: ClusterConfig):
        self.cfg = cfg
        self.nodes = []
        self.adapters = None
        for i in range(cfg.n_nodes):
            node_cfg = NodeConfig(**{**cfg.node.__dict__,
                                     "seed": cfg.node.seed + i})
            sim, adapters, cost = build_node(cfg.system, node_cfg)
            self.nodes.append(sim)
            self.adapters = adapters
        self.router = Router(cfg.policy, cfg.n_nodes,
                             cfg.affinity_overload_factor,
                             seed=cfg.node.seed)
        self._outstanding = np.zeros(cfg.n_nodes, int)
        self.n_submitted = 0

    # ----------------------------------------------- serving surface
    # The DES cluster serves the same ServingSystem protocol as
    # EngineCluster: submit routes on live queue pressure + residency
    # and returns a handle; step advances every node's virtual time.
    def submit(self, req, *, sampling=None, on_token=None, ttl=None):
        loads = [sim.queue_pressure() for sim in self.nodes]
        resident = [sim.cache.resident(req.adapter_id)
                    for sim in self.nodes]
        node = self.router.route(req.adapter_id, loads, resident,
                                 prefix_key=prefix_route_key(req))
        handle = self.nodes[node].submit(
            req, sampling=sampling, on_token=on_token, ttl=ttl)
        handle.node = node
        handle._system = self
        self.n_submitted += 1
        return handle

    def cancel(self, handle) -> bool:
        if handle.node is None:
            return False
        return self.nodes[handle.node].cancel(handle)

    def step(self) -> None:
        for sim in self.nodes:
            if sim.busy():
                sim.step()

    def busy(self) -> bool:
        return any(sim.busy() for sim in self.nodes)

    def drain(self, max_steps: int = DRAIN_MAX_STEPS) -> None:
        for _ in range(max_steps):
            if not self.busy():
                break
            self.step()

    def queue_pressure(self) -> float:
        return float(sum(sim.queue_pressure() for sim in self.nodes))

    def stats(self) -> dict:
        return {"per_node": [sim.stats() for sim in self.nodes]}

    def metrics(self) -> tuple[RunMetrics, list[RunMetrics]]:
        per_node = [sim.metrics() for sim in self.nodes]
        merged = merge_metrics(per_node,
                               n_submitted=self.n_submitted or None)
        return merged, per_node

    # ------------------------------------------------------------- run
    def run(self, trace: Trace) -> tuple[RunMetrics, list[RunMetrics]]:
        """Split the trace by routing policy, run nodes, merge metrics.

        Routing decisions use arrival order with an outstanding-count
        estimate decayed by each node's mean service rate (the DES runs
        nodes independently afterwards, so the estimate mirrors what a
        real router would know: queue depths at arrival time).
        """
        per_node: list[list] = [[] for _ in range(self.cfg.n_nodes)]
        # Outstanding estimate: arrivals minus estimated completions.
        finish_heaps = [list() for _ in range(self.cfg.n_nodes)]
        import heapq
        for req in sorted(trace.requests, key=lambda r: r.arrival_time):
            for i in range(self.cfg.n_nodes):
                h = finish_heaps[i]
                while h and h[0] <= req.arrival_time:
                    heapq.heappop(h)
                    self._outstanding[i] -= 1
            node = self.router.route(req.adapter_id, self._outstanding,
                                     prefix_key=prefix_route_key(req))
            per_node[node].append(req)
            self._outstanding[node] += 1
            est_service = 1.0 + 0.01 * req.output_len
            heapq.heappush(finish_heaps[node],
                           req.arrival_time + est_service)

        node_metrics = []
        for sim, reqs in zip(self.nodes, per_node):
            sub = Trace(requests=reqs, config=trace.config)
            node_metrics.append(sim.run(sub))
        merged = merge_metrics(node_metrics, n_submitted=trace.n)
        return merged, node_metrics


def run_cluster(policy: str, rps: float, n_nodes: int = 4,
                duration: float = 120.0, seed: int = 0,
                system: str = "chameleon"):
    cfg = ClusterConfig(n_nodes=n_nodes, system=system, policy=policy)
    cluster = Cluster(cfg)
    trace = synthesize(
        TraceConfig(rps=rps, duration_s=duration, seed=seed),
        list(cluster.adapters.values()))
    return cluster.run(trace)


# ===================================================================
# Real-engine cluster (N ChameleonEngine replicas, shared catalog)
# ===================================================================
@dataclass
class EngineClusterConfig:
    n_engines: int = 2
    system: str = "chameleon"          # see systems.ENGINE_SYSTEMS
    policy: str = "adapter_affinity"
    affinity_overload_factor: float = 1.5
    seed: int = 0


class _SharedClock:
    """Resettable monotonic clock shared by every replica in a cluster."""

    def __init__(self):
        import time as _time
        self._time = _time
        self.t0 = _time.monotonic()

    def reset(self) -> None:
        self.t0 = self._time.monotonic()

    def __call__(self) -> float:
        return self._time.monotonic() - self.t0


class EngineCluster:
    """N real JAX engines behind one router, one shared AdapterCatalog.

    Engines share host-side adapter weights (the catalog) and a wall
    clock, but own private device state — KV caches, adapter-slot
    buffers, pool/cache/scheduler — exactly like replicas on separate
    accelerators. The router sees live queue pressure and adapter
    residency, the signals a real cluster frontend would scrape.
    """

    def __init__(self, cfg, params, ecfg=None, ccfg=None):
        from .engine import AdapterCatalog, EngineConfig
        from .systems import build_engine

        self.ccfg = ccfg or EngineClusterConfig()
        self.ecfg = ecfg or EngineConfig()
        if self.ecfg.mesh_shape is not None:
            # N sharded replicas need N x mesh.size devices' worth of
            # hardware — reject over-subscription up front instead of
            # letting replica 2 OOM replica 1's HBM. (Single-device
            # replicas deliberately skip this: co-locating CPU replicas
            # on one host device is the normal CI topology.)
            import jax as _jax
            d, m = self.ecfg.mesh_shape
            need = self.ccfg.n_engines * d * m
            have = len(_jax.devices())
            if need > have:
                raise ValueError(
                    f"EngineCluster: {self.ccfg.n_engines} replicas x "
                    f"mesh {tuple(self.ecfg.mesh_shape)} need {need} "
                    f"devices, only {have} available")
        self.catalog = AdapterCatalog(cfg, self.ecfg.n_adapters,
                                      self.ecfg.r_max,
                                      seed=self.ccfg.seed)
        self._clock = _SharedClock()
        self.engines = [
            build_engine(self.ccfg.system, cfg, params, self.ecfg,
                         catalog=self.catalog, clock=self._clock)
            for _ in range(self.ccfg.n_engines)]
        self.router = Router(self.ccfg.policy, self.ccfg.n_engines,
                             self.ccfg.affinity_overload_factor,
                             seed=self.ccfg.seed)
        self.routed = np.zeros(self.ccfg.n_engines, int)
        self.n_submitted = 0

    def now(self) -> float:
        return self._clock()

    def warmup(self) -> None:
        """Force the dominant jit compiles (decode + one prefill bucket)
        on every replica, then reset stats and the shared clock so a
        subsequent replay measures steady-state serving, not XLA
        compilation. Every replica ends in the same warm state, so
        policy comparisons stay fair."""
        from repro.core import Request
        for e in self.engines:
            e.submit(Request(input_len=8, output_len=2, adapter_id=0))
            e.drain()
            e.reset_stats()
        self._clock.reset()

    # ------------------------------------------------------------ serve
    def submit(self, req, *, sampling=None, on_token=None,
               ttl=None):
        """Route and enqueue; returns the request's ``RequestHandle``
        with ``handle.node`` set to the chosen replica (the handle
        subsumes the bare node index the old surface returned —
        cluster-level cancellation routes through it)."""
        loads = [e.queue_pressure() for e in self.engines]
        resident = [e.cache.resident(req.adapter_id)
                    for e in self.engines]
        node = self.router.route(
            req.adapter_id, loads, resident,
            prefix_key=prefix_route_key(req, self.ecfg.page_size))
        handle = self.engines[node].submit(
            req, sampling=sampling, on_token=on_token, ttl=ttl)
        handle.node = node
        handle._system = self      # stream() pumps the whole cluster
        self.routed[node] += 1
        self.n_submitted += 1
        return handle

    def cancel(self, handle) -> bool:
        """Cluster-level cancel: route to the replica that owns the
        request (``handle.node``)."""
        if handle.node is None:
            return False
        return self.engines[handle.node].cancel(handle)

    def step(self) -> None:
        for e in self.engines:
            e.step()

    def busy(self) -> bool:
        return any(e.busy() for e in self.engines)

    def queue_pressure(self) -> float:
        """Cluster backlog: summed replica pressure (routing inside the
        cluster uses the per-replica signals; this export is for
        stacking clusters behind a higher-level balancer)."""
        return float(sum(e.queue_pressure() for e in self.engines))

    def drain(self, max_steps: int = DRAIN_MAX_STEPS) -> None:
        for _ in range(max_steps):
            if not self.busy():
                break
            self.step()

    def run(self, requests, max_steps: int = 100_000,
            ) -> tuple[RunMetrics, list[RunMetrics]]:
        """Replay requests against the wall clock: submit each when its
        ``arrival_time`` passes, stepping all engines in between.

        ``max_steps`` bounds *engine* iterations only; idle gaps
        between arrivals sleep until the next arrival instead of
        spinning the budget away.
        """
        import time as _time
        import warnings

        pending = sorted(requests, key=lambda r: r.arrival_time)
        i = 0
        steps = 0
        while steps < max_steps:
            now = self.now()
            while i < len(pending) and pending[i].arrival_time <= now:
                self.submit(pending[i])
                i += 1
            if not self.busy():
                if i >= len(pending):
                    break
                _time.sleep(min(0.05, max(0.0,
                            pending[i].arrival_time - self.now())))
                continue
            self.step()
            steps += 1
        if i < len(pending) or self.busy():
            warnings.warn(
                f"EngineCluster.run hit max_steps={max_steps} with "
                f"{len(pending) - i} unsubmitted and work in flight; "
                f"metrics cover a truncated run", RuntimeWarning)
        return self.metrics()

    # --------------------------------------------------------- reporting
    def metrics(self) -> tuple[RunMetrics, list[RunMetrics]]:
        per_node = [e.metrics() for e in self.engines]
        merged = merge_metrics(per_node, n_submitted=self.n_submitted)
        return merged, per_node

    def stats(self) -> dict:
        out = {
            "routed": self.routed.tolist(),
            "adapter_loads": sum(e.cache.stats.misses
                                 for e in self.engines),
            "per_engine": [e.stats() for e in self.engines],
        }
        pages = [e.kv_page_stats() for e in self.engines]
        if any(pages):
            # Cluster-wide KV page occupancy (paged replicas only).
            out["kv_pages_used"] = sum(p.get("kv_pages_used", 0)
                                       for p in pages)
            out["kv_pages_total"] = sum(p.get("kv_pages_total", 0)
                                        for p in pages)
            out["preempted"] = sum(p.get("preempted", 0) for p in pages)
        return out
