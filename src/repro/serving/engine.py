"""JAX serving engine: continuous batching with Chameleon integrated.

This is the *real* data plane (tier 1 in DESIGN §2): a jit'd decode
step over slot-padded KV caches and LoRA adapter-slot buffers, driven
by the same ChameleonScheduler / AdapterCache / MemoryPool objects the
simulator uses. LoRA matmuls route through the dispatch layer in
``repro.kernels.ops`` (``EngineConfig.lora_backend``): under ``auto``,
TPU backends run the fused Pallas bgmv (decode) / sgmv (prefill)
kernels and this CPU container runs the jnp einsum reference — the same
math, asserted token-identical by the CI parity jobs, which force the
kernel path in interpret mode.

Adapter loading is asynchronous by default (``EngineConfig.async_load``,
the systems half of paper §4's "minimize adapter loading times"): a
cache miss *dispatches* the host→device slot write and marks the cache
entry LOADING; the step loop keeps decoding the current batch while the
transfer is in flight, the scheduler refuses to place the loading
request (and only that request — the bypass lane may fill its seat),
and readiness is polled at the top of each step. Queued-request and
histogram prefetchers issue the same non-blocking loads ahead of
demand, so prefetch transfers overlap decode compute too.

Static-shape design (TPU-native):
- ``max_slots`` request slots; inactive slots run masked garbage that is
  never surfaced (standard TPU continuous batching);
- KV lives in a **paged pool** by default (``EngineConfig.paged``):
  fixed-size pages (L, n_pages, page, Kh, Dh) plus a per-slot page
  table, with page 0 reserved as the trash page inactive slots write.
  Pages are allocated on demand at prefill and per decoded page
  boundary, and freed on finish/squash, so ``MemoryPool`` request holds
  are *real* occupancy — the adapter cache, admission headroom, and
  ``queue_pressure()`` all see actual free HBM instead of a
  worst-case-reserved fiction. Decode attention routes through
  ``kernels.ops.paged_attention`` (Pallas on TPU, jnp reference on
  CPU). When a page cannot be allocated even after shrinking the
  adapter cache, the slot is preempted (pages freed, request requeued)
  — the price of admitting against actual rather than predicted
  occupancy. ``paged=False`` keeps the dense
  (L, max_slots, max_len, Kh, Dh) slab for parity testing;
- ``n_lora_slots`` adapter-slot buffers; the cache manager's on_load
  writes adapter weights into a slot (device-side copy), on_evict frees
  it. Residency decisions stay 100 % in repro.core — this file only
  moves bytes.

Engine surface (DESIGN §3): the engine implements the unified
``ServingSystem`` protocol — ``submit`` is non-blocking and returns a
``RequestHandle`` (streaming tokens, lifecycle state machine,
``cancel()``, per-request ``SamplingParams`` and deadlines), ``step``
runs one iteration — lifecycle sweep, *batched* prefill admission,
one decode, one jit'd batched sampling call — and ``drain`` runs the
queue dry. Prefills admitted in the same iteration share one jit'd
call over a (B, S) bucket instead of one compile-and-launch per
request, so TTFT under burst load reflects batch admission, not serial
prefill launches. Real prompt token ids (``Request.prompt``) feed the
prefill; trace-driven workloads without token material fall back to a
deterministic synthetic prompt. Squash/preemption preserves the
streamed prefix and its latency records across the requeue (the handle
never re-streams a position).

Multi-replica serving shares one ``AdapterCatalog`` (host-side adapter
weights + size metadata) across engines: replicas differ only in device
state, never in adapter bytes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdapterCache, AdapterInfo, CacheStats,
                        ChameleonScheduler, HistogramPrefetcher,
                        MemoryPool, NoisyOraclePredictor, PoolError,
                        QueuedRequestPrefetcher, Request, RequestState,
                        SamplingParams)
from repro.kernels.ops import resolve_lora_backend
from repro.models import api
from repro.models.base import ModelConfig
from repro.models.lora_apply import (init_lora_slots, random_lora_weights,
                                     write_adapter_to_slot)
from repro.serving.handles import RequestHandle, prepare_request
from repro.serving.metrics import RequestRecord, RunMetrics


@dataclass
class EngineConfig:
    max_slots: int = 8
    max_len: int = 256
    n_lora_slots: int = 8
    r_max: int = 32
    n_adapters: int = 16
    predictor_accuracy: float = 0.8
    seed: int = 0
    # Paged KV data plane (S-LoRA-style unified paging). ``paged=False``
    # falls back to the dense (L, max_slots, max_len, Kh, Dh) slab;
    # families without paged decode support fall back automatically.
    paged: bool = True
    page_size: int = 16
    # LoRA data-plane backend: "auto" = Pallas bgmv/sgmv on TPU, einsum
    # elsewhere; "kernel"/"einsum" force a path (kernel runs Pallas
    # interpret mode off-TPU — the CI parity jobs use this).
    lora_backend: str = "auto"
    # Async adapter loading: dispatch the host→device slot write and
    # keep stepping; placement waits on readiness (paper §4 overlap).
    # False restores the blocking load (the A/B baseline).
    async_load: bool = True
    # Modeled H2D link bandwidth (GB/s) for load-latency experiments;
    # 0 = unmodeled (readiness is actual device-write completion).
    # Sync mode stalls the step loop for the modeled transfer time,
    # async mode only defers the affected adapter's readiness.
    h2d_gbps: float = 0.0
    # Prefetchers (paper §4.1): walk the wait queues / per-adapter
    # arrival histograms and issue non-blocking loads ahead of demand.
    queued_prefetch: bool = True
    histogram_prefetch: bool = True


class AdapterCatalog:
    """Host-side LoRA adapter store shared by every engine replica.

    Holds the adapter weights ("host memory" in the paper) and the
    AdapterInfo metadata the control plane prices residency with. One
    catalog serves N engines: replicas keep per-device slot buffers but
    never duplicate the host-side weights (DESIGN §3).
    """

    def __init__(self, cfg: ModelConfig, n_adapters: int, r_max: int,
                 seed: int = 0):
        self.cfg = cfg
        self.r_max = r_max
        key = jax.random.PRNGKey(seed)
        self.ranks = [min(cfg.lora_ranks[i % len(cfg.lora_ranks)], r_max)
                      for i in range(n_adapters)]
        keys = jax.random.split(key, n_adapters)
        self.weights = {
            aid: random_lora_weights(keys[aid], self.ranks[aid], r_max,
                                     cfg.n_layers, cfg.d_model,
                                     cfg.q_dim, cfg.kv_dim)
            for aid in range(n_adapters)}
        kv_tok = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * 2
        lora_bytes = {aid: sum(
            int(np.prod(a.shape) + np.prod(b.shape)) * 2
            for a, b in self.weights[aid].values())
            for aid in self.weights}
        self.infos = {aid: AdapterInfo(
            adapter_id=aid, rank=self.ranks[aid],
            size_bytes=lora_bytes[aid],
            size_tokens=max(1, lora_bytes[aid] // kv_tok))
            for aid in self.weights}

    def __len__(self) -> int:
        return len(self.weights)

    def rank_of(self, adapter_id: int) -> int:
        return self.infos[adapter_id].rank


class ChameleonEngine:
    """Single-host engine over a (small) real model."""

    def __init__(self, cfg: ModelConfig, params: dict,
                 ecfg: EngineConfig | None = None,
                 scheduler_cls=ChameleonScheduler, cache_enabled=True,
                 catalog: AdapterCatalog | None = None,
                 clock: Optional[Callable[[], float]] = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg or EngineConfig()
        e = self.ecfg
        key = jax.random.PRNGKey(e.seed)

        # --- LoRA adapter catalog (host-side weights = "host memory") ---
        self.catalog = catalog or AdapterCatalog(cfg, e.n_adapters,
                                                 e.r_max, seed=e.seed)
        self.host_adapters = self.catalog.weights
        # Device adapter-slot buffers (per replica).
        self.lora = init_lora_slots(key, e.n_lora_slots, cfg.n_layers,
                                    cfg.d_model, cfg.q_dim, cfg.kv_dim,
                                    self.catalog.r_max)
        self.slot_of: dict[int, int] = {}       # adapter_id -> lora slot
        self.free_slots = list(range(e.n_lora_slots))
        # Double-buffered async loads: slot writes land in the
        # *staging* chain (``_lora_staging``) while the jit'd steps
        # keep reading the active ``self.lora`` — no data dependency on
        # an in-flight transfer, so decode genuinely overlaps the copy.
        # ``_pending_loads`` maps adapter_id -> (staging snapshot to
        # swap active to — None once swapped, fresh device arrays to
        # poll, modeled-ready wall time); `_poll_loads` swaps snapshots
        # in FIFO order as writes land and READYs entries once the
        # modeled time also passed.
        self._lora_staging = self.lora
        self._pending_loads: dict[
            int, tuple[Optional[dict], tuple, float]] = {}
        self.n_async_loads = 0
        self._lora_backend = resolve_lora_backend(e.lora_backend)

        # --- memory pool in token units ---
        infos = self.catalog.infos
        cap = e.max_slots * e.max_len \
            + 4 * max(c.size_tokens for c in infos.values())
        self.paged = bool(e.paged) and api.supports_paged(cfg)
        self.pool = MemoryPool(capacity_tokens=cap,
                               page_size=e.page_size if self.paged else 1)
        self.cache = AdapterCache(self.pool, infos,
                                  enabled=cache_enabled,
                                  on_load=self._load_adapter,
                                  on_evict=self._evict_adapter,
                                  max_entries=e.n_lora_slots)
        pred = NoisyOraclePredictor(accuracy=e.predictor_accuracy,
                                    seed=e.seed)
        skw = dict(max_batch_requests=e.max_slots)
        if issubclass(scheduler_cls, ChameleonScheduler):
            skw["t_refresh"] = 5.0
        self.sched = scheduler_cls(self.pool, self.cache, infos, pred,
                                   **skw)
        # §4.1 prefetchers: their cache.prefetch calls run through the
        # same async `_load_adapter`, so prefetch H2D transfers overlap
        # decode compute instead of stalling the loop.
        self.q_prefetch = (QueuedRequestPrefetcher(self.cache)
                           if e.queued_prefetch else None)
        self.h_prefetch = (HistogramPrefetcher(self.cache)
                           if e.histogram_prefetch else None)
        # Paged mode: the engine holds exactly its allocated pages in
        # the pool (per req_id) and grows/frees them itself; the
        # scheduler's worst-case reservation is switched off.
        self.sched.reserve_from_pool = not self.paged

        # --- device state ---
        if self.paged:
            ps = e.page_size
            # One physical page per pool page + the reserved trash page
            # (page 0). Sizing pages to the *whole* pool is the unified
            # paging: KV can spread into memory adapters are not using.
            self.n_pages = cap // ps + 1
            self.pages_per_slot = -(-e.max_len // ps)
            self.kv_pages = api.init_paged_serve_state(
                cfg, self.n_pages, ps, jnp.float32)
            self.page_table = np.zeros(
                (e.max_slots, self.pages_per_slot), np.int32)
            self.slot_pages: list[list[int]] = [[] for _ in
                                                range(e.max_slots)]
            self.free_pages = list(range(self.n_pages - 1, 0, -1))
            self.kv = None
        else:
            self.kv = api.init_serve_state(cfg, e.max_slots, e.max_len,
                                           jnp.float32)
        self.tokens = jnp.zeros((e.max_slots, 1), jnp.int32)
        self.cache_len = jnp.zeros((e.max_slots,), jnp.int32)
        self.active = np.zeros((e.max_slots,), bool)
        self.adapter_slot = jnp.zeros((e.max_slots,), jnp.int32)
        self.slot_req: list[Optional[Request]] = [None] * e.max_slots
        self.t0 = time.monotonic()
        self._clock = clock
        self.completed: list[Request] = []
        self.records: list[RequestRecord] = []
        self.outputs: dict[int, list[int]] = {}
        self._tbts: dict[int, list[float]] = {}
        self._last_tok: dict[int, float] = {}
        self.handles: dict[int, RequestHandle] = {}
        self.batch_occupancy: list[int] = []   # active slots per step
        self.n_preempted = 0                   # paged: out-of-page squashes
        self.n_cancelled = 0
        self.n_expired = 0
        # Lifecycle fast path: deadline/cancel sweeps run only once a
        # request armed them (keeps the hot step loop scan-free).
        self._deadlines_armed = False
        self._cancel_races: list[Request] = []

        self._decode_jit = jax.jit(self._decode_fn)
        self._decode_paged_jit = jax.jit(self._decode_paged_fn)
        self._prefill_jit = jax.jit(self._prefill_fn,
                                    static_argnames=("S",))
        self._sample_jit = jax.jit(api.sample_tokens)

    # ------------------------------------------------------------- clock
    def now(self) -> float:
        if self._clock is not None:
            return self._clock()
        return time.monotonic() - self.t0

    # ----------------------------------------------------- adapter moves
    def _load_adapter(self, info: AdapterInfo) -> None:
        """Cache ``on_load`` hook: stage the adapter into a device slot.

        Async mode (default) dispatches the host→device write into the
        *staging* buffer chain and marks the entry LOADING; the jit'd
        steps keep reading the active ``self.lora``, which has no data
        dependency on the in-flight transfer, so decode overlaps the
        copy for real. `_poll_loads` swaps the staging snapshot in once
        the write lands. Sync mode blocks until the write (plus any
        modeled H2D time) lands — the S-LoRA baseline the fig10 loading
        A/B measures against.
        """
        if not self.free_slots:
            raise RuntimeError(
                "adapter slot accounting drift: no free LoRA slot for "
                f"adapter {info.adapter_id} "
                f"(n_lora_slots={self.ecfg.n_lora_slots}, "
                f"slot_of={dict(sorted(self.slot_of.items()))}, "
                f"cache_resident={sorted(self.cache.resident_ids())}, "
                f"cache_loading={sorted(self.cache.loading_ids())})")
        slot = self.free_slots.pop()
        self.slot_of[info.adapter_id] = slot
        self._lora_staging = write_adapter_to_slot(
            self._lora_staging, self.host_adapters[info.adapter_id], slot)
        e = self.ecfg
        delay = (info.size_bytes / (e.h2d_gbps * 1e9)
                 if e.h2d_gbps > 0 else 0.0)
        if e.async_load:
            self.cache.mark_loading(info.adapter_id)
            self._pending_loads[info.adapter_id] = (
                self._lora_staging,
                jax.tree_util.tree_leaves(self._lora_staging),
                self.now() + delay)
            self.n_async_loads += 1
        else:
            jax.block_until_ready(self._lora_staging)
            self.lora = self._lora_staging
            if delay:
                time.sleep(delay)   # modeled H2D stall blocks the loop

    def _poll_loads(self) -> None:
        """Retire in-flight loads; runs every step, never blocks.

        Two decoupled transitions so snapshots die fast: (1) once a
        load's device write completes, its staging snapshot is swapped
        into the active buffer and *dropped* — snapshots live only for
        the actual write (ms), not the modeled transfer window, so at
        most a couple of extra slot-buffer copies exist transiently
        during a load burst; (2) the cache entry flips READY only after
        the modeled ``h2d_gbps`` time also elapsed. Swaps are FIFO:
        each snapshot was built on the previous one, so activating the
        first *unswapped* head is monotone and never exposes a later
        in-flight write.
        """
        now = self.now()
        for aid in list(self._pending_loads):
            staged, leaves, t_ready = self._pending_loads[aid]
            if staged is not None:
                if not all(x.is_ready() for x in leaves):
                    break           # FIFO: later writes chain on this one
                self.lora = staged
                self._pending_loads[aid] = (None, (), t_ready)
            if now >= t_ready:
                del self._pending_loads[aid]
                self.cache.mark_ready(aid)

    def flush_loads(self) -> None:
        """Barrier: block until every in-flight load lands (warmup /
        stats resets — a rebased clock must not strand a modeled
        ready-time in the old epoch)."""
        if not self._pending_loads:
            return
        jax.block_until_ready(self._lora_staging)
        self.lora = self._lora_staging
        for aid in list(self._pending_loads):
            del self._pending_loads[aid]
            self.cache.mark_ready(aid)

    def _evict_adapter(self, info: AdapterInfo) -> None:
        slot = self.slot_of.pop(info.adapter_id)
        self.free_slots.append(slot)
        # LOADING entries are never eviction candidates, so a pending
        # load here is unreachable; drop it anyway to stay consistent.
        self._pending_loads.pop(info.adapter_id, None)

    # ------------------------------------------------------- jit'd steps
    # ``self._lora_backend`` is a resolved Python constant captured by
    # these jit'd closures, so one engine = one backend = one coherent
    # jit cache (no per-call retraces on the backend choice).
    def _decode_fn(self, params, lora, tokens, kv, cache_len,
                   adapter_slot):
        return api.decode_step(self.cfg, params, tokens, kv, cache_len,
                               lora=lora, adapter_idx=adapter_slot,
                               lora_backend=self._lora_backend)

    def _decode_paged_fn(self, params, lora, tokens, kv_pages,
                         page_table, cache_len, adapter_slot):
        return api.decode_step_paged(self.cfg, params, tokens, kv_pages,
                                     page_table, cache_len, lora=lora,
                                     adapter_idx=adapter_slot,
                                     lora_backend=self._lora_backend)

    def _prefill_fn(self, params, lora, tokens, adapter_slot, last_pos,
                    S):
        del S
        return api.prefill(self.cfg, params, tokens, lora=lora,
                           adapter_idx=adapter_slot, last_pos=last_pos,
                           lora_backend=self._lora_backend)

    # ------------------------------------------------------- page moves
    def _alloc_page(self, req_id: int, now: float) -> Optional[int]:
        """One physical page for ``req_id``; None when HBM is truly full.

        The pool gate runs first: if the unified pool has no free page
        the adapter cache is asked to shrink (§4.1 dynamic downsizing,
        second-tier protection for queued adapters applies). Physical
        pages cannot run out before pool pages — the page arrays are
        sized to the whole pool.
        """
        if not self.free_pages:
            return None
        ps = self.pool.page_size
        if self.pool.free_tokens < ps and not self.cache.shrink_for_requests(
                ps, now, self.sched.queued_adapter_ids()):
            return None
        try:
            self.pool.reserve_request_pages(req_id, 1)
        except PoolError:
            return None
        return self.free_pages.pop()

    def _grow_slot(self, slot: int, n_pages: int, now: float) -> bool:
        """Grow a slot's page list by ``n_pages``; all-or-nothing."""
        req = self.slot_req[slot]
        got = []
        for _ in range(n_pages):
            pid = self._alloc_page(req.req_id, now)
            if pid is None:
                for p in got:
                    self.free_pages.append(p)
                if got:
                    self.pool.shrink_request(
                        req.req_id, len(got) * self.pool.page_size)
                return False
            got.append(pid)
        base = len(self.slot_pages[slot])
        self.slot_pages[slot].extend(got)
        self.page_table[slot, base:base + len(got)] = got
        return True

    def _free_slot_pages(self, slot: int, req_id: int) -> None:
        if not self.paged:
            return
        self.free_pages.extend(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.page_table[slot, :] = 0
        self.pool.release_request(req_id)

    def _stash_progress(self, req: Request) -> None:
        """Squash/preemption: move the request's already-streamed tokens
        and TBT records onto the request itself so the requeue keeps
        them (re-execution regenerates the same prefix deterministically
        and never re-streams it — the handle dedups by position)."""
        rid = req.req_id
        req.stash_progress(self.outputs.pop(rid, None),
                           self._tbts.pop(rid, None),
                           self._last_tok.pop(rid, None))

    def _preempt(self, slot: int) -> None:
        """Out of pages mid-flight: free the slot and requeue (squash
        path — the request re-executes, keeping its streamed prefix)."""
        req = self.slot_req[slot]
        self.active[slot] = False
        self.slot_req[slot] = None
        self._stash_progress(req)
        self._free_slot_pages(slot, req.req_id)
        self.n_preempted += 1
        self.sched.on_squash(req, self.now())

    # ---------------------------------------------------------- lifecycle
    def submit(self, req: Request, *,
               sampling: Optional[SamplingParams] = None,
               on_token=None, ttl: Optional[float] = None,
               ) -> RequestHandle:
        """Non-blocking: enqueue with the scheduler; no device work.
        Returns the request's handle (DESIGN §3 serving surface)."""
        now = self.now()
        handle = prepare_request(req, self, now, sampling, on_token, ttl)
        self.handles[req.req_id] = handle
        if req.deadline is not None:
            self._deadlines_armed = True
        self.sched.submit(req, now)
        if self.h_prefetch is not None:
            self.h_prefetch.observe_arrival(req.adapter_id, now)
        return handle

    def cancel(self, handle) -> bool:
        """Cancel a request. Queued / LOADING-deferred requests release
        their adapter pin and terminate immediately; RUNNING requests
        are finalised at the next step boundary (the in-flight jit'd
        decode cannot be interrupted). False once already terminal."""
        req = handle.req if isinstance(handle, RequestHandle) else handle
        if req.terminal:
            return False
        now = self.now()
        if any(r is req for r in self.slot_req):
            req.cancel_requested = True    # step() sweeps it
            return True
        if self.sched.cancel(req, now):
            self._finalize_unplaced(req, RequestState.CANCELLED, now)
            return True
        # Mid-transition race (e.g. cancelled from an on_token callback
        # while being placed): mark it; the step sweep resolves it.
        req.cancel_requested = True
        self._cancel_races.append(req)
        return True

    def _finalize_unplaced(self, req: Request, state: RequestState,
                           now: float) -> None:
        """Terminal transition for a request that never held a slot
        (queued cancel / queue-side deadline expiry). The scheduler
        already released the adapter pin; queued requests hold no pool
        reservation or quota charges."""
        req.state = state
        req.finish_time = now
        if state is RequestState.CANCELLED:
            self.n_cancelled += 1
        else:
            self.n_expired += 1

    # ------------------------------------------------------ token delivery
    def _record_token(self, req: Request, pos: int, tok: int,
                      now: float) -> None:
        """Record (and stream) the token at output position ``pos``.

        Re-executed positions after a squash overwrite in place and are
        *not* re-streamed or re-timed: the TBT of the first genuinely
        new token is measured from the last token the user actually saw
        (``last_stream_time`` survives the requeue)."""
        rid = req.req_id
        out = self.outputs[rid]
        if pos < len(out):
            out[pos] = tok         # deterministic regeneration of prefix
            return
        out.append(tok)
        if pos >= 1:
            tbts = self._tbts[rid]
            if len(tbts) < pos:
                tbts.append(now - self._last_tok[rid])
        self._last_tok[rid] = now
        handle = self.handles.get(rid)
        if handle is not None:
            handle._push(pos, tok)

    def _place_batch(self, reqs: list[Request]) -> None:
        """Batched prefill admission: one jit'd prefill over a (B, S)
        bucket covers every request admitted this iteration.

        Right-padding is safe under causal attention (positions past
        ``last_pos`` never influence the selected logits), and padded
        batch rows run masked garbage exactly like inactive decode
        slots. Buckets are powers of two so recompiles stay bounded.
        """
        if not reqs:
            return
        free = [int(s) for s in np.where(~self.active)[0]]
        if self.paged:
            # Allocate each request's prompt pages up front; a request
            # whose prompt cannot get pages even after shrinking the
            # cache bounces straight back to its queue (squash path).
            now = self.now()
            placed = []
            for req in reqs:
                slot = free[len(placed)]
                self.slot_req[slot] = req
                if self._grow_slot(slot, self.pool.pages_for(req.input_len),
                                   now):
                    placed.append(req)
                else:
                    self.slot_req[slot] = None
                    self.n_preempted += 1
                    self.sched.on_squash(req, now)
            reqs = placed
            if not reqs:
                return
        S = 1 << max(3, (max(r.input_len for r in reqs) - 1).bit_length())
        B = 1 << max(0, (len(reqs) - 1).bit_length())
        toks = np.zeros((B, S), np.int32)
        last_pos = np.zeros((B,), np.int32)
        lslots = np.zeros((B,), np.int32)
        for i, req in enumerate(reqs):
            if req.prompt is not None:
                toks[i, :req.input_len] = np.asarray(req.prompt, np.int32) \
                    % self.cfg.vocab_size
            else:
                # Trace-driven workloads carry lengths, not token
                # material: fabricate a deterministic prompt.
                toks[i, :req.input_len] = (np.arange(req.input_len)
                                           % self.cfg.vocab_size)
            last_pos[i] = req.input_len - 1
            lslots[i] = self.slot_of[req.adapter_id]
        logits, (k_new, v_new) = self._prefill_jit(
            self.params, self.lora, jnp.asarray(toks),
            jnp.asarray(lslots), jnp.asarray(last_pos), S)
        if self._all_greedy(reqs):
            first_toks = np.asarray(
                jnp.argmax(logits, axis=-1).astype(jnp.int32))
        else:
            first_toks = np.asarray(self._sample_jit(
                logits, *self._sampling_arrays(reqs, B, first=True)))
        if self.paged:
            kp, vp = self.kv_pages
        else:
            k, v = self.kv
        now = self.now()
        ps = self.pool.page_size
        for i, req in enumerate(reqs):
            slot = free[i]
            self.active[slot] = True
            self.slot_req[slot] = req
            L = req.input_len
            if self.paged:
                pages = self.slot_pages[slot]
                for j in range(0, L, ps):
                    pid = pages[j // ps]
                    n = min(ps, L - j)
                    kp = kp.at[:, pid, :n].set(k_new[:, i, j:j + n])
                    vp = vp.at[:, pid, :n].set(v_new[:, i, j:j + n])
            else:
                k = k.at[:, slot, :L].set(k_new[:, i, :L])
                v = v.at[:, slot, :L].set(v_new[:, i, :L])
            first = int(first_toks[i])
            self.tokens = self.tokens.at[slot, 0].set(first)
            self.cache_len = self.cache_len.at[slot].set(L)
            self.adapter_slot = self.adapter_slot.at[slot].set(
                int(lslots[i]))
            req.generated = 1
            rid = req.req_id
            if req.preserved_tokens:
                # Squash survivor: restore the streamed prefix and its
                # latency records; re-execution regenerates (and the
                # handle ignores) positions the user already has.
                self.outputs[rid] = list(req.preserved_tokens)
                self._tbts[rid] = list(req.preserved_tbts)
                if req.last_stream_time is not None:
                    self._last_tok[rid] = req.last_stream_time
            else:
                self.outputs[rid] = []
                self._tbts[rid] = []
                req.first_token_time = now
            self._record_token(req, 0, first, now)
        if self.paged:
            self.kv_pages = (kp, vp)
        else:
            self.kv = (k, v)
        for i, req in enumerate(reqs):
            if req.done or self._hit_stop(req):
                self._finish(free[i])

    def _hit_stop(self, req: Request) -> bool:
        """Did the latest recorded token hit a SamplingParams stop id?"""
        sp = req.sampling
        if sp is None or not sp.stop_token_ids:
            return False
        return self.outputs[req.req_id][req.generated - 1] \
            in sp.stop_token_ids

    @staticmethod
    def _all_greedy(reqs) -> bool:
        """Host-side fast-path test: with no stochastic row in the
        batch, sampling is plain argmax — skip building the sampler
        inputs and the full sorted/softmax/Gumbel sampler call (the
        default path, and the one every greedy benchmark measures)."""
        return all(r is None or r.sampling is None or r.sampling.greedy
                   for r in reqs)

    def _sampling_arrays(self, reqs, B: int, first: bool = False):
        """Per-row sampler inputs for a prefill batch (``reqs`` list,
        ``first=True`` → all positions 0) or the decode batch
        (``reqs = slot_req``; inactive slots run greedy garbage)."""
        temp = np.zeros(B, np.float32)
        topk = np.zeros(B, np.int32)
        topp = np.ones(B, np.float32)
        seeds = np.zeros(B, np.uint32)
        pos = np.zeros(B, np.int32)
        for i, req in enumerate(reqs):
            if req is None:
                continue
            sp = req.sampling
            if sp is not None and not sp.greedy:
                temp[i] = sp.temperature
                topk[i] = sp.top_k
                topp[i] = sp.top_p
                seeds[i] = sp.seed_for(req.req_id)
            if not first:
                pos[i] = req.generated
        return (jnp.asarray(temp), jnp.asarray(topk), jnp.asarray(topp),
                jnp.asarray(seeds), jnp.asarray(pos))

    def _finish(self, slot: int) -> None:
        # A cancel that raced the final token (e.g. issued from the
        # on_token callback that delivered it) still honours the
        # cancel() contract: the request terminates as CANCELLED.
        req = self.slot_req[slot]
        self._finalize_slot(slot, RequestState.CANCELLED
                            if req.cancel_requested
                            else RequestState.FINISHED)

    def _finalize_slot(self, slot: int, state: RequestState) -> None:
        """Terminal transition for the request occupying ``slot``:
        FINISHED, CANCELLED (handle.cancel on a running request) or
        EXPIRED (deadline passed mid-decode). All three release the
        slot, its KV pages and the scheduler/pool/cache holds; only
        FINISHED contributes a RequestRecord to the run metrics."""
        req = self.slot_req[slot]
        req.state = state
        now = self.now()
        req.finish_time = now
        self.sched.on_finish(req, now)
        self._free_slot_pages(slot, req.req_id)
        self.active[slot] = False
        self.slot_req[slot] = None
        tbts = self._tbts.pop(req.req_id, [])
        req.preserved_tbts = tbts    # handle.result() reads these
        self._last_tok.pop(req.req_id, None)
        if state is RequestState.CANCELLED:
            self.n_cancelled += 1
            return
        if state is RequestState.EXPIRED:
            self.n_expired += 1
            return
        self.completed.append(req)
        self.records.append(RequestRecord(
            req_id=req.req_id, adapter_id=req.adapter_id,
            rank=self.catalog.rank_of(req.adapter_id),
            input_len=req.input_len, output_len=req.output_len,
            arrival=req.arrival_time,
            ttft=req.ttft() or 0.0, e2e=req.e2e() or 0.0,
            tbt_mean=float(np.mean(tbts)) if tbts else 0.0,
            tbt_p99=float(np.percentile(tbts, 99)) if tbts else 0.0,
            slowdown=1.0,   # no isolated-run oracle on the real engine
            squashes=req.squash_count, bypassed=req.bypassed,
            queue_wait=req.queue_wait() or 0.0,
            load_wait=req.adapter_load_wait))

    def _ensure_decode_pages(self) -> None:
        """Grow each active slot to cover its next decode write; slots
        that cannot get a page even after shrinking the adapter cache
        are preempted (freed pages let the remaining slots proceed)."""
        now = self.now()
        lens = np.asarray(self.cache_len)
        ps = self.pool.page_size
        for slot in np.where(self.active)[0]:
            needed = int(lens[slot]) // ps + 1
            short = needed - len(self.slot_pages[slot])
            if short > 0 and not self._grow_slot(int(slot), short, now):
                self._preempt(int(slot))

    def _run_prefetchers(self, now: float) -> None:
        """Ahead-of-demand loads (paper §4.1). Dispatched async, they
        overlap the decode compute this same step launches; admission
        ran first, so prefetch never steals memory from the batch."""
        # Prefetch only fills *idle* slots: with every slot occupied it
        # would have to evict, fighting the cost-aware policy (§4.1:
        # prefetching must never evict useful entries). The budget is
        # the live free-slot count, re-read between prefetchers, so a
        # round can never load past the last idle slot. The simulator
        # has no slot cap, so this gate lives here, not in the
        # prefetchers.
        if not self.free_slots:
            return
        queued = self.sched.queued_requests_in_order()
        if self.q_prefetch is not None and queued:
            self.q_prefetch.run(queued, now, budget=len(self.free_slots))
        if self.h_prefetch is not None and self.free_slots:
            self.h_prefetch.run(
                now, queued_protect={r.adapter_id for r in queued},
                budget=len(self.free_slots))

    def _sweep_lifecycle(self, now: float) -> None:
        """Lifecycle enforcement at the step boundary: reap queued
        requests past their deadline, then finalise active slots whose
        request was cancelled (``handle.cancel()``) or expired."""
        if self._deadlines_armed:
            for req in self.sched.reap_expired(now):
                self._finalize_unplaced(req, RequestState.EXPIRED, now)
        for slot in np.where(self.active)[0]:
            req = self.slot_req[slot]
            if req.cancel_requested:
                self._finalize_slot(int(slot), RequestState.CANCELLED)
            elif req.deadline is not None and now >= req.deadline:
                self._finalize_slot(int(slot), RequestState.EXPIRED)
        # A cancel that raced placement (neither queued nor in a slot
        # at cancel() time) is caught here once it settles somewhere.
        if self._cancel_races:
            races, self._cancel_races = self._cancel_races, []
            for req in races:
                if not req.terminal:
                    self.cancel(req)

    def step(self) -> None:
        """One engine iteration: retire finished loads -> enforce
        deadlines/cancellations -> admit -> prefetch -> batched prefill
        -> one decode + sample."""
        self._poll_loads()
        now = self.now()
        self._sweep_lifecycle(now)
        running = [r for r in self.slot_req if r is not None]
        admitted = self.sched.schedule(now, running)
        self._run_prefetchers(now)
        self._place_batch(admitted)
        if self.paged:
            self._ensure_decode_pages()
        if not self.active.any():
            if self._pending_loads:
                # Idle with loads in flight: wait until the earliest
                # in-flight load's modeled readiness instead of spinning
                # a fixed busy-wait; already-due loads (waiting only on
                # the actual device write) poll at a tight interval.
                t_next = min(t for _, _, t in self._pending_loads.values())
                wait = t_next - self.now()
                time.sleep(min(max(wait, 1e-4), 0.05))
            return
        self.batch_occupancy.append(int(self.active.sum()))
        if self.paged:
            logits, self.kv_pages = self._decode_paged_jit(
                self.params, self.lora, self.tokens, self.kv_pages,
                jnp.asarray(self.page_table), self.cache_len,
                self.adapter_slot)
        else:
            logits, self.kv = self._decode_jit(
                self.params, self.lora, self.tokens, self.kv,
                self.cache_len, self.adapter_slot)
        if self._all_greedy(self.slot_req):
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = self._sample_jit(
                logits, *self._sampling_arrays(self.slot_req,
                                               self.ecfg.max_slots))
        self.tokens = nxt[:, None]
        self.cache_len = self.cache_len + jnp.asarray(self.active,
                                                      jnp.int32)
        now = self.now()
        nxt_host = np.asarray(nxt)
        to_finish, to_squash = [], []
        for slot in np.where(self.active)[0]:
            req = self.slot_req[slot]
            pos = req.generated
            req.generated += 1
            self._record_token(req, pos, int(nxt_host[slot]), now)
            if req.done or self._hit_stop(req) \
                    or req.generated + req.input_len \
                    >= self.ecfg.max_len - 1:
                to_finish.append(slot)
            elif req.bypassed and req.exceeded_prediction():
                to_squash.append(slot)
        for slot in to_finish:
            self._finish(slot)
        for slot in to_squash:
            req = self.slot_req[slot]
            self.active[slot] = False
            self.slot_req[slot] = None
            self._stash_progress(req)
            self._free_slot_pages(slot, req.req_id)
            self.sched.on_squash(req, self.now())

    def busy(self) -> bool:
        """True while any work is in flight or queued."""
        return bool(self.active.any()) or self.sched.pending_count() > 0

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.busy():
                break
            self.step()

    # ``drain`` is the surface name the cluster layer uses (DESIGN §3).
    drain = run_until_drained

    def reset_stats(self) -> None:
        """Clear accounting after a warmup pass (jit compiles, first
        adapter loads) so reported metrics cover only the measured run.
        Device state and cache residency are kept — replicas start warm
        but identically so across routing policies."""
        self.flush_loads()
        self.completed = []
        self.records = []
        self.outputs = {}
        self._tbts = {}
        self._last_tok = {}
        self.handles = {}
        self.batch_occupancy = []
        self.n_preempted = 0
        self.n_cancelled = 0
        self.n_expired = 0
        self.n_async_loads = 0
        self.cache.stats = CacheStats()
        for counter in ("n_bypassed", "n_squashed", "n_deferred"):
            if hasattr(self.sched, counter):
                setattr(self.sched, counter, 0)

    # ---------------------------------------------------------- reporting
    def queue_pressure(self) -> float:
        """Routing signal: scheduler backlog plus occupied batch slots."""
        return self.sched.queue_pressure() + float(self.active.sum())

    def kv_page_stats(self) -> dict:
        """Page-occupancy telemetry (paged mode; empty dict for dense)."""
        if not self.paged:
            return {}
        total = self.n_pages - 1     # page 0 is the trash page
        used = total - len(self.free_pages)
        return {"kv_pages_used": used, "kv_pages_total": total,
                "kv_page_util": used / max(1, total),
                "preempted": self.n_preempted}

    def stats(self) -> dict:
        return {
            "completed": len(self.completed),
            "cache": self.cache.stats.__dict__.copy(),
            "bypassed": getattr(self.sched, "n_bypassed", 0),
            "squashed": getattr(self.sched, "n_squashed", 0),
            "deferred": getattr(self.sched, "n_deferred", 0),
            "cancelled": self.n_cancelled,
            "expired": self.n_expired,
            "async_loads": self.n_async_loads,
            "pending_loads": len(self._pending_loads),
            "resident_adapters": sorted(self.cache.resident_ids()),
            "pool": self.pool.snapshot(),
            **self.kv_page_stats(),
        }

    def metrics(self) -> RunMetrics:
        """Per-node RunMetrics, aggregatable at cluster level."""
        # Submitted = completed + in the batch + still queued, so a
        # truncated run shows its loss instead of a fake 100% rate.
        n_sub = (len(self.records) + int(self.active.sum())
                 + self.sched.pending_count())
        m = RunMetrics(records=list(self.records), horizon=self.now(),
                       n_submitted=n_sub)
        m.cache_stats = {
            "hit_rate": round(self.cache.stats.hit_rate, 4),
            "hits": self.cache.stats.hits,
            "misses": self.cache.stats.misses,
            "evictions": self.cache.stats.evictions,
            "gb_loaded": round(self.cache.stats.bytes_loaded / 1e9, 6),
        }
        m.sched_stats = {
            "bypassed": getattr(self.sched, "n_bypassed", 0),
            "squashed": getattr(self.sched, "n_squashed", 0),
            "deferred": getattr(self.sched, "n_deferred", 0),
            "cancelled": self.n_cancelled,
            "expired": self.n_expired,
            "async_loads": self.n_async_loads,
            "pressure": round(self.queue_pressure(), 3),
            "batch_occupancy_mean": round(
                float(np.mean(self.batch_occupancy))
                if self.batch_occupancy else 0.0, 3),
            **self.kv_page_stats(),
        }
        return m
