"""JAX serving engine: continuous batching with Chameleon integrated.

This is the *real* data plane (tier 1 in DESIGN §2): a jit'd decode
step over slot-padded KV caches and LoRA adapter-slot buffers, driven
by the same ChameleonScheduler / AdapterCache / MemoryPool objects the
simulator uses. LoRA matmuls route through the dispatch layer in
``repro.kernels.ops`` (``EngineConfig.lora_backend``): under ``auto``,
TPU backends run the fused Pallas bgmv (decode) / sgmv (prefill)
kernels and this CPU container runs the jnp einsum reference — the same
math, asserted token-identical by the CI parity jobs, which force the
kernel path in interpret mode.

Adapter loading is asynchronous by default (``EngineConfig.async_load``,
the systems half of paper §4's "minimize adapter loading times"): a
cache miss *dispatches* the host→device slot write and marks the cache
entry LOADING; the step loop keeps decoding the current batch while the
transfer is in flight, the scheduler refuses to place the loading
request (and only that request — the bypass lane may fill its seat),
and readiness is polled at the top of each step. Queued-request and
histogram prefetchers issue the same non-blocking loads ahead of
demand, so prefetch transfers overlap decode compute too.

Static-shape design (TPU-native):
- ``max_slots`` request slots; inactive slots run masked garbage that is
  never surfaced (standard TPU continuous batching);
- KV lives in a **paged pool** by default (``EngineConfig.paged``):
  fixed-size pages (L, n_pages, page, Kh, Dh) plus a per-slot page
  table, with page 0 reserved as the trash page inactive slots write.
  Pages are allocated on demand at prefill and per decoded page
  boundary, and freed on finish/squash, so ``MemoryPool`` request holds
  are *real* occupancy — the adapter cache, admission headroom, and
  ``queue_pressure()`` all see actual free HBM instead of a
  worst-case-reserved fiction. Decode attention routes through
  ``kernels.ops.paged_attention`` (Pallas on TPU, jnp reference on
  CPU). When a page cannot be allocated even after shrinking the
  adapter cache, the slot is preempted (pages freed, request requeued)
  — the price of admitting against actual rather than predicted
  occupancy. ``paged=False`` keeps the dense
  (L, max_slots, max_len, Kh, Dh) slab for parity testing;
- ``n_lora_slots`` adapter-slot buffers; the cache manager's on_load
  writes adapter weights into a slot (device-side copy), on_evict frees
  it. Residency decisions stay 100 % in repro.core — this file only
  moves bytes.

The decode hot loop is **device-resident** by default
(``EngineConfig.fused_hotloop``, DESIGN §2): one donated-buffer jit
dispatch fuses decode + sampling + ``cache_len`` advance (logits never
leave the device, KV updates in place — no double buffering), batch
state (active mask, sample positions, per-row sampling params, decode
budgets, stop tokens, page table) stays on device with rebuilds only
at batch epochs (place/finish/squash), and when no admissions are due
and no deadline/cancel sweep is armed an adaptive K-step micro-horizon
runs in one ``lax.scan`` with an on-device done-mask, syncing K tokens
per host round-trip with the next horizon dispatched before the
previous one's readback (pipelined). Under backlog K=1, so TTFT and
admission latency match the seed loop, which stays selectable
(``fused_hotloop=False``) as the ``benchmarks/decode_hotloop.py``
baseline — both loops are token-identical by construction and by the
``tests/test_hotloop_parity.py`` whole-engine A/B.

Engine surface (DESIGN §3): the engine implements the unified
``ServingSystem`` protocol — ``submit`` is non-blocking and returns a
``RequestHandle`` (streaming tokens, lifecycle state machine,
``cancel()``, per-request ``SamplingParams`` and deadlines), ``step``
runs one iteration — lifecycle sweep, *batched* prefill admission,
one fused decode horizon (or the seed decode + sampling pair) — and
``drain`` runs the queue dry. Prefills admitted in the same iteration
share one jit'd call over a (B, S) bucket instead of one
compile-and-launch per request, so TTFT under burst load reflects
batch admission, not serial prefill launches. Real prompt token ids (``Request.prompt``) feed the
prefill; trace-driven workloads without token material fall back to a
deterministic synthetic prompt. Squash/preemption preserves the
streamed prefix and its latency records across the requeue (the handle
never re-streams a position).

One engine can span a device **mesh** (``EngineConfig.mesh_shape``,
DESIGN §4.1): weights and LoRA-slot dout shard over the "model" axis,
KV pages / dense KV and per-request batch state over "data", and every
jit'd entry point (prefill, both decode loops, sampling, slot writes)
carries explicit in/out shardings from the ``distributed.sharding``
rule table via ``ShardPlan``. The control plane (pool, scheduler, page
tables, prefix cache) stays host-side and global, which is what keeps
a mesh>1 engine token-identical to single-device (``mesh_shape=None``,
the default) — asserted by ``tests/test_sharded_engine.py``.

Multi-replica serving shares one ``AdapterCatalog`` (host-side adapter
weights + size metadata) across engines: replicas differ only in device
state, never in adapter bytes. A gateway (``serving/gateway.py``) can
front any of this — engine, DES node, or cluster — adding per-tenant
admission control without the engine knowing tenants exist.
"""
from __future__ import annotations

import contextlib
import time
import warnings
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import (AdapterCache, AdapterInfo, CacheStats,
                        ChameleonScheduler, HistogramPrefetcher,
                        MemoryPool, NoisyOraclePredictor, PoolError,
                        PrefixCache, QueuedRequestPrefetcher, Request,
                        RequestState, SamplingParams)
from repro.distributed.act_sharding import activation_sharding
from repro.kernels.ops import (COLLECTIVE_METER, DISPATCH_METER,
                               resolve_lora_backend)
from repro.launch.mesh import make_serving_mesh
from repro.models import api
from repro.models.base import ModelConfig
from repro.models.lora_apply import (init_lora_slots, random_lora_weights,
                                     write_adapter_to_slot)
from repro.serving.handles import RequestHandle, prepare_request
from repro.serving.metrics import RequestRecord, RunMetrics
from repro.serving.shard_plan import ShardPlan


@dataclass
class EngineConfig:
    max_slots: int = 8
    max_len: int = 256
    n_lora_slots: int = 8
    r_max: int = 32
    n_adapters: int = 16
    predictor_accuracy: float = 0.8
    seed: int = 0
    # Paged KV data plane (S-LoRA-style unified paging). ``paged=False``
    # falls back to the dense (L, max_slots, max_len, Kh, Dh) slab;
    # families without paged decode support fall back automatically.
    paged: bool = True
    page_size: int = 16
    # LoRA data-plane backend: "auto" = Pallas bgmv/sgmv on TPU, einsum
    # elsewhere; "kernel"/"einsum" force a path (kernel runs Pallas
    # interpret mode off-TPU — the CI parity jobs use this).
    lora_backend: str = "auto"
    # Async adapter loading: dispatch the host→device slot write and
    # keep stepping; placement waits on readiness (paper §4 overlap).
    # False restores the blocking load (the A/B baseline).
    async_load: bool = True
    # Modeled H2D link bandwidth (GB/s) for load-latency experiments;
    # 0 = unmodeled (readiness is actual device-write completion).
    # Sync mode stalls the step loop for the modeled transfer time,
    # async mode only defers the affected adapter's readiness.
    h2d_gbps: float = 0.0
    # Prefetchers (paper §4.1): walk the wait queues / per-adapter
    # arrival histograms and issue non-blocking loads ahead of demand.
    queued_prefetch: bool = True
    histogram_prefetch: bool = True
    # Device-resident fused decode hot loop (DESIGN §2): one jit
    # dispatch fuses decode + sampling + cache_len advance (logits
    # never leave the device), the KV/token/length buffers are donated
    # into it (in-place update — no double-buffered KV), and batch
    # state (active mask, positions, sampling params, page table) stays
    # device-resident with updates only at place/finish/squash
    # boundaries. False restores the seed two-dispatch loop — the
    # baseline `benchmarks/decode_hotloop.py` A/Bs against.
    fused_hotloop: bool = True
    # Adaptive micro-horizon: up to this many decode steps run in one
    # on-device lax.scan (with an on-device done-mask) when no
    # admissions are due and no deadline/cancel sweep is armed, so the
    # host syncs K tokens at a time. Under backlog the horizon drops to
    # 1 so TTFT and admission latency are untouched. Keep below
    # page_size: horizon page pre-growth stays within one page of the
    # seed loop's per-boundary allocation.
    max_horizon: int = 8
    # Pipelined readback: dispatch horizon N+1 from the carried device
    # state before syncing horizon N's tokens (host bookkeeping runs
    # one horizon behind the device while the batch is stable).
    pipeline_readback: bool = True
    # Prefix KV reuse (ROADMAP 1): a token-id-keyed radix tree over the
    # paged pool keeps *prompt* KV pages resident after requests finish;
    # the next request with a matching prefix maps those pages into its
    # page table and prefills only the suffix (COW fork on a mid-page
    # divergence). Paged mode only. False restores the seed prefill
    # path bit-for-bit (the A/B baseline).
    prefix_cache: bool = True
    # What may share a cached page:
    #   "exact" — pages are keyed per adapter. LoRA here touches the
    #   q/k/v/o projections, so prompt KV is adapter-dependent; only
    #   same-adapter reuse is output-identical to the cache-off run.
    #   "alora" — prompt prefill runs with the *base* model and the
    #   adapter activates at generation ("Activated LoRA", PAPERS.md):
    #   prefix pages become adapter-invariant and one tree serves every
    #   adapter (true cross-adapter reuse). Changes prefill semantics
    #   for *all* requests (cache on or off) so the A/B stays paired.
    prefix_mode: str = "exact"
    # Mesh-sharded data plane (DESIGN §4): (data, model) shape of the
    # ("data", "model") serving mesh one engine spans — resolved through
    # ``launch.make_serving_mesh``, the single mesh factory. Weights and
    # LoRA-slot dout shard over "model"; KV pages, dense KV batch and
    # all per-request batch state over "data"; every jit'd entry point
    # gets explicit in/out shardings from the ``sharding.py`` rule
    # table. None = single-device (bit-for-bit the seed path). The
    # control plane (pool, scheduler, page tables) stays host-side and
    # global, so a mesh>1 engine is token-identical to mesh=1 — the
    # parity lock ``tests/test_sharded_engine.py`` asserts.
    mesh_shape: Optional[tuple] = None
    # Chunked prefill (ROADMAP 3 stepping stone): a prompt longer than
    # this many tokens prefills in chunks of this size — one chunk per
    # engine step — instead of one monolithic jit'd call, so in-flight
    # decodes keep stepping between chunks and a long prefill can no
    # longer stall them for its full duration. Paged mode only (chunks
    # write straight into the slot's pages via the suffix-prefill entry
    # point). Tokens are unchanged: each chunk attends to all previously
    # written positions, so the final logits match the monolithic
    # prefill bit-for-bit. 0 disables (the seed behavior, and the A/B
    # baseline of ``benchmarks/disagg_interference.py``).
    prefill_chunk_tokens: int = 0
    # Speculative draft–verify decoding (ROADMAP 5) inside the fused
    # hot loop: a small dense *draft* model (base weights only — the
    # LoRA adapters ride along at verify time) proposes up to ``spec_k``
    # tokens per row, the target scores all drafted positions in ONE
    # multi-token verify dispatch, and the accept mask / bonus token /
    # per-row cache_len rollback are computed on device — greedy tokens
    # stay bit-identical to the non-speculative loop; seeded sampling
    # uses (seed, position)-keyed rejection sampling. ``spec_k`` adapts
    # down with the measured acceptance EWMA, and the existing backlog /
    # deadline K=1 demotions turn speculation off for that step. Needs
    # the fused loop and a dense draft family; anything else warns once
    # at construction and falls back.
    spec_decode: bool = False
    spec_k: int = 4
    spec_draft: str = "internlm2-1.8b"


class AdapterCatalog:
    """Host-side LoRA adapter store shared by every engine replica.

    Holds the adapter weights ("host memory" in the paper) and the
    AdapterInfo metadata the control plane prices residency with. One
    catalog serves N engines: replicas keep per-device slot buffers but
    never duplicate the host-side weights (DESIGN §3).
    """

    def __init__(self, cfg: ModelConfig, n_adapters: int, r_max: int,
                 seed: int = 0):
        self.cfg = cfg
        self.r_max = r_max
        key = jax.random.PRNGKey(seed)
        self.ranks = [min(cfg.lora_ranks[i % len(cfg.lora_ranks)], r_max)
                      for i in range(n_adapters)]
        keys = jax.random.split(key, n_adapters)
        self.weights = {
            aid: random_lora_weights(keys[aid], self.ranks[aid], r_max,
                                     cfg.n_layers, cfg.d_model,
                                     cfg.q_dim, cfg.kv_dim)
            for aid in range(n_adapters)}
        kv_tok = 2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * 2
        lora_bytes = {aid: sum(
            int(np.prod(a.shape) + np.prod(b.shape)) * 2
            for a, b in self.weights[aid].values())
            for aid in self.weights}
        self.infos = {aid: AdapterInfo(
            adapter_id=aid, rank=self.ranks[aid],
            size_bytes=lora_bytes[aid],
            size_tokens=max(1, lora_bytes[aid] // kv_tok))
            for aid in self.weights}

    def __len__(self) -> int:
        return len(self.weights)

    def rank_of(self, adapter_id: int) -> int:
        return self.infos[adapter_id].rank


class ChameleonEngine:
    """Single-host engine over a (small) real model."""

    def __init__(self, cfg: ModelConfig, params: dict,
                 ecfg: EngineConfig | None = None,
                 scheduler_cls=ChameleonScheduler, cache_enabled=True,
                 catalog: AdapterCatalog | None = None,
                 clock: Optional[Callable[[], float]] = None,
                 draft: Optional[tuple] = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg or EngineConfig()
        e = self.ecfg
        key = jax.random.PRNGKey(e.seed)

        # --- serving mesh (DESIGN §4): one engine across N devices ---
        self.mesh = None
        self.plan: Optional[ShardPlan] = None
        self._collective = False      # mesh>1: COLLECTIVE_METER armed
        if e.mesh_shape is not None:
            d, m = e.mesh_shape
            self.mesh = make_serving_mesh(d * m, m)
            self.plan = ShardPlan(self.mesh, cfg)
            # Weights land sharded over "model" once, up front —
            # resident, never re-gathered per step.
            self._params_sh = self.plan.params(params)
            self.params = params = jax.device_put(params, self._params_sh)
            self._collective = self.mesh.size > 1

        # --- LoRA adapter catalog (host-side weights = "host memory") ---
        self.catalog = catalog or AdapterCatalog(cfg, e.n_adapters,
                                                 e.r_max, seed=e.seed)
        self.host_adapters = self.catalog.weights
        # Device adapter-slot buffers (per replica).
        self.lora = init_lora_slots(key, e.n_lora_slots, cfg.n_layers,
                                    cfg.d_model, cfg.q_dim, cfg.kv_dim,
                                    self.catalog.r_max)
        # Sharded slot arena: A replicated, B dout over "model" (the
        # LoRA delta adds to the sharded projection output without a
        # reshard — S-LoRA's TP partition strategy). Host adapter
        # weights upload *directly into this layout*: each device
        # receives only its dout slice of B, never the full tensor.
        self._lora_sh = None
        self._adapter_w_sh = None
        if self.plan is not None:
            self._lora_sh = self.plan.lora_slots(self.lora)
            self.lora = jax.device_put(self.lora, self._lora_sh)
            if self.host_adapters:
                self._adapter_w_sh = self.plan.adapter_weights(
                    next(iter(self.host_adapters.values())))
        self.slot_of: dict[int, int] = {}       # adapter_id -> lora slot
        self.free_slots = list(range(e.n_lora_slots))
        # Double-buffered async loads: slot writes land in the
        # *staging* chain (``_lora_staging``) while the jit'd steps
        # keep reading the active ``self.lora`` — no data dependency on
        # an in-flight transfer, so decode genuinely overlaps the copy.
        # ``_pending_loads`` maps adapter_id -> (staging snapshot to
        # swap active to — None once swapped, fresh device arrays to
        # poll, modeled-ready wall time); `_poll_loads` swaps snapshots
        # in FIFO order as writes land and READYs entries once the
        # modeled time also passed.
        self._lora_staging = self.lora
        self._pending_loads: dict[
            int, tuple[Optional[dict], tuple, float]] = {}
        self.n_async_loads = 0
        self._lora_backend = resolve_lora_backend(e.lora_backend)

        # --- memory pool in token units ---
        infos = self.catalog.infos
        cap = e.max_slots * e.max_len \
            + 4 * max(c.size_tokens for c in infos.values())
        self.paged = bool(e.paged) and api.supports_paged(cfg)
        # Per-device sizing is telemetry (n_shards); the *accounting*
        # stays global so admission/eviction decisions — and therefore
        # emitted tokens — are identical at every mesh shape.
        self.pool = MemoryPool(capacity_tokens=cap,
                               page_size=e.page_size if self.paged else 1,
                               n_shards=(self.mesh.size
                                         if self.mesh is not None else 1))
        self.cache = AdapterCache(self.pool, infos,
                                  enabled=cache_enabled,
                                  on_load=self._load_adapter,
                                  on_evict=self._evict_adapter,
                                  max_entries=e.n_lora_slots)
        pred = NoisyOraclePredictor(accuracy=e.predictor_accuracy,
                                    seed=e.seed)
        skw = dict(max_batch_requests=e.max_slots)
        if issubclass(scheduler_cls, ChameleonScheduler):
            skw["t_refresh"] = 5.0
        self.sched = scheduler_cls(self.pool, self.cache, infos, pred,
                                   **skw)
        # §4.1 prefetchers: their cache.prefetch calls run through the
        # same async `_load_adapter`, so prefetch H2D transfers overlap
        # decode compute instead of stalling the loop.
        self.q_prefetch = (QueuedRequestPrefetcher(self.cache)
                           if e.queued_prefetch else None)
        self.h_prefetch = (HistogramPrefetcher(self.cache)
                           if e.histogram_prefetch else None)
        # Paged mode: the engine holds exactly its allocated pages in
        # the pool (per req_id) and grows/frees them itself; the
        # scheduler's worst-case reservation is switched off.
        self.sched.reserve_from_pool = not self.paged

        # --- device state ---
        if self.paged:
            ps = e.page_size
            # One physical page per pool page + the reserved trash page
            # (page 0). Sizing pages to the *whole* pool is the unified
            # paging: KV can spread into memory adapters are not using.
            self.n_pages = cap // ps + 1
            if self.mesh is not None:
                # Round physical pages up to the data-axis size so the
                # page axis shards evenly. The pool still caps
                # allocation at the unrounded capacity and pages pop
                # off the free list in the same 1, 2, 3… order, so the
                # extra pages are never allocated — control-plane
                # decisions (hence tokens) stay mesh-invariant.
                ds = self.mesh.shape["data"]
                self.n_pages = -(-self.n_pages // ds) * ds
            self.pages_per_slot = -(-e.max_len // ps)
            self.kv_pages = api.init_paged_serve_state(
                cfg, self.n_pages, ps, jnp.float32)
            if self.plan is not None:
                kvp = self.plan.kv_pages(self.kv_pages[0].shape)
                self._kv_sh = (kvp, kvp)
                self.kv_pages = jax.device_put(self.kv_pages, self._kv_sh)
            self.page_table = np.zeros(
                (e.max_slots, self.pages_per_slot), np.int32)
            self.slot_pages: list[list[int]] = [[] for _ in
                                                range(e.max_slots)]
            self.free_pages = list(range(self.n_pages - 1, 0, -1))
            self.kv = None
        else:
            self.kv = api.init_serve_state(cfg, e.max_slots, e.max_len,
                                           jnp.float32)
            if self.plan is not None:
                kvd = self.plan.kv_dense(self.kv[0].shape)
                self._kv_sh = (kvd, kvd)
                self.kv = jax.device_put(self.kv, self._kv_sh)
        # --- prefix KV reuse (radix tree over the paged pool) ---
        if e.prefix_mode not in ("exact", "alora"):
            raise ValueError(f"unknown prefix_mode {e.prefix_mode!r}")
        self.prefix: Optional[PrefixCache] = (
            PrefixCache(self.pool, e.page_size)
            if self.paged and e.prefix_cache else None)
        # Per-slot shared prefix pages (subset of slot_pages the slot
        # only *references*: freed via release_shared, never returned
        # to free_pages directly).
        self.slot_shared: list[list[int]] = [[] for _ in range(e.max_slots)]
        self.prefix_hit_tokens = 0
        self.prefix_lookup_tokens = 0
        self.n_prefix_hits = 0          # placements with a nonzero match
        self.n_cow_forks = 0
        self.tokens = jnp.zeros((e.max_slots, 1), jnp.int32)
        self.cache_len = jnp.zeros((e.max_slots,), jnp.int32)
        self.active = np.zeros((e.max_slots,), bool)
        self.adapter_slot = jnp.zeros((e.max_slots,), jnp.int32)
        if self.plan is not None:
            # Batch state over "data". ``_batch_sh(ndim)`` reuses the
            # fitted row spec so a max_slots that doesn't divide the
            # data axis degrades to replicated everywhere consistently.
            row = self.plan.batch((e.max_slots,))
            ax = row.spec[0] if len(row.spec) else None
            self._batch_ax = ax
            self.tokens = self.plan.put(self.tokens, self._batch_sh(2))
            self.cache_len = self.plan.put(self.cache_len,
                                           self._batch_sh(1))
            self.adapter_slot = self.plan.put(self.adapter_slot,
                                              self._batch_sh(1))
        self.slot_req: list[Optional[Request]] = [None] * e.max_slots
        self.t0 = time.monotonic()
        self._clock = clock
        self.completed: list[Request] = []
        self.records: list[RequestRecord] = []
        self.outputs: dict[int, list[int]] = {}
        self._tbts: dict[int, list[float]] = {}
        self._last_tok: dict[int, float] = {}
        self.handles: dict[int, RequestHandle] = {}
        self.batch_occupancy: list[int] = []   # active slots per step
        self.n_preempted = 0                   # paged: out-of-page squashes
        self.n_cancelled = 0
        self.n_expired = 0
        # Chunked prefill (``EngineConfig.prefill_chunk_tokens``): slots
        # whose prompt is mid-prefill. The slot holds its request and
        # all prompt pages but stays off the active mask until the last
        # chunk produces the first token, so decode steps interleave
        # with the chunks. slot -> {"req", "prompt", "done"}.
        self._chunked: dict[int, dict] = {}
        self.n_chunked_prefills = 0
        # Disaggregated serving (serving/disagg.py): requests detached
        # from decode by ``begin_migration`` while their KV crosses to
        # a decode replica. The slot stays occupied (slot_req set,
        # active False) until complete/abort, so its pool holds and
        # shared-page refs keep the KV pinned mid-handoff.
        # req_id -> slot.
        self._migrating: dict[int, int] = {}
        self.n_kv_exports = 0
        self.n_kv_imports = 0
        self.kv_handoff_bytes = 0
        # Lifecycle fast path: deadline/cancel sweeps run only once a
        # request armed them (keeps the hot step loop scan-free).
        self._deadlines_armed = False
        self._cancel_armed = False
        self._cancel_races: list[Request] = []

        # --- device-resident hot loop state (DESIGN §2) ---
        # ``batch_epoch`` counts batch-composition changes (place /
        # finish / squash); the device-side batch state — active mask,
        # sample positions, per-row sampling params, decode budgets,
        # stop-token matrix — is rebuilt from the Python requests only
        # when the epoch moved, and otherwise carried on device across
        # fused steps. Same for the paged page table: ``self.page_table``
        # (host numpy) is uploaded only when a page was allocated or
        # freed, not per step.
        self.fused = bool(e.fused_hotloop) and api.supports_fused(cfg)
        if e.fused_hotloop and not self.fused:
            warnings.warn(
                f"fused_hotloop=True ignored: model family "
                f"{cfg.family.name} has no fused decode path "
                f"(api.supports_fused) — falling back to the per-step "
                f"seed decode loop", RuntimeWarning, stacklevel=2)
        self.batch_epoch = 0
        self._dev: Optional[dict] = None
        self._dev_epoch = -1
        self._page_table_dev = None
        self._page_table_dirty = True
        # One dispatched-but-unsynced horizon: (toks (K, B) on device,
        # emits (K, B) on device, K). Host bookkeeping for it runs at
        # the next step boundary — after the *next* horizon was
        # dispatched, when the batch is stable (pipelined readback).
        self._inflight: Optional[tuple] = None

        # --- speculative draft–verify decoding (ROADMAP 5) ---
        # ``self.spec`` is the *effective* switch: spec_decode=True with
        # a validated dense draft on a fused single-device engine.
        # Invalid draft configs raise here (construction), never in jit.
        self.spec = False
        self.draft_cfg: Optional[ModelConfig] = None
        self.draft_params: Optional[dict] = None
        self.draft_kv = None
        self.spec_drafted_tokens = 0
        self.spec_accepted_tokens = 0
        self.n_spec_dispatches = 0
        self.n_spec_draft_dispatches = 0
        self.n_spec_verify_dispatches = 0
        self._spec_ewma = 1.0     # acceptance EWMA → adaptive spec_k
        if e.spec_decode:
            self._init_spec(draft)

        self._decode_jit = jax.jit(self._decode_fn)
        self._decode_paged_jit = jax.jit(self._decode_paged_fn)
        # Fused decode+sample horizon: tokens/KV/cache_len/active/
        # positions are *donated* — XLA updates the KV slab (the big
        # buffer) in place instead of allocating a second copy per
        # step. K and the all-greedy fast path are static (bounded
        # variants: K is bucketed to powers of two).
        self._fused_jit = jax.jit(
            self._fused_fn, static_argnames=("K", "all_greedy"),
            donate_argnums=(2, 3, 4, 5, 6))
        self._fused_paged_jit = jax.jit(
            self._fused_paged_fn, static_argnames=("K", "all_greedy"),
            donate_argnums=(2, 3, 5, 6, 7))
        self._prefill_jit = jax.jit(self._prefill_fn,
                                    static_argnames=("S",))
        # Suffix prefill straight into donated KV pages (prefix path).
        self._prefill_paged_jit = jax.jit(self._prefill_paged_fn,
                                          static_argnames=("S",),
                                          donate_argnums=(3,))
        self._sample_jit = jax.jit(api.sample_tokens)
        if self.spec:
            # Speculative round: tokens, target KV, draft KV, cache_len,
            # active and positions are donated (same in-place invariant
            # as the fused horizon); spec_k / n_rounds / all_greedy are
            # static (spec_k is bucketed to powers of two, n_rounds
            # derives from it, so jit variants stay bounded).
            self._spec_jit = jax.jit(
                self._spec_fn,
                static_argnames=("spec_k", "n_rounds", "all_greedy"),
                donate_argnums=(2, 3, 4, 5, 6, 7))
            self._spec_paged_jit = jax.jit(
                self._spec_paged_fn,
                static_argnames=("spec_k", "n_rounds", "all_greedy"),
                donate_argnums=(2, 3, 5, 6, 7, 8))
            # Draft-KV catch-up: batched multi-token draft forward that
            # replays tokens the draft cache is missing (placement,
            # prefix-cache import, chunked prefill, squash re-execution
            # all leave the draft behind the target; see _draft_sync).
            self._draft_catchup_jit = jax.jit(
                self._draft_catchup_fn, static_argnames=("S",),
                donate_argnums=(2,))
        # Prefill shapes vary per (B, S) admission bucket, so their
        # sharded jits (fitted in/out shardings per bucket) are built
        # lazily; the fixed-shape decode/fused jits above are replaced
        # with explicitly-sharded versions here.
        self._sharded_prefill_cache: dict = {}
        if self.plan is not None:
            self._install_sharded_jits()

    # ------------------------------------- speculative decoding setup
    def _init_spec(self, draft: Optional[tuple]) -> None:
        """Validate and build the speculative-decoding state.

        ``draft`` is an optional ``(draft_cfg, draft_params)`` pair
        (tests and benchmarks pass reduced models); None resolves
        ``EngineConfig.spec_draft`` through the config registry and
        initialises base weights from the engine seed. Config errors —
        non-dense draft family, vocab mismatch, bad spec_k — raise
        here, at engine construction, never inside jit; unsupported
        *engine* shapes (non-fused target, mesh>1) warn once and leave
        speculation off."""
        e = self.ecfg
        if draft is not None:
            draft_cfg, draft_params = draft
        else:
            from repro.configs import get_config
            draft_cfg = get_config(e.spec_draft)
            draft_params = None
        if not api.supports_spec_draft(draft_cfg):
            raise ValueError(
                f"spec_decode=True needs a dense draft model: draft "
                f"{draft_cfg.name!r} is family {draft_cfg.family.name}, "
                f"which has no dense-KV decode_step for the speculative "
                f"scan (api.supports_spec_draft). Pick a Family.DENSE "
                f"config for EngineConfig.spec_draft (e.g. "
                f"'internlm2-1.8b') or turn spec_decode off.")
        if draft_cfg.vocab_size != self.cfg.vocab_size:
            raise ValueError(
                f"spec_decode draft {draft_cfg.name!r} has vocab_size="
                f"{draft_cfg.vocab_size} but the target "
                f"{self.cfg.name!r} has vocab_size="
                f"{self.cfg.vocab_size}; draft and target must share a "
                f"vocabulary for draft tokens to be target-scorable.")
        if e.spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {e.spec_k}")
        if not self.fused:
            warnings.warn(
                f"spec_decode=True ignored: target family "
                f"{self.cfg.family.name} (or fused_hotloop=False) has "
                f"no fused decode path to speculate inside — running "
                f"the non-speculative loop", RuntimeWarning,
                stacklevel=2)
            return
        if self.mesh is not None:
            warnings.warn(
                "spec_decode=True ignored on a mesh-sharded engine: "
                "the speculative jits are not in the sharding rule "
                "table yet — running the non-speculative fused loop",
                RuntimeWarning, stacklevel=2)
            return
        if draft_params is None:
            draft_params = api.init_params(
                draft_cfg, jax.random.PRNGKey(e.seed + 1))
        self.draft_cfg = draft_cfg
        self.draft_params = draft_params
        # The draft KV is a dense slab outside the paged pool: the
        # draft is small and adapter-free, so its cache is priced as
        # part of the (constant) speculation overhead, not as
        # per-request pool occupancy. "Freeing" a slot's draft KV is
        # bookkeeping: _draft_len drops to 0 and the slab rows are
        # rewritten by the next occupant's catch-up.
        self.draft_kv = api.init_serve_state(
            draft_cfg, e.max_slots, e.max_len, jnp.float32)
        # Tokens of the *target* cache the draft cache mirrors, per
        # slot (host truth — the lazy catch-up syncs the gap).
        self._draft_len = np.zeros(e.max_slots, np.int64)
        self.spec = True

    # --------------------------------------------- sharded data plane
    def _batch_sh(self, ndim: int):
        """NamedSharding for (max_slots, ...) batch-state tensors."""
        return self.plan.named(
            P(self._batch_ax, *([None] * (ndim - 1))))

    def _act_scope(self):
        """Activation-sharding anchors (constrain_* in models/) are
        armed only while a mesh engine traces/dispatches — scoped, not
        global, so single-device engines in the same process (cluster
        replicas, A/B baselines) are untouched."""
        if self.mesh is None:
            return contextlib.nullcontext()
        # Batch axes are *empty* in exact mode: data-splitting the batch
        # halves every local matmul's M, and XLA picks a different
        # blocking (FP summation order) for the smaller shape — measured
        # 2e-6 logit drift at mesh (2,2) even with replicated weights.
        # Compute therefore runs at full batch; the "data" axis shards
        # storage (KV pages, batch-state vectors) via the jit in/out
        # shardings, and GSPMD inserts the elementwise (exact)
        # gather/scatter at the jit boundary.
        return activation_sharding(
            (), model_size=self.mesh.shape["model"],
            mesh=self.mesh, exact_reductions=True)

    def _install_sharded_jits(self) -> None:
        """Explicit in/out shardings for the fixed-shape entry points.

        Derived entirely from the ``sharding.py`` rule table via the
        engine's ShardPlan: weights + LoRA-B over "model", KV (pages or
        dense batch) and every per-request vector over "data". Donated
        buffers (tokens/KV/cache_len/active/positions) keep identical
        in- and out-shardings so XLA's in-place aliasing survives
        sharding. Only the active data plane's pair is rebuilt; the
        other keeps its unsharded default (it is never called)."""
        b1, b2 = self._batch_sh(1), self._batch_sh(2)
        hor = self.plan.named(P(None, self._batch_ax))   # (K, B) blocks
        logits_sh = self.plan.logits(
            (self.ecfg.max_slots, self.cfg.vocab_size))
        params_sh, lora_sh = self._params_sh, self._lora_sh
        kv_sh = self._kv_sh
        if self.paged:
            self._decode_paged_jit = jax.jit(
                self._decode_paged_fn,
                in_shardings=(params_sh, lora_sh, b2, kv_sh, b2, b1, b1),
                out_shardings=(logits_sh, kv_sh))
            carry = (b2, kv_sh, b1, b1, b1)

            # pjit rejects *any* kwargs once in_shardings is explicit,
            # so the static knobs move to trailing positional args and
            # a thin wrapper keeps the call sites' K=/all_greedy=
            # keyword surface identical to the unsharded jits.
            def fp(params, lora, tokens, kv_pages, page_table,
                   cache_len, active, positions, adapter_slot, budget,
                   stop, temp, topk, topp, seeds, K, all_greedy):
                return self._fused_paged_fn(
                    params, lora, tokens, kv_pages, page_table,
                    cache_len, active, positions, adapter_slot, budget,
                    stop, temp, topk, topp, seeds, K=K,
                    all_greedy=all_greedy)
            fp_jit = jax.jit(
                fp, static_argnums=(15, 16),
                donate_argnums=(2, 3, 5, 6, 7),
                in_shardings=(params_sh, lora_sh, b2, kv_sh, b2, b1,
                              b1, b1, b1, b1, b2, b1, b1, b1, b1),
                out_shardings=(carry, hor, hor))
            self._fused_paged_jit = (
                lambda *a, K, all_greedy: fp_jit(*a, K, all_greedy))
        else:
            self._decode_jit = jax.jit(
                self._decode_fn,
                in_shardings=(params_sh, lora_sh, b2, kv_sh, b1, b1),
                out_shardings=(logits_sh, kv_sh))
            carry = (b2, kv_sh, b1, b1, b1)

            def fd(params, lora, tokens, kv, cache_len, active,
                   positions, adapter_slot, budget, stop, temp, topk,
                   topp, seeds, K, all_greedy):
                return self._fused_fn(
                    params, lora, tokens, kv, cache_len, active,
                    positions, adapter_slot, budget, stop, temp, topk,
                    topp, seeds, K=K, all_greedy=all_greedy)
            fd_jit = jax.jit(
                fd, static_argnums=(14, 15),
                donate_argnums=(2, 3, 4, 5, 6),
                in_shardings=(params_sh, lora_sh, b2, kv_sh, b1, b1,
                              b1, b1, b1, b2, b1, b1, b1, b1),
                out_shardings=(carry, hor, hor))
            self._fused_jit = (
                lambda *a, K, all_greedy: fd_jit(*a, K, all_greedy))

    def _get_prefill_jit(self, B: int, S: int):
        """Sharded dense prefill jit for one (B, S) bucket. pjit input
        shardings demand exact divisibility, so each bucket fits its
        own specs (B=1 rows degrade to replicated)."""
        if self.plan is None:
            return self._prefill_jit
        key = ("dense", B, S)
        jitf = self._sharded_prefill_cache.get(key)
        if jitf is None:
            pl, cfg = self.plan, self.cfg
            lora_sh = (None if self.ecfg.prefix_mode == "alora"
                       else self._lora_sh)
            bB = pl.batch((B,))
            kv = pl.kv_dense((cfg.n_layers, B, S, cfg.n_kv_heads,
                              cfg.head_dim))
            jitf = jax.jit(
                self._prefill_fn, static_argnames=("S",),
                in_shardings=(self._params_sh, lora_sh,
                              pl.batch((B, S)), bB, bB),
                out_shardings=(pl.logits((B, cfg.vocab_size)),
                               (kv, kv)))
            self._sharded_prefill_cache[key] = jitf
        return jitf

    def _get_prefill_paged_jit(self, B: int, S: int):
        """Sharded suffix-prefill jit for one (B, S) bucket; the KV
        pool keeps its fixed pages-over-"data" sharding (donated)."""
        if self.plan is None:
            return self._prefill_paged_jit
        key = ("paged", B, S)
        jitf = self._sharded_prefill_cache.get(key)
        if jitf is None:
            pl, cfg = self.plan, self.cfg
            lora_sh = (None if self.ecfg.prefix_mode == "alora"
                       else self._lora_sh)
            bB = pl.batch((B,))
            jitf = jax.jit(
                self._prefill_paged_fn, static_argnames=("S",),
                donate_argnums=(3,),
                in_shardings=(self._params_sh, lora_sh,
                              pl.batch((B, S)), self._kv_sh,
                              pl.batch((B, self.pages_per_slot)),
                              bB, bB, bB),
                out_shardings=(pl.logits((B, cfg.vocab_size)),
                               self._kv_sh))
            self._sharded_prefill_cache[key] = jitf
        return jitf

    def _commit(self, x, sh):
        """Re-commit a host-updated device value to its planned
        sharding before a jit with explicit in_shardings sees it
        (eager ``.at[].set`` preserves sharding in practice, making
        this a free no-op — but pjit hard-errors on a mismatch, so the
        invariant is enforced, not assumed)."""
        if self.plan is None:
            return x
        return jax.device_put(x, sh)

    def _commit_batch_state(self) -> None:
        if self.plan is None:
            return
        self.tokens = self._commit(self.tokens, self._batch_sh(2))
        self.cache_len = self._commit(self.cache_len, self._batch_sh(1))
        self.adapter_slot = self._commit(self.adapter_slot,
                                         self._batch_sh(1))
        if self.paged:
            self.kv_pages = self._commit(self.kv_pages, self._kv_sh)
        else:
            self.kv = self._commit(self.kv, self._kv_sh)

    # ------------------------------------------------------------- clock
    def now(self) -> float:
        if self._clock is not None:
            return self._clock()
        return time.monotonic() - self.t0

    # ----------------------------------------------------- adapter moves
    def _load_adapter(self, info: AdapterInfo) -> None:
        """Cache ``on_load`` hook: stage the adapter into a device slot.

        Async mode (default) dispatches the host→device write into the
        *staging* buffer chain and marks the entry LOADING; the jit'd
        steps keep reading the active ``self.lora``, which has no data
        dependency on the in-flight transfer, so decode overlaps the
        copy for real. `_poll_loads` swaps the staging snapshot in once
        the write lands. Sync mode blocks until the write (plus any
        modeled H2D time) lands — the S-LoRA baseline the fig10 loading
        A/B measures against.
        """
        if not self.free_slots:
            raise RuntimeError(
                "adapter slot accounting drift: no free LoRA slot for "
                f"adapter {info.adapter_id} "
                f"(n_lora_slots={self.ecfg.n_lora_slots}, "
                f"slot_of={dict(sorted(self.slot_of.items()))}, "
                f"cache_resident={sorted(self.cache.resident_ids())}, "
                f"cache_loading={sorted(self.cache.loading_ids())})")
        slot = self.free_slots.pop()
        self.slot_of[info.adapter_id] = slot
        self._lora_staging = write_adapter_to_slot(
            self._lora_staging, self.host_adapters[info.adapter_id], slot,
            shardings=self._adapter_w_sh)
        if self._lora_sh is not None:
            # The slot write preserves the arena sharding; re-commit so
            # the jits' explicit in_shardings never see a drifted one.
            self._lora_staging = jax.device_put(self._lora_staging,
                                                self._lora_sh)
        e = self.ecfg
        delay = (info.size_bytes / (e.h2d_gbps * 1e9)
                 if e.h2d_gbps > 0 else 0.0)
        if e.async_load:
            self.cache.mark_loading(info.adapter_id)
            self._pending_loads[info.adapter_id] = (
                self._lora_staging,
                jax.tree_util.tree_leaves(self._lora_staging),
                self.now() + delay)
            self.n_async_loads += 1
        else:
            jax.block_until_ready(self._lora_staging)
            self.lora = self._lora_staging
            if delay:
                time.sleep(delay)   # modeled H2D stall blocks the loop

    def _poll_loads(self) -> None:
        """Retire in-flight loads; runs every step, never blocks.

        Two decoupled transitions so snapshots die fast: (1) once a
        load's device write completes, its staging snapshot is swapped
        into the active buffer and *dropped* — snapshots live only for
        the actual write (ms), not the modeled transfer window, so at
        most a couple of extra slot-buffer copies exist transiently
        during a load burst; (2) the cache entry flips READY only after
        the modeled ``h2d_gbps`` time also elapsed. Swaps are FIFO:
        each snapshot was built on the previous one, so activating the
        first *unswapped* head is monotone and never exposes a later
        in-flight write.
        """
        now = self.now()
        for aid in list(self._pending_loads):
            staged, leaves, t_ready = self._pending_loads[aid]
            if staged is not None:
                if not all(x.is_ready() for x in leaves):
                    break           # FIFO: later writes chain on this one
                self.lora = staged
                self._pending_loads[aid] = (None, (), t_ready)
            if now >= t_ready:
                del self._pending_loads[aid]
                self.cache.mark_ready(aid)

    def flush_loads(self) -> None:
        """Barrier: block until every in-flight load lands (warmup /
        stats resets — a rebased clock must not strand a modeled
        ready-time in the old epoch)."""
        if not self._pending_loads:
            return
        jax.block_until_ready(self._lora_staging)
        self.lora = self._lora_staging
        for aid in list(self._pending_loads):
            del self._pending_loads[aid]
            self.cache.mark_ready(aid)

    def _evict_adapter(self, info: AdapterInfo) -> None:
        slot = self.slot_of.pop(info.adapter_id)
        self.free_slots.append(slot)
        # LOADING entries are never eviction candidates, so a pending
        # load here is unreachable; drop it anyway to stay consistent.
        self._pending_loads.pop(info.adapter_id, None)

    # ------------------------------------------------------- jit'd steps
    # ``self._lora_backend`` is a resolved Python constant captured by
    # these jit'd closures, so one engine = one backend = one coherent
    # jit cache (no per-call retraces on the backend choice).
    def _decode_fn(self, params, lora, tokens, kv, cache_len,
                   adapter_slot):
        return api.decode_step(self.cfg, params, tokens, kv, cache_len,
                               lora=lora, adapter_idx=adapter_slot,
                               lora_backend=self._lora_backend)

    def _decode_paged_fn(self, params, lora, tokens, kv_pages,
                         page_table, cache_len, adapter_slot):
        return api.decode_step_paged(self.cfg, params, tokens, kv_pages,
                                     page_table, cache_len, lora=lora,
                                     adapter_idx=adapter_slot,
                                     lora_backend=self._lora_backend)

    def _fused_fn(self, params, lora, tokens, kv, cache_len, active,
                  positions, adapter_slot, budget, stop, temp, topk,
                  topp, seeds, *, K, all_greedy):
        return api.decode_fused(
            self.cfg, params, tokens, kv, cache_len, active, positions,
            budget, stop, temp, topk, topp, seeds, n_steps=K,
            all_greedy=all_greedy, max_ctx=self.ecfg.max_len, lora=lora,
            adapter_idx=adapter_slot, lora_backend=self._lora_backend)

    def _fused_paged_fn(self, params, lora, tokens, kv_pages, page_table,
                        cache_len, active, positions, adapter_slot,
                        budget, stop, temp, topk, topp, seeds, *, K,
                        all_greedy):
        return api.decode_fused_paged(
            self.cfg, params, tokens, kv_pages, page_table, cache_len,
            active, positions, budget, stop, temp, topk, topp, seeds,
            n_steps=K, all_greedy=all_greedy, max_ctx=self.ecfg.max_len,
            lora=lora, adapter_idx=adapter_slot,
            lora_backend=self._lora_backend)

    def _spec_fn(self, params, lora, tokens, kv, draft_kv, cache_len,
                 active, positions, adapter_slot, budget, stop, temp,
                 topk, topp, seeds, *, spec_k, n_rounds, all_greedy):
        return api.decode_spec_fused(
            self.cfg, params, self.draft_cfg, self.draft_params, tokens,
            kv, draft_kv, cache_len, active, positions, budget, stop,
            temp, topk, topp, seeds, spec_k=spec_k, n_rounds=n_rounds,
            all_greedy=all_greedy, max_ctx=self.ecfg.max_len, lora=lora,
            adapter_idx=adapter_slot, lora_backend=self._lora_backend)

    def _spec_paged_fn(self, params, lora, tokens, kv_pages, page_table,
                       draft_kv, cache_len, active, positions,
                       adapter_slot, budget, stop, temp, topk, topp,
                       seeds, *, spec_k, n_rounds, all_greedy):
        return api.decode_spec_fused_paged(
            self.cfg, params, self.draft_cfg, self.draft_params, tokens,
            kv_pages, page_table, draft_kv, cache_len, active,
            positions, budget, stop, temp, topk, topp, seeds,
            spec_k=spec_k, n_rounds=n_rounds, all_greedy=all_greedy,
            max_ctx=self.ecfg.max_len, lora=lora,
            adapter_idx=adapter_slot, lora_backend=self._lora_backend)

    def _draft_catchup_fn(self, draft_params, tokens, draft_kv, start,
                          seq_len, S):
        del S
        _, dkv = api.verify(self.draft_cfg, draft_params, tokens,
                            draft_kv, start, seq_len=seq_len)
        return dkv

    def _prefill_fn(self, params, lora, tokens, adapter_slot, last_pos,
                    S):
        del S
        return api.prefill(self.cfg, params, tokens, lora=lora,
                           adapter_idx=adapter_slot, last_pos=last_pos,
                           lora_backend=self._lora_backend)

    def _prefill_paged_fn(self, params, lora, tokens, kv_pages,
                          page_table, start, seq_len, adapter_slot, S):
        del S
        return api.prefill_paged(self.cfg, params, tokens, kv_pages,
                                 page_table, start, seq_len, lora=lora,
                                 adapter_idx=adapter_slot,
                                 lora_backend=self._lora_backend)

    def _prefill_lora(self):
        """LoRA tensors for *prefill*. aLoRA prefix mode computes prompt
        KV with the base model (the adapter activates at generation), so
        cached prefix pages are adapter-invariant; decode is untouched."""
        return None if self.ecfg.prefix_mode == "alora" else self.lora

    # ------------------------------------------------------- page moves
    def _alloc_page(self, req_id: int, now: float) -> Optional[int]:
        """One physical page for ``req_id``; None when HBM is truly full.

        The pool gate runs first: if the unified pool has no free page,
        idle prefix-cache pages (refcount 1, LRU) are reclaimed, then
        the adapter cache is asked to shrink (§4.1 dynamic downsizing,
        second-tier protection for queued adapters applies). Cached
        prefixes and resident adapters are both *accounted* idle memory
        that live requests displace on demand. Physical pages cannot
        run out before pool pages — the page arrays are sized to the
        whole pool.
        """
        ps = self.pool.page_size
        if self.pool.free_tokens < ps and self.prefix is not None:
            self.free_pages.extend(self.prefix.evict_lru(1))
        if self.pool.free_tokens < ps and not self.cache.shrink_for_requests(
                ps, now, self.sched.queued_adapter_ids()):
            return None
        if not self.free_pages:
            return None
        try:
            self.pool.reserve_request_pages(req_id, 1)
        except PoolError:
            return None
        return self.free_pages.pop()

    def _grow_slot(self, slot: int, n_pages: int, now: float) -> bool:
        """Grow a slot's page list by ``n_pages``; all-or-nothing."""
        req = self.slot_req[slot]
        got = []
        for _ in range(n_pages):
            pid = self._alloc_page(req.req_id, now)
            if pid is None:
                for p in got:
                    self.free_pages.append(p)
                if got:
                    self.pool.shrink_request(
                        req.req_id, len(got) * self.pool.page_size)
                return False
            got.append(pid)
        base = len(self.slot_pages[slot])
        self.slot_pages[slot].extend(got)
        self.page_table[slot, base:base + len(got)] = got
        self._page_table_dirty = True
        return True

    def _free_slot_pages(self, slot: int, req_id: int) -> None:
        if not self.paged:
            return
        shared = self.slot_shared[slot]
        if shared:
            # Drop the slot's references; pages only return to the
            # physical free list once the prefix tree also lets go
            # (eviction) — the tree holds its own pool ref, so this
            # normally frees nothing and the prefix stays resident.
            self.free_pages.extend(self.pool.release_shared(shared))
            self.slot_shared[slot] = []
            shared_set = set(shared)
            private = [p for p in self.slot_pages[slot]
                       if p not in shared_set]
        else:
            private = self.slot_pages[slot]
        self.free_pages.extend(private)
        self.slot_pages[slot] = []
        self.page_table[slot, :] = 0
        self._page_table_dirty = True
        self.pool.release_request(req_id)

    def _stash_progress(self, req: Request) -> None:
        """Squash/preemption: move the request's already-streamed tokens
        and TBT records onto the request itself so the requeue keeps
        them (re-execution regenerates the same prefix deterministically
        and never re-streams it — the handle dedups by position)."""
        rid = req.req_id
        req.stash_progress(self.outputs.pop(rid, None),
                           self._tbts.pop(rid, None),
                           self._last_tok.pop(rid, None))

    def _squash_slot(self, slot: int) -> None:
        """Free a slot and requeue its request (squash path: bypass
        misprediction or page preemption — the request re-executes,
        keeping its streamed prefix)."""
        req = self.slot_req[slot]
        self.active[slot] = False
        self.slot_req[slot] = None
        self.batch_epoch += 1
        if self.spec:
            self._draft_len[slot] = 0    # draft KV freed with the slot
        self._stash_progress(req)
        self._free_slot_pages(slot, req.req_id)
        self.sched.on_squash(req, self.now())

    def _preempt(self, slot: int) -> None:
        """Out of pages mid-flight: squash, and count it."""
        self.n_preempted += 1
        self._squash_slot(slot)

    # ---------------------------------------------------------- lifecycle
    def submit(self, req: Request, *,
               sampling: Optional[SamplingParams] = None,
               on_token=None, ttl: Optional[float] = None,
               ) -> RequestHandle:
        """Non-blocking: enqueue with the scheduler; no device work.
        Returns the request's handle (DESIGN §3 serving surface)."""
        now = self.now()
        handle = prepare_request(req, self, now, sampling, on_token, ttl)
        self.handles[req.req_id] = handle
        if req.deadline is not None:
            self._deadlines_armed = True
        self.sched.submit(req, now)
        if self.h_prefetch is not None:
            self.h_prefetch.observe_arrival(req.adapter_id, now)
        return handle

    def cancel(self, handle) -> bool:
        """Cancel a request. Queued / LOADING-deferred requests release
        their adapter pin and terminate immediately; RUNNING requests
        are finalised at the next step boundary (the in-flight jit'd
        decode cannot be interrupted). False once already terminal."""
        req = handle.req if isinstance(handle, RequestHandle) else handle
        if req.terminal:
            return False
        now = self.now()
        if any(r is req for r in self.slot_req):
            req.cancel_requested = True    # step() sweeps it
            self._cancel_armed = True      # fused loop: force a sweep
            return True
        if self.sched.cancel(req, now):
            self._finalize_unplaced(req, RequestState.CANCELLED, now)
            return True
        # Mid-transition race (e.g. cancelled from an on_token callback
        # while being placed): mark it; the step sweep resolves it.
        req.cancel_requested = True
        self._cancel_armed = True
        self._cancel_races.append(req)
        return True

    def _finalize_unplaced(self, req: Request, state: RequestState,
                           now: float) -> None:
        """Terminal transition for a request that never held a slot
        (queued cancel / queue-side deadline expiry). The scheduler
        already released the adapter pin; queued requests hold no pool
        reservation or quota charges."""
        req.state = state
        req.finish_time = now
        if state is RequestState.CANCELLED:
            self.n_cancelled += 1
        else:
            self.n_expired += 1

    # ------------------------------------------------------ token delivery
    def _record_token(self, req: Request, pos: int, tok: int,
                      now: float) -> None:
        """Record (and stream) the token at output position ``pos``.

        Re-executed positions after a squash overwrite in place and are
        *not* re-streamed or re-timed: the TBT of the first genuinely
        new token is measured from the last token the user actually saw
        (``last_stream_time`` survives the requeue)."""
        rid = req.req_id
        out = self.outputs[rid]
        if pos < len(out):
            out[pos] = tok         # deterministic regeneration of prefix
            return
        out.append(tok)
        if pos >= 1:
            tbts = self._tbts[rid]
            if len(tbts) < pos:
                tbts.append(now - self._last_tok[rid])
        self._last_tok[rid] = now
        handle = self.handles.get(rid)
        if handle is not None and not req.cancel_requested:
            # A cancelled request's slot is finalised at the next step
            # boundary, but tokens already in flight (the seed loop's
            # current step; up to two undrained horizons on the fused
            # loop) are still recorded internally — they must not reach
            # the handle after cancel() returned.
            handle._push(pos, tok)

    def _free_slots(self) -> list[int]:
        """Slots a new placement may take: off the active mask *and*
        holding no request. A slot can be inactive yet occupied — a
        chunked prefill in progress, or a MIGRATING request whose KV is
        mid-handoff — and clobbering either would corrupt its pages."""
        return [s for s in range(self.ecfg.max_slots)
                if not self.active[s] and self.slot_req[s] is None]

    def _place_batch(self, reqs: list[Request]) -> None:
        """Batched prefill admission: one jit'd prefill over a (B, S)
        bucket covers every request admitted this iteration.

        Right-padding is safe under causal attention (positions past
        ``last_pos`` never influence the selected logits), and padded
        batch rows run masked garbage exactly like inactive decode
        slots. Buckets are powers of two so recompiles stay bounded.
        """
        if not reqs:
            return
        e = self.ecfg
        if self.paged and e.prefill_chunk_tokens > 0:
            big = [r for r in reqs if r.input_len > e.prefill_chunk_tokens]
            if big:
                reqs = [r for r in reqs
                        if r.input_len <= e.prefill_chunk_tokens]
                self._start_chunked(big)
            if not reqs:
                return
        if self.prefix is not None:
            return self._place_batch_prefix(reqs)
        free = self._free_slots()
        if self.paged:
            # Allocate each request's prompt pages up front; a request
            # whose prompt cannot get pages even after shrinking the
            # cache bounces straight back to its queue (squash path).
            now = self.now()
            placed = []
            for req in reqs:
                slot = free[len(placed)]
                self.slot_req[slot] = req
                if self._grow_slot(slot, self.pool.pages_for(req.input_len),
                                   now):
                    placed.append(req)
                else:
                    self.slot_req[slot] = None
                    self.n_preempted += 1
                    self.sched.on_squash(req, now)
            reqs = placed
            if not reqs:
                return
        S = 1 << max(3, (max(r.input_len for r in reqs) - 1).bit_length())
        B = 1 << max(0, (len(reqs) - 1).bit_length())
        toks = np.zeros((B, S), np.int32)
        last_pos = np.zeros((B,), np.int32)
        lslots = np.zeros((B,), np.int32)
        for i, req in enumerate(reqs):
            toks[i, :req.input_len] = self._prompt_tokens(req)
            last_pos[i] = req.input_len - 1
            lslots[i] = self.slot_of[req.adapter_id]
        with self._act_scope():
            logits, (k_new, v_new) = self._get_prefill_jit(B, S)(
                self.params, self._prefill_lora(), jnp.asarray(toks),
                jnp.asarray(lslots), jnp.asarray(last_pos), S)
        if self._all_greedy(reqs):
            first_toks = np.asarray(
                jnp.argmax(logits, axis=-1).astype(jnp.int32))
        else:
            first_toks = np.asarray(self._sample_jit(
                logits, *self._sampling_arrays(reqs, B, first=True)))
        if self.paged:
            kp, vp = self.kv_pages
        else:
            k, v = self.kv
        now = self.now()
        ps = self.pool.page_size
        for i, req in enumerate(reqs):
            slot = free[i]
            self.active[slot] = True
            self.slot_req[slot] = req
            L = req.input_len
            if self.paged:
                pages = self.slot_pages[slot]
                for j in range(0, L, ps):
                    pid = pages[j // ps]
                    n = min(ps, L - j)
                    kp = kp.at[:, pid, :n].set(k_new[:, i, j:j + n])
                    vp = vp.at[:, pid, :n].set(v_new[:, i, j:j + n])
            else:
                k = k.at[:, slot, :L].set(k_new[:, i, :L])
                v = v.at[:, slot, :L].set(v_new[:, i, :L])
            first = int(first_toks[i])
            self.tokens = self.tokens.at[slot, 0].set(first)
            self.cache_len = self.cache_len.at[slot].set(L)
            self.adapter_slot = self.adapter_slot.at[slot].set(
                int(lslots[i]))
            req.generated = 1
            rid = req.req_id
            if req.preserved_tokens:
                # Squash survivor: restore the streamed prefix and its
                # latency records; re-execution regenerates (and the
                # handle ignores) positions the user already has.
                self.outputs[rid] = list(req.preserved_tokens)
                self._tbts[rid] = list(req.preserved_tbts)
                if req.last_stream_time is not None:
                    self._last_tok[rid] = req.last_stream_time
            else:
                self.outputs[rid] = []
                self._tbts[rid] = []
                req.first_token_time = now
            self._record_token(req, 0, first, now)
        if self.paged:
            self.kv_pages = (kp, vp)
        else:
            self.kv = (k, v)
        self.batch_epoch += 1      # admission boundary: device batch
        for i, req in enumerate(reqs):   # state rebuilds next dispatch
            if req.done or self._hit_stop(req):
                self._finish(free[i])

    def _prompt_tokens(self, req: Request) -> np.ndarray:
        """The request's prompt token ids, vocab-folded. Trace-driven
        workloads carry lengths, not token material: fabricate a
        deterministic prompt (identical across re-executions and across
        the prefix-on/off arms)."""
        if req.prompt is not None:
            return np.asarray(req.prompt, np.int32) % self.cfg.vocab_size
        return (np.arange(req.input_len) % self.cfg.vocab_size) \
            .astype(np.int32)

    def _sig_of(self, req: Request) -> int:
        """KV signature a cached page is keyed by (see EngineConfig
        .prefix_mode): the adapter in exact mode, one shared tree in
        aLoRA mode (prompt KV is base-model-only there)."""
        return -1 if self.ecfg.prefix_mode == "alora" else req.adapter_id

    def _place_batch_prefix(self, reqs: list[Request]) -> None:
        """Prefix-cache admission (paged): match each prompt against
        the radix tree, map the shared pages into the slot's page
        table, COW-fork a mid-page divergence, then batch-prefill only
        the suffixes via ``prefill_paged`` (hits and misses share the
        one jit — a miss is simply start=0). Freshly computed full
        prompt pages are adopted into the tree afterwards."""
        now = self.now()
        free = self._free_slots()
        ps = self.pool.page_size
        placed, slots, starts, prompts = [], [], [], []
        for req in reqs:
            slot = free[len(placed)]
            toksr = self._prompt_tokens(req)
            L = req.input_len
            sig = self._sig_of(req)
            # Cap the match at L-1: the last prompt position always
            # prefills, so first-token logits are computed fresh.
            pages, m, ppage, plen = self.prefix.match(sig, toksr, L - 1)
            # Reference everything we plan to read *before* allocating
            # (allocation pressure may evict refcount-1 tree pages —
            # ours must not be candidates).
            self.pool.share_pages(pages)
            if ppage is not None:
                self.pool.share_pages([ppage])
            self.slot_req[slot] = req
            self.slot_pages[slot] = list(pages)
            self.slot_shared[slot] = list(pages)
            if pages:
                self.page_table[slot, :len(pages)] = pages
                self._page_table_dirty = True
            n_priv = self.pool.pages_for(L) - len(pages)
            if not self._grow_slot(slot, n_priv, now):
                # Bounce: undo the mapping and requeue (squash path).
                self.free_pages.extend(self.pool.release_shared(pages))
                if ppage is not None:
                    self.free_pages.extend(
                        self.pool.release_shared([ppage]))
                self.slot_pages[slot] = []
                self.slot_shared[slot] = []
                self.page_table[slot, :] = 0
                self.slot_req[slot] = None
                self.n_preempted += 1
                self.sched.on_squash(req, now)
                continue
            start = m
            if ppage is not None:
                # Divergence mid-page: copy the agreeing head of the
                # cached page into the request's first private page
                # (which the suffix prefill then extends in place).
                dst = self.slot_pages[slot][len(pages)]
                kp, vp = self.kv_pages
                kp = kp.at[:, dst, :plen].set(kp[:, ppage, :plen])
                vp = vp.at[:, dst, :plen].set(vp[:, ppage, :plen])
                self.kv_pages = (kp, vp)
                self.free_pages.extend(self.pool.release_shared([ppage]))
                self.n_cow_forks += 1
                start = m + plen
            self.prefix_lookup_tokens += L
            self.prefix_hit_tokens += start
            if start:
                self.n_prefix_hits += 1
            placed.append(req)
            slots.append(slot)
            starts.append(start)
            prompts.append(toksr)
        if not placed:
            return
        S = 1 << max(3, (max(r.input_len - s for r, s in
                             zip(placed, starts)) - 1).bit_length())
        B = 1 << max(0, (len(placed) - 1).bit_length())
        toks = np.zeros((B, S), np.int32)
        start_arr = np.zeros((B,), np.int32)
        seq_len = np.ones((B,), np.int32)    # pad rows: 1 trash token
        lslots = np.zeros((B,), np.int32)
        row_table = np.zeros((B, self.pages_per_slot), np.int32)
        for i, req in enumerate(placed):
            s, L = starts[i], req.input_len
            toks[i, :L - s] = prompts[i][s:]
            start_arr[i] = s
            seq_len[i] = L - s
            lslots[i] = self.slot_of[req.adapter_id]
            row_table[i] = self.page_table[slots[i]]
        if self.plan is not None:
            # The COW fork above host-updates the (donated) pool —
            # re-commit so the explicit in_shardings hold exactly.
            self.kv_pages = self._commit(self.kv_pages, self._kv_sh)
        with self._act_scope():
            logits, self.kv_pages = self._get_prefill_paged_jit(B, S)(
                self.params, self._prefill_lora(), jnp.asarray(toks),
                self.kv_pages, jnp.asarray(row_table),
                jnp.asarray(start_arr), jnp.asarray(seq_len),
                jnp.asarray(lslots), S)
        if self._all_greedy(placed):
            first_toks = np.asarray(
                jnp.argmax(logits, axis=-1).astype(jnp.int32))
        else:
            first_toks = np.asarray(self._sample_jit(
                logits, *self._sampling_arrays(placed, B, first=True)))
        now = self.now()
        for i, req in enumerate(placed):
            slot = slots[i]
            self.active[slot] = True
            L = req.input_len
            first = int(first_toks[i])
            self.tokens = self.tokens.at[slot, 0].set(first)
            self.cache_len = self.cache_len.at[slot].set(L)
            self.adapter_slot = self.adapter_slot.at[slot].set(
                int(lslots[i]))
            req.generated = 1
            rid = req.req_id
            if req.preserved_tokens:
                self.outputs[rid] = list(req.preserved_tokens)
                self._tbts[rid] = list(req.preserved_tbts)
                if req.last_stream_time is not None:
                    self._last_tok[rid] = req.last_stream_time
            else:
                self.outputs[rid] = []
                self._tbts[rid] = []
                req.first_token_time = now
            self._record_token(req, 0, first, now)
            self._adopt_prompt_pages(slot, req, prompts[i])
        self.batch_epoch += 1
        for i, req in enumerate(placed):
            if req.done or self._hit_stop(req):
                self._finish(slots[i])

    def _adopt_prompt_pages(self, slot: int, req: Request,
                            toks: np.ndarray) -> None:
        """Hand the request's fully-written prompt pages to the radix
        tree. Accounting is a conserving transfer per adopted page:
        the request's hold shrinks by one page, the shared ledger gains
        it (tree ref), and the slot takes its mapping ref — the pages
        it keeps reading are now shared, tracked in ``slot_shared``."""
        n_full = req.input_len // self.pool.page_size
        if n_full == 0:
            return
        ps = self.pool.page_size
        pages = self.slot_pages[slot][:n_full]
        adopted = self.prefix.insert(self._sig_of(req), toks[:n_full * ps],
                                     pages)
        for pid in adopted:
            self.pool.shrink_request(req.req_id, ps)
            self.pool.add_shared_page(pid)
            self.pool.share_pages([pid])
            self.slot_shared[slot].append(pid)

    # ------------------------------------------------- chunked prefill
    def _start_chunked(self, reqs: list[Request]) -> None:
        """Admit long prompts onto slots without prefilling them yet:
        the slot takes the request and all its prompt pages up front
        (so the memory admission decision is identical to the
        monolithic path — a prompt that cannot get pages bounces via
        the squash path exactly as before), then ``_advance_chunked``
        runs one ``prefill_chunk_tokens`` chunk per engine step."""
        now = self.now()
        free = self._free_slots()
        n_placed = 0
        for req in reqs:
            slot = free[n_placed]
            self.slot_req[slot] = req
            if self._grow_slot(slot, self.pool.pages_for(req.input_len),
                               now):
                # The decode dispatches that run between chunks write
                # their per-row KV at ``cache_len[row]`` for *every*
                # slot, masked or not — inactive rows are harmless only
                # because their page-table row points at the trash
                # page. So the real row lives privately here until
                # activation; the global table keeps the trash mapping.
                row = self.page_table[slot].copy()
                self.page_table[slot, :] = 0
                self._page_table_dirty = True
                self._chunked[slot] = {
                    "req": req, "prompt": self._prompt_tokens(req),
                    "done": 0, "table": row}
                self.n_chunked_prefills += 1
                n_placed += 1
            else:
                self.slot_req[slot] = None
                self.n_preempted += 1
                self.sched.on_squash(req, now)

    def _advance_chunked(self) -> None:
        """Run one prefill chunk for every mid-prefill slot (each is a
        B=1 suffix-prefill call: chunk tokens attend to all previously
        written positions, so the final logits — and therefore every
        token — match the monolithic prefill). The last chunk's logits
        produce the first token and the slot joins the decode batch."""
        if not self._chunked:
            return
        if self.plan is not None:
            self.kv_pages = self._commit(self.kv_pages, self._kv_sh)
        chunk = self.ecfg.prefill_chunk_tokens
        for slot in sorted(self._chunked):
            st = self._chunked[slot]
            req = st["req"]
            done = st["done"]
            L = req.input_len
            n = min(chunk, L - done)
            S = 1 << max(3, (n - 1).bit_length())
            toks = np.zeros((1, S), np.int32)
            toks[0, :n] = st["prompt"][done:done + n]
            row_table = st["table"][None, :]
            lslot = self.slot_of[req.adapter_id]
            with self._act_scope():
                logits, self.kv_pages = self._get_prefill_paged_jit(1, S)(
                    self.params, self._prefill_lora(), jnp.asarray(toks),
                    self.kv_pages, jnp.asarray(row_table),
                    jnp.asarray([done], np.int32),
                    jnp.asarray([n], np.int32),
                    jnp.asarray([lslot], np.int32), S)
            st["done"] = done + n
            if st["done"] >= L:
                del self._chunked[slot]
                self.page_table[slot] = st["table"]
                self._page_table_dirty = True
                self._activate_chunked(slot, req, logits, lslot)

    def _activate_chunked(self, slot: int, req: Request, logits,
                          lslot: int) -> None:
        """Last chunk landed: sample the first token and join the
        decode batch — the same bookkeeping the monolithic placement
        runs after its prefill call."""
        if self._all_greedy([req]):
            first = int(np.asarray(
                jnp.argmax(logits[0:1], axis=-1).astype(jnp.int32))[0])
        else:
            first = int(np.asarray(self._sample_jit(
                logits[0:1], *self._sampling_arrays([req], 1,
                                                    first=True)))[0])
        now = self.now()
        self.active[slot] = True
        self.tokens = self.tokens.at[slot, 0].set(first)
        self.cache_len = self.cache_len.at[slot].set(req.input_len)
        self.adapter_slot = self.adapter_slot.at[slot].set(lslot)
        req.generated = 1
        rid = req.req_id
        if req.preserved_tokens:
            self.outputs[rid] = list(req.preserved_tokens)
            self._tbts[rid] = list(req.preserved_tbts)
            if req.last_stream_time is not None:
                self._last_tok[rid] = req.last_stream_time
        else:
            self.outputs[rid] = []
            self._tbts[rid] = []
            req.first_token_time = now
        self._record_token(req, 0, first, now)
        self.batch_epoch += 1
        if req.done or self._hit_stop(req):
            self._finish(slot)

    # ------------------------------------------- KV handoff (disagg)
    def begin_migration(self, req: Request) -> Optional[dict]:
        """Detach ``req`` from decode and serialize its KV for a
        prefill->decode handoff (serving/disagg.py). Returns the
        shipment dict, or None when the request is not in a migratable
        state (mid-chunk-prefill, already finished, or not here).

        The slot is *not* freed: slot_req stays set (so no placement
        can take the slot), the request's pool holds and shared-page
        refs stay live (so neither the prefix tree's LRU eviction nor
        the adapter cache's shrink can reclaim the pages mid-copy), and
        the request enters MIGRATING. ``complete_migration`` (transfer
        landed) or ``abort_migration`` (cancel/deadline) releases it.
        """
        self._sync_inflight()
        slot = next((i for i, r in enumerate(self.slot_req) if r is req),
                    None)
        if slot is None or slot in self._chunked \
                or not self.active[slot] or req.terminal:
            return None
        cache_len = req.input_len + req.generated - 1
        pending = int(np.asarray(self.tokens)[slot, 0])
        if self.paged:
            n_pages = self.pool.pages_for(cache_len)
            pages = self.slot_pages[slot][:n_pages]
            kp, vp = self.kv_pages
            idx = jnp.asarray(np.asarray(pages, np.int32))
            k_pay = np.asarray(kp[:, idx])
            v_pay = np.asarray(vp[:, idx])
        else:
            k, v = self.kv
            k_pay = np.asarray(k[:, slot, :cache_len])
            v_pay = np.asarray(v[:, slot, :cache_len])
        rid = req.req_id
        shipment = {
            "req": req,
            "cache_len": cache_len,
            "pending_token": pending,
            "paged": self.paged,
            "k": k_pay, "v": v_pay,
            "nbytes": int(k_pay.nbytes + v_pay.nbytes),
            # Streamed-token state moves with the request so the decode
            # replica's bookkeeping (dedup positions, TBT reference
            # point) continues exactly where the source stopped.
            "outputs": self.outputs.pop(rid, []),
            "tbts": self._tbts.pop(rid, []),
            "last_tok": self._last_tok.pop(rid, None),
            "handle": self.handles.pop(rid, None),
        }
        req.state = RequestState.MIGRATING
        self.active[slot] = False
        self._migrating[rid] = slot
        self.batch_epoch += 1
        return shipment

    def complete_migration(self, req: Request) -> None:
        """The shipment landed on the decode replica: release this
        end — adapter pin (scheduler on_finish), KV pages, the slot."""
        slot = self._migrating.pop(req.req_id, None)
        if slot is None:
            return
        now = self.now()
        self.sched.on_finish(req, now)
        self._free_slot_pages(slot, req.req_id)
        self.slot_req[slot] = None
        self.batch_epoch += 1
        self.n_kv_exports += 1

    def abort_migration(self, req: Request,
                        state: RequestState = RequestState.CANCELLED,
                        shipment: Optional[dict] = None) -> bool:
        """Cancel / deadline expiry while MIGRATING: finalize on the
        source (the destination never saw the request). The shipment,
        when passed back, restores the streamed-token records that
        export popped, so ``handle.result()`` still reports the tokens
        and TBTs the user actually saw."""
        slot = self._migrating.pop(req.req_id, None)
        if slot is None:
            return False
        if shipment is not None:
            rid = req.req_id
            self.outputs[rid] = list(shipment["outputs"])
            self._tbts[rid] = list(shipment["tbts"])
            if shipment["handle"] is not None:
                self.handles[rid] = shipment["handle"]
        self._finalize_slot(slot, state)
        return True

    def import_request_kv(self, shipment: dict) -> bool:
        """Decode-replica end of the handoff: pin the adapter, reserve
        pages in this pool, scatter the shipped KV in, restore the
        streamed-token state and join the decode batch. Returns False
        (with nothing held) when a slot, the adapter, or pages are not
        available — the caller retries next step.

        The adapter load this may trigger is flushed synchronously
        (``flush_loads``): the modeled H2D time already overlapped the
        KV transfer on the link, and the disagg router pre-warms decode
        replicas, so a blocking flush here is the rare path."""
        self._sync_inflight()
        req = shipment["req"]
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        now = self.now()
        aid = req.adapter_id
        rid = req.req_id
        cache_len = shipment["cache_len"]
        protect = self.sched.queued_adapter_ids() - {aid}
        need = (self.pool.pages_for(cache_len) * self.pool.page_size
                if self.paged else req.input_len + req.predicted_output)
        extra = (0 if self.cache.resident(aid)
                 else self.catalog.infos[aid].size_tokens)
        if not self.cache.shrink_for_requests(need + extra, now, protect):
            return False
        try:
            self.cache.acquire(aid, now, queued_protect=protect)
        except PoolError:
            return False
        if not self.cache.is_ready(aid):
            self.flush_loads()
        self.slot_req[slot] = req
        if self.paged:
            if not self._grow_slot(slot, self.pool.pages_for(cache_len),
                                   now):
                self.slot_req[slot] = None
                self.cache.release(aid, now)
                return False
            pages = self.slot_pages[slot]
            idx = jnp.asarray(np.asarray(pages, np.int32))
            kp, vp = self.kv_pages
            kp = kp.at[:, idx].set(jnp.asarray(shipment["k"]))
            vp = vp.at[:, idx].set(jnp.asarray(shipment["v"]))
            self.kv_pages = (kp, vp)
            self._page_table_dirty = True
        else:
            try:
                self.pool.reserve_request(rid, need)
            except PoolError:
                self.slot_req[slot] = None
                self.cache.release(aid, now)
                return False
            req.reserved_tokens = need
            k, v = self.kv
            k = k.at[:, slot, :cache_len].set(jnp.asarray(shipment["k"]))
            v = v.at[:, slot, :cache_len].set(jnp.asarray(shipment["v"]))
            self.kv = (k, v)
        req.adapter_ref = True
        self.active[slot] = True
        self.tokens = self.tokens.at[slot, 0].set(
            shipment["pending_token"])
        self.cache_len = self.cache_len.at[slot].set(cache_len)
        self.adapter_slot = self.adapter_slot.at[slot].set(
            self.slot_of[aid])
        self.outputs[rid] = list(shipment["outputs"])
        self._tbts[rid] = list(shipment["tbts"])
        if shipment["last_tok"] is not None:
            self._last_tok[rid] = shipment["last_tok"]
        if shipment["handle"] is not None:
            self.handles[rid] = shipment["handle"]
        req.state = RequestState.RUNNING
        if req.deadline is not None:
            self._deadlines_armed = True
        self.batch_epoch += 1
        self.n_kv_imports += 1
        self.kv_handoff_bytes += shipment["nbytes"]
        return True

    def _hit_stop(self, req: Request) -> bool:
        """Did the latest recorded token hit a SamplingParams stop id?"""
        sp = req.sampling
        if sp is None or not sp.stop_token_ids:
            return False
        return self.outputs[req.req_id][req.generated - 1] \
            in sp.stop_token_ids

    @staticmethod
    def _all_greedy(reqs) -> bool:
        """Host-side fast-path test: with no stochastic row in the
        batch, sampling is plain argmax — skip building the sampler
        inputs and the full sorted/softmax/Gumbel sampler call (the
        default path, and the one every greedy benchmark measures)."""
        return all(r is None or r.sampling is None or r.sampling.greedy
                   for r in reqs)

    def _sampling_arrays(self, reqs, B: int, first: bool = False):
        """Per-row sampler inputs for a prefill batch (``reqs`` list,
        ``first=True`` → all positions 0) or the decode batch
        (``reqs = slot_req``; inactive slots run greedy garbage)."""
        temp = np.zeros(B, np.float32)
        topk = np.zeros(B, np.int32)
        topp = np.ones(B, np.float32)
        seeds = np.zeros(B, np.uint32)
        pos = np.zeros(B, np.int32)
        for i, req in enumerate(reqs):
            if req is None:
                continue
            sp = req.sampling
            if sp is not None and not sp.greedy:
                temp[i] = sp.temperature
                topk[i] = sp.top_k
                topp[i] = sp.top_p
                seeds[i] = sp.seed_for(req.req_id)
            if not first:
                pos[i] = req.generated
        return (jnp.asarray(temp), jnp.asarray(topk), jnp.asarray(topp),
                jnp.asarray(seeds), jnp.asarray(pos))

    def _finish(self, slot: int) -> None:
        # A cancel that raced the final token (e.g. issued from the
        # on_token callback that delivered it) still honours the
        # cancel() contract: the request terminates as CANCELLED.
        req = self.slot_req[slot]
        self._finalize_slot(slot, RequestState.CANCELLED
                            if req.cancel_requested
                            else RequestState.FINISHED)

    def _finalize_slot(self, slot: int, state: RequestState) -> None:
        """Terminal transition for the request occupying ``slot``:
        FINISHED, CANCELLED (handle.cancel on a running request) or
        EXPIRED (deadline passed mid-decode). All three release the
        slot, its KV pages and the scheduler/pool/cache holds; only
        FINISHED contributes a RequestRecord to the run metrics."""
        req = self.slot_req[slot]
        req.state = state
        now = self.now()
        req.finish_time = now
        self.sched.on_finish(req, now)
        self._free_slot_pages(slot, req.req_id)
        self.active[slot] = False
        self.slot_req[slot] = None
        self.batch_epoch += 1
        if self.spec:
            self._draft_len[slot] = 0    # draft KV freed with the slot
        tbts = self._tbts.pop(req.req_id, [])
        req.preserved_tbts = tbts    # handle.result() reads these
        self._last_tok.pop(req.req_id, None)
        if state is RequestState.CANCELLED:
            self.n_cancelled += 1
            return
        if state is RequestState.EXPIRED:
            self.n_expired += 1
            return
        self.completed.append(req)
        self.records.append(RequestRecord(
            req_id=req.req_id, adapter_id=req.adapter_id,
            rank=self.catalog.rank_of(req.adapter_id),
            input_len=req.input_len, output_len=req.output_len,
            arrival=req.arrival_time,
            ttft=req.ttft() or 0.0, e2e=req.e2e() or 0.0,
            tbt_mean=float(np.mean(tbts)) if tbts else 0.0,
            tbt_p99=float(np.percentile(tbts, 99)) if tbts else 0.0,
            slowdown=1.0,   # no isolated-run oracle on the real engine
            squashes=req.squash_count, bypassed=req.bypassed,
            queue_wait=req.queue_wait() or 0.0,
            load_wait=req.adapter_load_wait))

    def _ensure_decode_pages(self, lens: Optional[np.ndarray] = None
                             ) -> None:
        """Grow each active slot to cover its next decode write; slots
        that cannot get a page even after shrinking the adapter cache
        are preempted (freed pages let the remaining slots proceed).
        The fused loop passes host-derived lengths so this never forces
        a device sync on the hot path."""
        now = self.now()
        if lens is None:
            lens = np.asarray(self.cache_len)
        ps = self.pool.page_size
        for slot in np.where(self.active)[0]:
            needed = int(lens[slot]) // ps + 1
            short = needed - len(self.slot_pages[slot])
            if short > 0 and not self._grow_slot(int(slot), short, now):
                self._preempt(int(slot))

    def _run_prefetchers(self, now: float) -> None:
        """Ahead-of-demand loads (paper §4.1). Dispatched async, they
        overlap the decode compute this same step launches; admission
        ran first, so prefetch never steals memory from the batch."""
        # Prefetch only fills *idle* slots: with every slot occupied it
        # would have to evict, fighting the cost-aware policy (§4.1:
        # prefetching must never evict useful entries). The budget is
        # the live free-slot count, re-read between prefetchers, so a
        # round can never load past the last idle slot. The simulator
        # has no slot cap, so this gate lives here, not in the
        # prefetchers.
        if not self.free_slots:
            return
        queued = self.sched.queued_requests_in_order()
        if self.q_prefetch is not None and queued:
            self.q_prefetch.run(queued, now, budget=len(self.free_slots))
        if self.h_prefetch is not None and self.free_slots:
            self.h_prefetch.run(
                now, queued_protect={r.adapter_id for r in queued},
                budget=len(self.free_slots))

    def _sweep_lifecycle(self, now: float) -> None:
        """Lifecycle enforcement at the step boundary: reap queued
        requests past their deadline, then finalise active slots whose
        request was cancelled (``handle.cancel()``) or expired."""
        if self._deadlines_armed:
            for req in self.sched.reap_expired(now):
                self._finalize_unplaced(req, RequestState.EXPIRED, now)
            # Disarm once no live request carries a deadline, so the
            # fused loop's micro-horizon re-opens after TTL'd work
            # drains (submit re-arms).
            self._deadlines_armed = (
                any(r is not None and r.deadline is not None
                    for r in self.slot_req)
                or any(r.deadline is not None
                       for r in self.sched.queued_requests_in_order()))
        self._cancel_armed = False      # re-armed below by racing cancels
        for slot in np.where(self.active)[0]:
            req = self.slot_req[slot]
            if req.cancel_requested:
                self._finalize_slot(int(slot), RequestState.CANCELLED)
            elif req.deadline is not None and now >= req.deadline:
                self._finalize_slot(int(slot), RequestState.EXPIRED)
        # Mid-chunk prefills are off the active mask but hold a slot
        # and pages — cancel/expiry must reap them here too.
        for slot in list(self._chunked):
            req = self._chunked[slot]["req"]
            if req.cancel_requested:
                del self._chunked[slot]
                self._finalize_slot(slot, RequestState.CANCELLED)
            elif req.deadline is not None and now >= req.deadline:
                del self._chunked[slot]
                self._finalize_slot(slot, RequestState.EXPIRED)
        # A cancel that raced placement (neither queued nor in a slot
        # at cancel() time) is caught here once it settles somewhere.
        if self._cancel_races:
            races, self._cancel_races = self._cancel_races, []
            for req in races:
                if not req.terminal:
                    self.cancel(req)

    def _idle_wait(self) -> None:
        """Idle with loads in flight: wait until the earliest in-flight
        load's modeled readiness instead of spinning a fixed busy-wait;
        already-due loads (waiting only on the actual device write)
        poll at a tight interval. Under an *injected* clock the owner
        of that clock controls time — modeled waits are virtual-time
        deltas, so sleeping real wall time for them would stall a DES
        replay; the engine returns immediately and lets the driver
        advance the clock."""
        if self._pending_loads and self._clock is None:
            t_next = min(t for _, _, t in self._pending_loads.values())
            wait = t_next - self.now()
            time.sleep(min(max(wait, 1e-4), 0.05))

    def step(self) -> None:
        """One engine iteration: retire finished loads -> enforce
        deadlines/cancellations -> admit -> prefetch -> batched prefill
        -> decode. With ``fused_hotloop`` the decode half is the
        device-resident fused loop (one dispatch per K-token horizon);
        otherwise the seed two-dispatch loop runs."""
        if self.fused:
            return self._step_fused()
        return self._step_seed()

    def _step_seed(self) -> None:
        """The seed decode loop: one decode dispatch, logits back to a
        second sampling dispatch, per-step host re-uploads of page
        table / active mask / sampling arrays, and a blocking token
        sync before bookkeeping. Kept verbatim (plus the dispatch
        meter) as the ``decode_hotloop.py`` A/B baseline and the
        fallback for model families without fused decode support."""
        self._poll_loads()
        now = self.now()
        self._sweep_lifecycle(now)
        running = [r for r in self.slot_req if r is not None]
        admitted = self.sched.schedule(now, running)
        self._run_prefetchers(now)
        self._place_batch(admitted)
        self._advance_chunked()
        if self.paged:
            self._ensure_decode_pages()
        if not self.active.any():
            self._idle_wait()
            return
        self.batch_occupancy.append(int(self.active.sum()))
        self._commit_batch_state()
        DISPATCH_METER.tick()
        if self._collective:
            COLLECTIVE_METER.tick()
        with self._act_scope():
            if self.paged:
                logits, self.kv_pages = self._decode_paged_jit(
                    self.params, self.lora, self.tokens, self.kv_pages,
                    jnp.asarray(self.page_table), self.cache_len,
                    self.adapter_slot)
            else:
                logits, self.kv = self._decode_jit(
                    self.params, self.lora, self.tokens, self.kv,
                    self.cache_len, self.adapter_slot)
        DISPATCH_METER.tick()
        if self._all_greedy(self.slot_req):
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = self._sample_jit(
                logits, *self._sampling_arrays(self.slot_req,
                                               self.ecfg.max_slots))
        self.tokens = nxt[:, None]
        self.cache_len = self.cache_len + jnp.asarray(self.active,
                                                      jnp.int32)
        now = self.now()
        with DISPATCH_METER.sync(), COLLECTIVE_METER.sync() \
                if self._collective else contextlib.nullcontext():
            nxt_host = np.asarray(nxt)
        to_finish, to_squash = [], []
        for slot in np.where(self.active)[0]:
            req = self.slot_req[slot]
            pos = req.generated
            req.generated += 1
            self._record_token(req, pos, int(nxt_host[slot]), now)
            if req.done or self._hit_stop(req) \
                    or req.generated + req.input_len \
                    >= self.ecfg.max_len - 1:
                to_finish.append(slot)
            elif req.bypassed and req.exceeded_prediction():
                to_squash.append(slot)
        for slot in to_finish:
            self._finish(slot)
        for slot in to_squash:
            self._squash_slot(slot)

    # ------------------------------------ fused device-resident hot loop
    #
    # Dataflow (DESIGN §2): everything the per-token loop touches lives
    # on device — KV (donated, updated in place), current tokens,
    # cache_len, the active mask, sample positions, per-row sampling
    # params, decode budgets, stop-token matrix, and (paged) the page
    # table. The host crosses the boundary only at *batch epochs*
    # (place/finish/squash rebuild the batch state; page alloc/free
    # re-uploads the table) and once per K-step horizon to sync the
    # (K, B) token block. Under backlog, armed deadlines, pending
    # cancels or in-flight adapter loads the horizon collapses to K=1,
    # so admission latency and lifecycle sweeps behave exactly like the
    # seed loop.

    def _host_work_pending(self) -> bool:
        """Anything that needs host-truth bookkeeping before the next
        dispatch: queued admissions, armed deadline/cancel sweeps, or
        in-flight adapter loads to poll."""
        return bool(self._deadlines_armed or self._cancel_armed
                    or self._cancel_races or self._pending_loads
                    or self._chunked
                    or self.sched.pending_count() > 0)

    def _refresh_device_state(self) -> None:
        """(Re)build the device-resident batch state — only when the
        batch epoch moved (satellite: the seed loop rebuilt
        ``_all_greedy`` + ``_sampling_arrays`` from Python requests
        every step even with an unchanged batch)."""
        if self._dev_epoch == self.batch_epoch and self._dev is not None:
            return
        B = self.ecfg.max_slots
        reqs = self.slot_req
        # One source of truth with the seed loop: the sampler inputs
        # (temperature/top_k/top_p/seeds/positions) come from the same
        # builder its per-step path uses; only the fused-loop extras —
        # decode budgets, the stop-token matrix, the active mask — are
        # built here.
        temp, topk, topp, seeds, pos = self._sampling_arrays(reqs, B)
        budget = np.zeros(B, np.int32)
        n_stop = max((len(r.sampling.stop_token_ids) for r in reqs
                      if r is not None and r.sampling is not None),
                     default=0)
        stop = np.full((B, n_stop), -1, np.int32)
        for i, r in enumerate(reqs):
            if r is None:
                continue
            budget[i] = r.max_output_tokens
            if r.sampling is not None and r.sampling.stop_token_ids:
                stop[i, :len(r.sampling.stop_token_ids)] = \
                    r.sampling.stop_token_ids
        self._dev = dict(
            active=jnp.asarray(self.active),
            positions=pos, budget=jnp.asarray(budget),
            stop=jnp.asarray(stop), temp=temp, topk=topk, topp=topp,
            seeds=seeds, all_greedy=self._all_greedy(reqs))
        self._dev_epoch = self.batch_epoch

    def _host_lens(self) -> np.ndarray:
        """cache_len derived from host truth (no device sync):
        ``input_len + generated - 1`` for occupied slots."""
        lens = np.zeros(self.ecfg.max_slots, np.int64)
        for i, r in enumerate(self.slot_req):
            if r is not None:
                lens[i] = r.input_len + r.generated - 1
        return lens

    def _choose_horizon(self) -> int:
        """Adaptive micro-horizon K: number of decode steps fused into
        the next dispatch. K=1 whenever the host may need to intervene
        between tokens — backlog (``queue_pressure`` via the scheduler
        queue), armed deadlines/cancels, in-flight loads — so TTFT and
        admission latency are untouched; otherwise up to
        ``max_horizon``, clamped by the longest surviving row's budget
        and (for bypassers) the first possible squash point, then
        bucketed to a power of two so jit variants stay bounded."""
        e = self.ecfg
        if e.max_horizon <= 1 or self._host_work_pending():
            return 1
        reqs = [r for r in self.slot_req if r is not None]
        if not reqs:
            return 1
        K = min(e.max_horizon,
                max(r.max_output_tokens - r.generated for r in reqs))
        # A bypasser squashes on the token that exceeds its predicted
        # length — that check is host-side, so the horizon must end
        # exactly there (the seed loop checks it every token).
        for r in reqs:
            if r.bypassed:
                K = min(K, r.predicted_output - r.generated + 1)
        if K <= 1:
            return 1
        return 1 << (K.bit_length() - 1)

    def _page_cover(self) -> int:
        """Paged mode: tokens writable from *already-allocated* pages,
        minimised over active slots (host truth — no device sync). The
        horizon is clamped to this instead of pre-allocating ahead, so
        page-allocation (and therefore preemption) timing is identical
        to the seed loop's per-boundary ``_ensure_decode_pages``: the
        scan cannot allocate mid-flight, and it never needs to."""
        ps = self.pool.page_size
        cover = 1 << 30
        for slot in np.where(self.active)[0]:
            r = self.slot_req[slot]
            cover = min(cover, len(self.slot_pages[slot]) * ps
                        - (r.input_len + r.generated - 1))
        return cover

    # ------------------------------- speculative draft–verify dispatch
    def _spec_k_eff(self) -> int:
        """Adaptive draft length: the acceptance EWMA scales ``spec_k``
        down when drafts stop landing (each round costs ``kk + 1`` draft
        steps + one verify regardless of acceptance, so a cold draft
        should shrink toward kk=1). Bucketed to a power of two so the
        static-spec_k jit variants stay bounded."""
        e = self.ecfg
        kk = int(round(self._spec_ewma * (e.spec_k + 1)))
        kk = max(1, min(e.spec_k, kk))
        return 1 << (kk.bit_length() - 1)

    def _draft_sync(self) -> None:
        """Lazy draft-KV catch-up: replay, through one batched
        multi-token draft forward, every token the target cache holds
        that the draft cache does not (per-slot ``_draft_len`` tracks
        the synced length). Placement, prefix-cache hits, chunked
        prefill, KV import and squash re-execution all advance the
        target without touching the draft — this one entry point makes
        them all spec-compatible without per-path hooks. Token material
        comes from host truth (prompt + recorded outputs), so the draft
        prefix is identical across re-executions."""
        lens = self._host_lens()
        rows = [int(s) for s in np.where(self.active)[0]
                if self._draft_len[s] < lens[s]]
        if not rows:
            return
        gap = max(int(lens[s] - self._draft_len[s]) for s in rows)
        S = 1 << max(3, (gap - 1).bit_length())
        B = self.ecfg.max_slots
        toks = np.zeros((B, S), np.int32)
        start = np.zeros(B, np.int32)
        seq = np.zeros(B, np.int32)
        for s in rows:
            req = self.slot_req[s]
            full = np.concatenate([
                self._prompt_tokens(req),
                np.asarray(self.outputs[req.req_id], np.int32)])
            lo, hi = int(self._draft_len[s]), int(lens[s])
            toks[s, :hi - lo] = full[lo:hi]
            start[s] = lo
            seq[s] = hi - lo
        DISPATCH_METER.tick()
        DISPATCH_METER.tick_draft()
        self.n_spec_draft_dispatches += 1
        self.draft_kv = self._draft_catchup_jit(
            self.draft_params, jnp.asarray(toks), self.draft_kv,
            jnp.asarray(start), jnp.asarray(seq), S=S)
        for s in rows:
            self._draft_len[s] = int(lens[s])

    def _shrink_spec_pages(self) -> None:
        """Roll back speculative page growth: after the round drained,
        every surviving slot keeps exactly the pages the seed loop's
        ``_ensure_decode_pages`` would hold (``len // ps + 1`` — the
        next write covered), and the rest go back to the free list with
        the pool hold shrunk to match. Rejected drafts therefore never
        inflate pool occupancy past one round, so admission headroom
        and preemption timing stay honest."""
        ps = self.pool.page_size
        lens = self._host_lens()
        for slot in np.where(self.active)[0]:
            req = self.slot_req[slot]
            if req is None:
                continue
            keep = int(lens[slot]) // ps + 1
            extra = len(self.slot_pages[slot]) - keep
            if extra <= 0:
                continue
            for _ in range(extra):
                pid = self.slot_pages[slot].pop()
                self.page_table[slot, len(self.slot_pages[slot])] = 0
                self.free_pages.append(pid)
            self.pool.shrink_request(req.req_id, extra * ps)
            self._page_table_dirty = True

    def _dispatch_spec(self) -> bool:
        """One speculative block: draft catch-up, then ``n_rounds``
        draft–verify rounds in a single fused dispatch, drained
        synchronously (emission counts are data-dependent, so the
        pipelined-readback page math cannot cover a speculative block).
        Returns False — caller falls back to the normal fused horizon —
        when speculation is not viable this step: nothing to decode, a
        bypasser's squash point inside the round, no context headroom,
        or (paged) not even one round of page cover after best-effort
        growth. Speculation only ever *shrinks* on pressure; it never
        preempts a slot to grow."""
        e = self.ecfg
        reqs = [r for r in self.slot_req if r is not None]
        if not reqs or max(r.max_output_tokens - r.generated
                           for r in reqs) < 1:
            return False
        kk = self._spec_k_eff()
        has_bypass = False
        for r in reqs:
            # A bypasser squashes on the token exceeding its predicted
            # length (host-side check): the round must end at or before
            # that point, exactly like ``_choose_horizon``.
            if r.bypassed:
                has_bypass = True
                kk = min(kk, r.predicted_output - r.generated)
        lens = self._host_lens()
        for slot in np.where(self.active)[0]:
            # Rows at the context edge finish within a step or two —
            # don't burn drafts past their done-mask.
            kk = min(kk, e.max_len - 2 - int(lens[slot]))
        if kk < 1:
            return False
        kk = 1 << (kk.bit_length() - 1)
        n_rounds = 1 if has_bypass else max(1, e.max_horizon // (kk + 1))
        if self.paged:
            now = self.now()
            ps = self.pool.page_size
            need = n_rounds * (kk + 1)
            for slot in np.where(self.active)[0]:
                needed = (int(lens[slot]) + need - 1) // ps + 1
                short = needed - len(self.slot_pages[slot])
                if short > 0:
                    self._grow_slot(int(slot), short, now)  # best effort
            cover = self._page_cover()
            while n_rounds > 1 and n_rounds * (kk + 1) > cover:
                n_rounds -= 1
            if kk + 1 > cover:
                kk = cover - 1
                if kk < 1:
                    self._shrink_spec_pages()
                    return False
                kk = 1 << (kk.bit_length() - 1)
        self._refresh_device_state()
        self._draft_sync()
        d = self._dev
        self._commit_batch_state()
        DISPATCH_METER.tick()
        DISPATCH_METER.tick_draft(n_rounds * (kk + 1))
        DISPATCH_METER.tick_verify(n_rounds)
        self.n_spec_dispatches += 1
        self.n_spec_draft_dispatches += n_rounds * (kk + 1)
        self.n_spec_verify_dispatches += n_rounds
        with self._act_scope():
            if self.paged:
                if self._page_table_dirty or self._page_table_dev is None:
                    self._page_table_dev = jnp.asarray(self.page_table)
                    self._page_table_dirty = False
                carry, toks, emits, accs = self._spec_paged_jit(
                    self.params, self.lora, self.tokens, self.kv_pages,
                    self._page_table_dev, self.draft_kv, self.cache_len,
                    d["active"], d["positions"], self.adapter_slot,
                    d["budget"], d["stop"], d["temp"], d["topk"],
                    d["topp"], d["seeds"], spec_k=kk, n_rounds=n_rounds,
                    all_greedy=d["all_greedy"])
                (self.tokens, self.kv_pages, self.draft_kv,
                 self.cache_len, d["active"], d["positions"]) = carry
            else:
                carry, toks, emits, accs = self._spec_jit(
                    self.params, self.lora, self.tokens, self.kv,
                    self.draft_kv, self.cache_len, d["active"],
                    d["positions"], self.adapter_slot, d["budget"],
                    d["stop"], d["temp"], d["topk"], d["topp"],
                    d["seeds"], spec_k=kk, n_rounds=n_rounds,
                    all_greedy=d["all_greedy"])
                (self.tokens, self.kv, self.draft_kv, self.cache_len,
                 d["active"], d["positions"]) = carry
        self._inflight = (toks, emits, n_rounds * (kk + 1),
                          (accs, kk, n_rounds))
        self._drain_inflight()
        # The draft cache advanced in lockstep with the target for
        # every surviving slot (garbage entries past the accepted
        # prefix are overwritten before the next read, same as the
        # target's); finished/squashed slots were cleared by their
        # terminal hooks.
        lens = self._host_lens()
        for slot in range(e.max_slots):
            if self.active[slot] and self.slot_req[slot] is not None:
                self._draft_len[slot] = int(lens[slot])
        if self.paged:
            self._shrink_spec_pages()
        return True

    def _dispatch_horizon(self, K: int, refresh: bool = True) -> None:
        """Launch one fused K-step horizon and re-point the engine's
        device state at its (asynchronous) outputs. The inputs are
        donated — after this call the previous buffers are gone, which
        is exactly the in-place-KV invariant.

        ``refresh=False`` (pipelined dispatch): host truth lags the
        device by the in-flight horizon, so rebuilding active/positions
        from the Python requests would *rewind* the device state — the
        carried device arrays are the only truth until the next full
        sync. A finish the device discovered mid-horizon is already off
        the carried active mask; a host-side squash leaves its row
        decoding masked garbage until the next synced placement rebuild.
        """
        if refresh:
            self._refresh_device_state()
        d = self._dev
        self._commit_batch_state()
        DISPATCH_METER.tick()
        if self._collective:
            COLLECTIVE_METER.tick()
        with self._act_scope():
            if self.paged:
                if self._page_table_dirty or self._page_table_dev is None:
                    self._page_table_dev = jnp.asarray(self.page_table)
                    if self.plan is not None:
                        self._page_table_dev = jax.device_put(
                            self._page_table_dev,
                            self._batch_sh(2))
                    self._page_table_dirty = False
                carry, toks, emits = self._fused_paged_jit(
                    self.params, self.lora, self.tokens, self.kv_pages,
                    self._page_table_dev, self.cache_len, d["active"],
                    d["positions"], self.adapter_slot, d["budget"],
                    d["stop"], d["temp"], d["topk"], d["topp"], d["seeds"],
                    K=K, all_greedy=d["all_greedy"])
                (self.tokens, self.kv_pages, self.cache_len,
                 d["active"], d["positions"]) = carry
            else:
                carry, toks, emits = self._fused_jit(
                    self.params, self.lora, self.tokens, self.kv,
                    self.cache_len, d["active"], d["positions"],
                    self.adapter_slot, d["budget"], d["stop"], d["temp"],
                    d["topk"], d["topp"], d["seeds"],
                    K=K, all_greedy=d["all_greedy"])
                (self.tokens, self.kv, self.cache_len,
                 d["active"], d["positions"]) = carry
        self._inflight = (toks, emits, K)

    def _drain_inflight(self) -> None:
        """Sync the in-flight horizon's token block and replay the
        seed loop's per-token bookkeeping from it: record/stream each
        emitted token, then finish / squash slots in sub-step order
        (the on-device done-mask already stopped finished rows, so a
        request that hit EOS inside the horizon emitted nothing past
        it)."""
        if self._inflight is None:
            return
        toks, emits, _K = self._inflight[:3]
        spec_meta = self._inflight[3] if len(self._inflight) > 3 else None
        self._inflight = None
        with DISPATCH_METER.sync(), COLLECTIVE_METER.sync() \
                if self._collective else contextlib.nullcontext():
            toks_h = np.asarray(toks)
            emits_h = np.asarray(emits)
            accs_h = (np.asarray(spec_meta[0])
                      if spec_meta is not None else None)
        if spec_meta is not None:
            # Speculative block: emissions are per-round prefix-masked
            # (round r emits its first cnt[b] of kk+1 slots), so a
            # rejected round leaves empty *interior* steps — count the
            # round-start rows (step 0 of each round is emitted by
            # every row active then) for drafted/accepted accounting.
            _, kk, n_rounds = spec_meta
            round_act = emits_h.reshape(n_rounds, kk + 1, -1)[:, 0, :]
            drafted = int(round_act.sum()) * kk
            self.spec_drafted_tokens += drafted
            self.spec_accepted_tokens += int(accs_h.sum())
            if drafted:
                self._spec_ewma = (0.8 * self._spec_ewma
                                   + 0.2 * int(accs_h.sum()) / drafted)
        now = self.now()
        for k in range(toks_h.shape[0]):
            em = emits_h[k]
            if not em.any():
                if spec_meta is not None:
                    continue    # interior rejection gap, later rounds
                break               # every row finished earlier in the scan
            self.batch_occupancy.append(int(em.sum()))
            to_finish, to_squash = [], []
            for slot in np.where(em)[0]:
                req = self.slot_req[slot]
                if req is None:
                    # Slot squashed at an earlier sub-step of this (or
                    # the previous, pipelined) horizon: the device kept
                    # emitting, but the request re-executes from its
                    # requeue — dropping the tail keeps the stream
                    # identical to the seed loop's.
                    continue
                pos = req.generated
                req.generated += 1
                self._record_token(req, pos, int(toks_h[k, slot]), now)
                if req.done or self._hit_stop(req) \
                        or req.generated + req.input_len \
                        >= self.ecfg.max_len - 1:
                    to_finish.append(int(slot))
                elif req.bypassed and req.exceeded_prediction():
                    to_squash.append(int(slot))
            for slot in to_finish:
                self._finish(slot)
            for slot in to_squash:
                self._squash_slot(slot)

    def _sync_inflight(self) -> None:
        """Barrier: retire any dispatched-but-unsynced horizon so host
        records are complete (warmup resets, external state reads)."""
        self._drain_inflight()

    def _plan_pipelined_horizon(self) -> Optional[int]:
        """Pipelined readback: decide whether the *next* horizon can be
        dispatched from the carried device state before the in-flight
        one is synced. Requires zero host work due, at least one row
        that is provably still decoding after the in-flight horizon's
        K steps, and (paged) page coverage for both horizons' writes —
        host truth lags the device by exactly the in-flight K, so every
        bound is computed against that worst case. Returns the next K,
        or None to sync first."""
        e = self.ecfg
        if (not e.pipeline_readback or self._inflight is None
                or self._host_work_pending()):
            return None
        _, _, k_in = self._inflight
        alive = 0
        for r in self.slot_req:
            if r is None or (r.sampling is not None
                             and r.sampling.stop_token_ids):
                continue        # a stop token could end the row any step
            rem = r.max_output_tokens - r.generated - k_in
            rem = min(rem, (e.max_len - 1 - r.input_len
                            - r.generated - k_in))
            alive = max(alive, rem)
        if alive <= 0:
            return None
        K = min(e.max_horizon, alive)
        for r in self.slot_req:
            # A bypasser's squash point is host-side: the combined
            # in-flight + next horizon must end exactly on it.
            if r is not None and r.bypassed:
                K = min(K, r.predicted_output - r.generated - k_in + 1)
        if K < 1:
            return None
        if self.paged:
            # Host truth lags the device by the in-flight k_in writes;
            # existing pages must cover those plus the next horizon.
            K = min(K, self._page_cover() - k_in)
            if K < 1:
                return None     # sync, then allocate at the boundary
        return 1 << (K.bit_length() - 1)

    def _step_fused(self) -> None:
        """One fused-loop iteration. Steady state (stable batch, empty
        queue): dispatch horizon N+1 from the carried device state,
        *then* sync horizon N's tokens — the host-side bookkeeping of
        step N overlaps the device compute of step N+1. Any pending
        host work (admissions, lifecycle, loads) first syncs the
        in-flight horizon, then runs the same admit/place path as the
        seed loop."""
        self._poll_loads()
        if self._inflight is not None:
            k_next = self._plan_pipelined_horizon()
            if k_next is not None:
                prev, self._inflight = self._inflight, None
                self._dispatch_horizon(k_next, refresh=False)
                nxt, self._inflight = self._inflight, prev
                self._drain_inflight()
                self._inflight = nxt
                return
            self._drain_inflight()
        now = self.now()
        self._sweep_lifecycle(now)
        running = [r for r in self.slot_req if r is not None]
        admitted = self.sched.schedule(now, running)
        self._run_prefetchers(now)
        self._place_batch(admitted)
        self._advance_chunked()
        if self.paged:
            self._ensure_decode_pages(self._host_lens())
        if not self.active.any():
            self._idle_wait()
            return
        K = self._choose_horizon()
        if self.spec and K > 1 and self._dispatch_spec():
            # Speculative block dispatched and drained (the K=1
            # demotions — backlog, armed sweeps, loads — reach here as
            # K == 1 and keep speculation off for the step, exactly
            # like the horizon collapse).
            return
        if self.paged and K > 1:
            # Clamp to allocated pages (cover >= 1: the _ensure pass
            # grew or preempted) — allocation timing stays seed-equal.
            K = 1 << (max(1, min(K, self._page_cover())).bit_length() - 1)
        self._dispatch_horizon(K)
        if not self.ecfg.pipeline_readback:
            self._drain_inflight()

    def busy(self) -> bool:
        """True while any work is in flight or queued. Mid-prefill
        chunked slots count; MIGRATING slots do not — their next step
        belongs to the handoff plane (the disagg cluster's ``busy``
        covers in-flight shipments)."""
        return (bool(self.active.any()) or bool(self._chunked)
                or self.sched.pending_count() > 0)

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.busy():
                break
            self.step()

    # ``drain`` is the surface name the cluster layer uses (DESIGN §3).
    drain = run_until_drained

    def reset_stats(self) -> None:
        """Clear accounting after a warmup pass (jit compiles, first
        adapter loads) so reported metrics cover only the measured run.
        Device state and cache residency are kept — replicas start warm
        but identically so across routing policies."""
        self._sync_inflight()
        self.flush_loads()
        self.completed = []
        self.records = []
        self.outputs = {}
        self._tbts = {}
        self._last_tok = {}
        self.handles = {}
        self.batch_occupancy = []
        self.n_preempted = 0
        self.n_cancelled = 0
        self.n_expired = 0
        self.n_async_loads = 0
        self.n_chunked_prefills = 0
        self.n_kv_exports = 0
        self.n_kv_imports = 0
        self.kv_handoff_bytes = 0
        # Speculation accounting restarts; the acceptance EWMA stays
        # warm (like cache residency) so the measured run speculates at
        # the adapted spec_k, not the optimistic cold start.
        self.spec_drafted_tokens = 0
        self.spec_accepted_tokens = 0
        self.n_spec_dispatches = 0
        self.n_spec_draft_dispatches = 0
        self.n_spec_verify_dispatches = 0
        # Prefix-cache hit accounting restarts; the cached pages stay
        # resident (warm prefixes, like warm adapters).
        self.prefix_hit_tokens = 0
        self.prefix_lookup_tokens = 0
        self.n_prefix_hits = 0
        self.n_cow_forks = 0
        if self.prefix is not None:
            self.prefix.evictions = 0
            self.prefix.inserts = 0
        self.cache.stats = CacheStats()
        for counter in ("n_bypassed", "n_squashed", "n_deferred"):
            if hasattr(self.sched, counter):
                setattr(self.sched, counter, 0)

    # ---------------------------------------------------------- reporting
    def queue_pressure(self) -> float:
        """Routing signal: scheduler backlog plus occupied batch slots."""
        return self.sched.queue_pressure() + float(self.active.sum())

    def kv_page_stats(self) -> dict:
        """Page-occupancy telemetry (paged mode; empty dict for dense)."""
        if not self.paged:
            return {}
        total = self.n_pages - 1     # page 0 is the trash page
        used = total - len(self.free_pages)
        return {"kv_pages_used": used, "kv_pages_total": total,
                "kv_page_util": used / max(1, total),
                "preempted": self.n_preempted}

    def handoff_stats(self) -> dict:
        """Chunked-prefill / KV-handoff gauges (zeros when unused)."""
        if not (self.n_chunked_prefills or self.n_kv_exports
                or self.n_kv_imports or self._chunked
                or self._migrating):
            return {}
        return {"chunked_prefills": self.n_chunked_prefills,
                "kv_exports": self.n_kv_exports,
                "kv_imports": self.n_kv_imports,
                "kv_handoff_gb": round(self.kv_handoff_bytes / 1e9, 6),
                "migrating": len(self._migrating)}

    def prefix_stats(self) -> dict:
        """Prefix-reuse gauges (empty dict when the cache is off)."""
        if self.prefix is None:
            return {}
        return {
            "prefix_hit_rate": round(
                self.prefix_hit_tokens
                / max(1, self.prefix_lookup_tokens), 4),
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_lookup_tokens": self.prefix_lookup_tokens,
            "prefix_hits": self.n_prefix_hits,
            "prefix_shared_pages": self.pool.n_shared_pages,
            "prefix_nodes": len(self.prefix),
            "prefix_evictions": self.prefix.evictions,
            "cow_forks": self.n_cow_forks,
        }

    def spec_stats(self) -> dict:
        """Speculative-decoding gauges (empty dict when spec is off).

        Acceptance is drafted-token yield: ``spec_accepted_tokens``
        counts draft proposals verified equal/accepted by the target,
        over ``spec_drafted_tokens`` proposed (``spec_k_eff`` per row
        per round). Emitted tokens run higher than accepted — every
        round also emits its correction/bonus token. The per-phase
        dispatch counters split DISPATCH_METER-style device work into
        draft forwards (chained proposal steps + catch-up replays) and
        multi-token target verifies."""
        if not self.spec:
            return {}
        return {
            "spec_accept_rate": round(
                self.spec_accepted_tokens
                / max(1, self.spec_drafted_tokens), 4),
            "spec_drafted_tokens": self.spec_drafted_tokens,
            "spec_accepted_tokens": self.spec_accepted_tokens,
            "spec_draft_dispatches": self.n_spec_draft_dispatches,
            "spec_verify_dispatches": self.n_spec_verify_dispatches,
            "spec_dispatches": self.n_spec_dispatches,
            "spec_k_eff": self._spec_k_eff(),
        }

    def shard_stats(self) -> dict:
        """Per-device data-plane gauges (empty dict off-mesh): physical
        page occupancy per data shard, resident LoRA-arena bytes per
        device, and the collective time fraction from the
        COLLECTIVE_METER probe."""
        if self.mesh is None:
            return {}
        out = {
            "mesh_shape": [self.mesh.shape["data"],
                           self.mesh.shape["model"]],
            "n_devices": self.mesh.size,
        }
        if self.paged:
            ds = self.mesh.shape["data"]
            stride = self.n_pages // ds
            per = [0] * ds
            used = set(range(1, self.n_pages)) - set(self.free_pages)
            for pid in used:
                per[pid // stride] += 1
            out["per_shard_pages_used"] = per
            out["per_shard_pages_total"] = stride
        # Actual bytes one device holds for the slot arena — with B
        # sharded over "model" this is arena_bytes/model_size + the
        # replicated A halves.
        arena = 0
        for a, b in self.lora.values():
            arena += a.addressable_shards[0].data.nbytes
            arena += b.addressable_shards[0].data.nbytes
        out["per_shard_lora_slot_bytes"] = arena
        if self._collective:
            out["collective_frac"] = round(COLLECTIVE_METER.frac(), 4)
            out["collective_dispatches"] = COLLECTIVE_METER.dispatches
        return out

    def stats(self) -> dict:
        return {
            "completed": len(self.completed),
            "cache": self.cache.stats.__dict__.copy(),
            "bypassed": getattr(self.sched, "n_bypassed", 0),
            "squashed": getattr(self.sched, "n_squashed", 0),
            "deferred": getattr(self.sched, "n_deferred", 0),
            "cancelled": self.n_cancelled,
            "expired": self.n_expired,
            "async_loads": self.n_async_loads,
            "pending_loads": len(self._pending_loads),
            "resident_adapters": sorted(self.cache.resident_ids()),
            "pool": self.pool.snapshot(),
            # Fused hot loop (DESIGN §2): the device batch state is
            # rebuilt only when this epoch counter moves.
            "fused_hotloop": self.fused,
            "batch_epoch": self.batch_epoch,
            **self.kv_page_stats(),
            **self.handoff_stats(),
            **self.prefix_stats(),
            **self.spec_stats(),
            **self.shard_stats(),
        }

    def metrics(self) -> RunMetrics:
        """Per-node RunMetrics, aggregatable at cluster level."""
        # Submitted = completed + in the batch + still queued, so a
        # truncated run shows its loss instead of a fake 100% rate.
        n_sub = (len(self.records) + int(self.active.sum())
                 + self.sched.pending_count())
        m = RunMetrics(records=list(self.records), horizon=self.now(),
                       n_submitted=n_sub)
        m.cache_stats = {
            "hit_rate": round(self.cache.stats.hit_rate, 4),
            "hits": self.cache.stats.hits,
            "misses": self.cache.stats.misses,
            "evictions": self.cache.stats.evictions,
            "gb_loaded": round(self.cache.stats.bytes_loaded / 1e9, 6),
        }
        m.sched_stats = {
            "bypassed": getattr(self.sched, "n_bypassed", 0),
            "squashed": getattr(self.sched, "n_squashed", 0),
            "deferred": getattr(self.sched, "n_deferred", 0),
            "cancelled": self.n_cancelled,
            "expired": self.n_expired,
            "async_loads": self.n_async_loads,
            "pressure": round(self.queue_pressure(), 3),
            "batch_occupancy_mean": round(
                float(np.mean(self.batch_occupancy))
                if self.batch_occupancy else 0.0, 3),
            **self.kv_page_stats(),
            **self.handoff_stats(),
            **self.prefix_stats(),
            **self.spec_stats(),
            **self.shard_stats(),
        }
        return m
