"""JAX serving engine: continuous batching with Chameleon integrated.

This is the *real* data plane (tier 1 in DESIGN §2): a jit'd decode
step over slot-padded KV caches and LoRA adapter-slot buffers, driven
by the same ChameleonScheduler / AdapterCache / MemoryPool objects the
simulator uses. On TPU the LoRA matmuls route to the Pallas bgmv/sgmv
kernels; on this CPU container the jnp reference path runs (same math).

Static-shape design (TPU-native):
- ``max_slots`` request slots; inactive slots run masked garbage that is
  never surfaced (standard TPU continuous batching);
- KV caches (L, max_slots, max_len, Kh, Dh) written in place per slot;
- ``n_lora_slots`` adapter-slot buffers; the cache manager's on_load
  writes adapter weights into a slot (device-side copy), on_evict frees
  it. Residency decisions stay 100 % in repro.core — this file only
  moves bytes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdapterCache, AdapterInfo, ChameleonScheduler,
                        MemoryPool, NoisyOraclePredictor, Request,
                        RequestState, build_adapter_pool)
from repro.models import api
from repro.models.base import ModelConfig
from repro.models.lora_apply import (init_lora_slots, random_lora_weights,
                                     write_adapter_to_slot)


@dataclass
class EngineConfig:
    max_slots: int = 8
    max_len: int = 256
    n_lora_slots: int = 8
    r_max: int = 32
    n_adapters: int = 16
    predictor_accuracy: float = 0.8
    seed: int = 0


class ChameleonEngine:
    """Single-host engine over a (small) real model."""

    def __init__(self, cfg: ModelConfig, params: dict,
                 ecfg: EngineConfig | None = None,
                 scheduler_cls=ChameleonScheduler, cache_enabled=True):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg or EngineConfig()
        e = self.ecfg
        key = jax.random.PRNGKey(e.seed)

        # --- LoRA adapter catalog (host-side weights = "host memory") ---
        ranks = [cfg.lora_ranks[i % len(cfg.lora_ranks)]
                 for i in range(e.n_adapters)]
        ranks = [min(r, e.r_max) for r in ranks]
        keys = jax.random.split(key, e.n_adapters)
        self.host_adapters = {
            aid: random_lora_weights(keys[aid], ranks[aid], e.r_max,
                                     cfg.n_layers, cfg.d_model,
                                     cfg.q_dim, cfg.kv_dim)
            for aid in range(e.n_adapters)}
        # Device adapter-slot buffers.
        self.lora = init_lora_slots(key, e.n_lora_slots, cfg.n_layers,
                                    cfg.d_model, cfg.q_dim, cfg.kv_dim,
                                    e.r_max)
        self.slot_of: dict[int, int] = {}       # adapter_id -> lora slot
        self.free_slots = list(range(e.n_lora_slots))

        # --- memory pool in token units ---
        kv_token_bytes = (2 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim
                          * 2)
        lora_bytes = {aid: sum(
            int(np.prod(a.shape) + np.prod(b.shape)) * 2
            for a, b in self.host_adapters[aid].values())
            for aid in self.host_adapters}
        catalog = {aid: AdapterInfo(
            adapter_id=aid, rank=ranks[aid], size_bytes=lora_bytes[aid],
            size_tokens=max(1, lora_bytes[aid] // kv_token_bytes))
            for aid in self.host_adapters}
        # Capacity: KV slots + room for a few adapters.
        cap = e.max_slots * e.max_len \
            + 4 * max(c.size_tokens for c in catalog.values())
        self.pool = MemoryPool(capacity_tokens=cap)
        self.cache = AdapterCache(self.pool, catalog,
                                  enabled=cache_enabled,
                                  on_load=self._load_adapter,
                                  on_evict=self._evict_adapter,
                                  max_entries=e.n_lora_slots)
        pred = NoisyOraclePredictor(accuracy=e.predictor_accuracy,
                                    seed=e.seed)
        self.sched = scheduler_cls(self.pool, self.cache, catalog, pred,
                                   max_batch_requests=e.max_slots,
                                   t_refresh=5.0)

        # --- device state ---
        self.kv = api.init_serve_state(cfg, e.max_slots, e.max_len,
                                       jnp.float32)
        self.tokens = jnp.zeros((e.max_slots, 1), jnp.int32)
        self.cache_len = jnp.zeros((e.max_slots,), jnp.int32)
        self.active = np.zeros((e.max_slots,), bool)
        self.adapter_slot = jnp.zeros((e.max_slots,), jnp.int32)
        self.slot_req: list[Optional[Request]] = [None] * e.max_slots
        self.t0 = time.monotonic()
        self.completed: list[Request] = []
        self.outputs: dict[int, list[int]] = {}

        self._decode_jit = jax.jit(self._decode_fn)
        self._prefill_jit = jax.jit(self._prefill_fn,
                                    static_argnames=("S",))

    # ------------------------------------------------------------- clock
    def now(self) -> float:
        return time.monotonic() - self.t0

    # ----------------------------------------------------- adapter moves
    def _load_adapter(self, info: AdapterInfo) -> None:
        slot = self.free_slots.pop()
        self.slot_of[info.adapter_id] = slot
        self.lora = write_adapter_to_slot(
            self.lora, self.host_adapters[info.adapter_id], slot)

    def _evict_adapter(self, info: AdapterInfo) -> None:
        slot = self.slot_of.pop(info.adapter_id)
        self.free_slots.append(slot)

    # ------------------------------------------------------- jit'd steps
    def _decode_fn(self, params, lora, tokens, kv, cache_len,
                   adapter_slot):
        return api.decode_step(self.cfg, params, tokens, kv, cache_len,
                               lora=lora, adapter_idx=adapter_slot)

    def _prefill_fn(self, params, lora, tokens, adapter_slot, last_pos,
                    S):
        del S
        return api.prefill(self.cfg, params, tokens, lora=lora,
                           adapter_idx=adapter_slot, last_pos=last_pos)

    # ---------------------------------------------------------- lifecycle
    def submit(self, req: Request) -> None:
        self.sched.submit(req, self.now())

    def _place(self, req: Request) -> None:
        slot = int(np.where(~self.active)[0][0])
        self.active[slot] = True
        self.slot_req[slot] = req
        # Prefill this request alone, right-padded to a power-of-two
        # bucket (keeps RoPE positions correct and recompiles bounded).
        S = 1 << max(3, (req.input_len - 1).bit_length())
        toks = np.zeros((1, S), np.int32)
        prompt = np.arange(req.input_len) % self.cfg.vocab_size
        toks[0, :req.input_len] = prompt
        lslot = self.slot_of[req.adapter_id]
        lora1 = {k: (a[:, lslot:lslot + 1], b[:, lslot:lslot + 1])
                 for k, (a, b) in self.lora.items()}
        logits, kv_new = self._prefill_jit(
            self.params, lora1, jnp.asarray(toks), jnp.zeros(1, jnp.int32),
            jnp.asarray([req.input_len - 1]), S)
        # Write the request's KV into its slot (drop right padding).
        k_new, v_new = kv_new
        kseq = k_new[:, 0, :req.input_len]
        vseq = v_new[:, 0, :req.input_len]
        k, v = self.kv
        k = k.at[:, slot, :req.input_len].set(kseq)
        v = v.at[:, slot, :req.input_len].set(vseq)
        self.kv = (k, v)
        first = int(jnp.argmax(logits[0]))
        self.tokens = self.tokens.at[slot, 0].set(first)
        self.cache_len = self.cache_len.at[slot].set(req.input_len)
        self.adapter_slot = self.adapter_slot.at[slot].set(lslot)
        req.generated = 1
        req.first_token_time = self.now()
        self.outputs[req.req_id] = [first]
        if req.done:
            self._finish(slot)

    def _finish(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.state = RequestState.FINISHED
        req.finish_time = self.now()
        self.sched.on_finish(req, self.now())
        self.completed.append(req)
        self.active[slot] = False
        self.slot_req[slot] = None

    def step(self) -> None:
        """One engine iteration: admit -> (prefills) -> one decode."""
        now = self.now()
        running = [r for r in self.slot_req if r is not None]
        admitted = self.sched.schedule(now, running)
        for req in admitted:
            self._place(req)
        if not self.active.any():
            return
        logits, self.kv = self._decode_jit(
            self.params, self.lora, self.tokens, self.kv,
            self.cache_len, self.adapter_slot)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        self.cache_len = self.cache_len + jnp.asarray(self.active,
                                                      jnp.int32)
        to_finish, to_squash = [], []
        for slot in np.where(self.active)[0]:
            req = self.slot_req[slot]
            req.generated += 1
            self.outputs[req.req_id].append(int(nxt[slot]))
            if req.done or req.generated + req.input_len \
                    >= self.ecfg.max_len - 1:
                to_finish.append(slot)
            elif req.bypassed and req.exceeded_prediction():
                to_squash.append(slot)
        for slot in to_finish:
            self._finish(slot)
        for slot in to_squash:
            req = self.slot_req[slot]
            self.active[slot] = False
            self.slot_req[slot] = None
            self.outputs.pop(req.req_id, None)
            self.sched.on_squash(req, self.now())

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.active.any() and self.sched.pending_count() == 0:
                break
            self.step()

    # ---------------------------------------------------------- reporting
    def stats(self) -> dict:
        return {
            "completed": len(self.completed),
            "cache": self.cache.stats.__dict__.copy(),
            "bypassed": getattr(self.sched, "n_bypassed", 0),
            "squashed": getattr(self.sched, "n_squashed", 0),
            "resident_adapters": sorted(self.cache.resident_ids()),
        }
