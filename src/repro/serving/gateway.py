"""Multi-tenant gateway: admission control, weighted-fair dispatch and
SLO-aware overload behavior over any ``ServingSystem`` (DESIGN §3.3).

The engine-side scheduler (``core.scheduler``) prevents head-of-line
blocking *inside* one continuous batch, but nothing below this layer
bounds a misbehaving tenant: a single org replaying a flood of long
requests inflates every other tenant's TTFT and the overload behavior
is implicit (queues grow without bound). The gateway is the front door
that makes overload an explicit, observable policy:

- **Per-tenant limits.** Each tenant has a ``TenantPolicy`` (weight,
  max in-flight dispatched into the wrapped tier, max queued at the
  gateway). Exceeding ``max_queued`` rejects early with a retry-after
  hint instead of letting the backlog grow.
- **Two-lane weighted-fair queueing.** Admitted requests are classified
  by *predicted* decode length (``core.predictor.predict_request`` —
  the same hook the scheduler uses, so both layers agree) into a short
  and a long lane; within each lane tenants are scheduled by start-time
  fair queueing (VERONICA-style: virtual time, per-tenant finish tags,
  cost = predicted tokens / weight), and the lanes interleave by a
  configurable ratio so long requests cannot starve short ones and
  vice versa.
- **SLO-aware overload handling.** When a request carries a deadline
  (``ttl`` / ``Request.deadline``) — or ``GatewayConfig.slo_default_s``
  arms one for everything — admission projects its completion from the
  current backlog and a self-calibrating service estimate. Predicted
  TTFT past the budget rejects immediately (``retry_after`` tells the
  client when the backlog should have drained); a feasible TTFT whose
  *full* decode would bust the budget degrades ``max_new_tokens`` to
  what still fits (never below ``degrade_floor_tokens``).
- **Decision traces.** Every submit resolves to a terminal handle state
  *and* a ``GatewayDecision`` (admit/degrade/reject + lane + reason +
  the numbers the decision was made from), attached to the handle and
  kept in ``Gateway.decisions``. Rejected requests terminate in the
  ``REJECTED`` state without ever touching the wrapped tier — refusal
  is reported, never dropped.

The gateway itself implements ``ServingSystem``, so anything that can
drive an engine can drive a gated engine; ``build_system(...,
gateway=...)`` wires it over any tier. Aggregate health is exported as
``gateway_stats()`` (per-tenant counters) and as ``gw_*`` gauges merged
into ``metrics().sched_stats`` (catalogued in ``serving.metrics.GAUGES``
and docs/OPERATIONS.md).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.predictor import HistogramPredictor, predict_request
from repro.core.request import Request, RequestState
from repro.core.sampling import GREEDY, SamplingParams

from .handles import DRAIN_MAX_STEPS, RequestHandle, prepare_request

LANES = ("short", "long")


# ----------------------------------------------------------------------
# Policy / configuration
# ----------------------------------------------------------------------
@dataclass
class TenantPolicy:
    """Per-tenant limits and fair-share weight.

    weight        relative service share under backlog (WFQ cost is
                  predicted tokens / weight);
    max_inflight  requests dispatched into the wrapped tier and not yet
                  terminal; the gateway holds the rest back;
    max_queued    requests the gateway will hold for this tenant before
                  rejecting new submits with a retry-after.
    """

    weight: float = 1.0
    max_inflight: int = 8
    max_queued: int = 64


@dataclass
class GatewayConfig:
    """Gateway policy knobs (per-knob guidance in docs/OPERATIONS.md)."""

    #: Policy for tenants absent from ``tenants``.
    default_policy: TenantPolicy = field(default_factory=TenantPolicy)
    #: Per-tenant overrides, keyed by ``Request.tenant``.
    tenants: dict = field(default_factory=dict)
    #: Predicted decode length at or below this goes to the short lane.
    short_lane_max_decode: int = 64
    #: Lane interleave ratio (short, long): with (3, 1) three short-lane
    #: dispatches are attempted per long-lane one when both have work.
    lane_weights: tuple = (3, 1)
    #: Stop dispatching into the wrapped tier once its
    #: ``queue_pressure()`` reaches this (keeps the engine's own queue
    #: shallow so fairness decisions stay at the gateway, where tenant
    #: identity exists).
    dispatch_pressure_max: float = 32.0
    #: Global cap on requests queued at the gateway (all tenants).
    max_queued_total: int = 512
    #: Deadline budget armed for requests submitted without one
    #: (None = no implicit SLO; only explicit ttl/deadline requests get
    #: SLO treatment).
    slo_default_s: Optional[float] = None
    #: Never degrade ``max_new_tokens`` below this; reject instead.
    degrade_floor_tokens: int = 16
    #: Fraction of the residual budget the degraded decode may consume
    #: (headroom for estimate error).
    degrade_safety: float = 0.8
    #: Floor for the retry-after hint on rejections.
    min_retry_after_s: float = 0.5
    #: Clamp on predicted output length (mirrors the scheduler's).
    max_predicted_output: int = 4096
    #: Service-time seeds for the wait model; None pulls them from the
    #: cost model when one is supplied (sim tier) else falls back to
    #: conservative constants. Self-calibrated from completions unless
    #: ``calibrate=False``.
    init_s_per_tok: Optional[float] = None
    init_ttft_s: Optional[float] = None
    #: Effective service parallelism of the wrapped tier (≈ continuous
    #: batch width): backlog drains this many streams at once.
    service_parallelism: float = 8.0
    #: EMA step for the self-calibrating service estimates.
    ema_alpha: float = 0.2
    calibrate: bool = True


@dataclass
class GatewayDecision:
    """Admission-time record of what the gateway did to one request.

    ``action`` is one of ``admit`` / ``degrade`` / ``reject``; the
    terminal *outcome* (finished / cancelled / expired / rejected) lives
    on the request/handle state. The numbers the decision was computed
    from ride along so an operator can reconstruct any admit/reject
    from the trace alone.
    """

    req_id: int
    tenant: str
    action: str                       # admit | degrade | reject
    lane: Optional[str]               # short | long | None (rejected)
    reason: str
    t: float                          # decision time (system clock)
    predicted_wait_s: float = 0.0     # backlog drain estimate at admission
    budget_s: Optional[float] = None  # deadline budget (None = no SLO)
    retry_after_s: Optional[float] = None
    max_new_tokens: Optional[int] = None       # post-degrade cap
    original_max_new_tokens: Optional[int] = None


@dataclass
class _TenantState:
    policy: TenantPolicy
    queued: int = 0
    queued_tokens: int = 0
    inflight: int = 0
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    degraded: int = 0
    completed: int = 0      # FINISHED after dispatch
    failed: int = 0         # CANCELLED/EXPIRED after dispatch
    expired_queued: int = 0
    cancelled_queued: int = 0
    tokens_done: int = 0


class _FairLane:
    """Start-time fair queueing over tenants (one instance per lane).

    Classic SFQ: each request gets a start tag ``max(vtime,
    last_finish[tenant])`` and a finish tag ``start + cost/weight``;
    dispatch serves the smallest eligible finish tag and advances
    virtual time to the served start tag. A tenant that went idle
    re-enters at the current virtual time, so backlog built by a flood
    never counts against a light tenant's next request.
    """

    def __init__(self):
        self.queues: dict[str, deque] = {}   # tenant -> (start, fin, req)
        self.vtime = 0.0
        self._finish: dict[str, float] = {}

    def __len__(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def push(self, req: Request, weight: float, cost: float) -> None:
        start = max(self.vtime, self._finish.get(req.tenant, 0.0))
        fin = start + cost / max(weight, 1e-9)
        self._finish[req.tenant] = fin
        self.queues.setdefault(req.tenant, deque()).append((start, fin, req))

    def pop_fair(self, eligible: Callable[[str], bool]) -> Optional[Request]:
        """Serve the smallest finish tag among tenants ``eligible``
        accepts (ineligible = at max_inflight); None when no tenant
        qualifies."""
        best, best_fin = None, float("inf")
        for tenant, q in self.queues.items():
            if not q or not eligible(tenant):
                continue
            if q[0][1] < best_fin:
                best_fin, best = q[0][1], tenant
        if best is None:
            return None
        start, _, req = self.queues[best].popleft()
        self.vtime = max(self.vtime, start)
        return req

    def remove(self, req: Request) -> bool:
        q = self.queues.get(req.tenant)
        if not q:
            return False
        for item in q:
            if item[2] is req:
                q.remove(item)
                return True
        return False

    def requests(self):
        for q in self.queues.values():
            for _, _, req in q:
                yield req


# ----------------------------------------------------------------------
# The gateway
# ----------------------------------------------------------------------
class Gateway:
    """Admission/dispatch layer wrapping one ``ServingSystem``.

    ``submit`` accepts both surfaces: the ISSUE/operator shape
    ``gateway.submit(tenant_id, request)`` and the ``ServingSystem``
    protocol shape ``gateway.submit(request, sampling=..., ...)`` with
    the tenant read from ``Request.tenant``. Either way the caller gets
    a ``RequestHandle`` whose ``decision`` attribute carries the
    ``GatewayDecision`` (and ``retry_after`` on rejection); tokens
    stream through the gateway handle exactly as they would from the
    wrapped tier.

    Requests submitted with a *future* ``arrival_time`` (trace replay)
    are held and admitted when the wrapped tier's clock reaches them —
    admission control must see the backlog as of arrival, not as of the
    submit call. On DES tiers the gateway advances virtual time across
    idle gaps itself, so ``drain()`` replays a whole trace.
    """

    def __init__(self, inner, cfg: Optional[GatewayConfig] = None, *,
                 predictor=None, cost_model=None):
        self.inner = inner
        self.cfg = cfg or GatewayConfig()
        self.predictor = predictor or HistogramPredictor()
        self.cost = cost_model
        self.lanes: dict[str, _FairLane] = {ln: _FairLane() for ln in LANES}
        self.tenants: dict[str, _TenantState] = {}
        self.decisions: dict[int, GatewayDecision] = {}
        self._handles: dict[int, RequestHandle] = {}
        self._inner_handles: dict[int, RequestHandle] = {}
        self._dispatched: dict[int, Request] = {}
        self._cost_tokens: dict[int, int] = {}
        self._future: list = []                  # (arrival, seq, req) heap
        self._seq = itertools.count()
        self._queued_tokens = 0
        self._inflight_tokens = 0
        self._deadlines_armed = False
        # Weighted lane interleave pattern, e.g. (3,1) -> S,S,S,L.
        s, l = self.cfg.lane_weights
        self._lane_pattern = ["short"] * int(s) + ["long"] * int(l)
        self._lane_idx = 0
        # Aggregate counters.
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_rejected = 0
        self.n_degraded = 0
        self.n_dispatched = 0
        self.n_expired_queued = 0
        self.n_cancelled_queued = 0
        # Service-time estimates for the wait model (seeded from the
        # cost model where available, then EMA-calibrated from every
        # completion the gateway observes).
        self._s_per_tok = self.cfg.init_s_per_tok
        self._ttft_est = self.cfg.init_ttft_s
        if cost_model is not None:
            if self._s_per_tok is None:
                self._s_per_tok = cost_model.decode_time(1, 512, [16])
            if self._ttft_est is None:
                self._ttft_est = cost_model.isolated_ttft(256, 16)
        if self._s_per_tok is None:
            self._s_per_tok = 0.02
        if self._ttft_est is None:
            self._ttft_est = 0.25

    # ------------------------------------------------------------- clock
    def _now(self) -> float:
        n = getattr(self.inner, "now", None)
        if callable(n):
            return float(n())
        if isinstance(n, (int, float)):
            return float(n)
        nodes = getattr(self.inner, "nodes", None)
        if nodes:
            return max(float(nd.now) for nd in nodes)
        return 0.0

    def _advance_clock(self, t: float) -> None:
        """DES tiers only: jump virtual time to the next gateway-held
        arrival when the whole stack is idle (wall-clock tiers advance
        themselves)."""
        n = getattr(self.inner, "now", None)
        if isinstance(n, (int, float)):
            self.inner.now = max(float(n), t)
            return
        nodes = getattr(self.inner, "nodes", None)
        if nodes and isinstance(getattr(nodes[0], "now", None), float):
            for nd in nodes:
                nd.now = max(nd.now, t)

    # ----------------------------------------------------------- helpers
    def _tenant(self, name: str) -> _TenantState:
        ts = self.tenants.get(name)
        if ts is None:
            policy = self.cfg.tenants.get(name, self.cfg.default_policy)
            ts = self.tenants[name] = _TenantState(policy=policy)
        return ts

    def _intended_decode(self, req: Request) -> int:
        cap = (req.sampling.max_new_tokens
               if req.sampling is not None else None)
        out = req.predicted_output
        return min(out, cap) if cap is not None else out

    def _cost_of(self, req: Request) -> int:
        return req.input_len + self._intended_decode(req)

    def predicted_wait_s(self, tenant: Optional[str] = None) -> float:
        """Backlog drain estimate over the calibrated service rate.

        Without a tenant: the global conservative view (all queued +
        in-flight predicted tokens) — the gauge the operator watches.
        With a tenant: fair-share-aware — SFQ guarantees the tenant at
        least ``weight/sum(active weights)`` of service, so only its
        *own* backlog is divided by that share; another tenant's flood
        does not count against it (that is the whole point of the
        gateway). In-flight work delays everyone and counts fully.
        """
        par = max(1.0, self.cfg.service_parallelism)
        if tenant is None:
            backlog = self._queued_tokens + self._inflight_tokens
            return backlog * self._s_per_tok / par
        ts = self._tenant(tenant)
        active_w = sum(t.policy.weight for name, t in self.tenants.items()
                       if t.queued > 0 or name == tenant)
        share = ts.policy.weight / max(active_w, 1e-9)
        backlog = self._inflight_tokens + ts.queued_tokens / max(share, 1e-9)
        return backlog * self._s_per_tok / par

    def _total_queued(self) -> int:
        return sum(len(lane) for lane in self.lanes.values())

    # ------------------------------------------------------------ submit
    def submit(self, req, maybe_req=None, *,
               sampling: Optional[SamplingParams] = None,
               on_token: Optional[Callable[[int], None]] = None,
               ttl: Optional[float] = None,
               tenant: Optional[str] = None) -> RequestHandle:
        """Admit (or refuse) one request; non-blocking.

        ``submit(tenant_id, request)`` and ``submit(request,
        tenant=...)`` both tag ``request.tenant``; plain
        ``submit(request)`` uses the tag already on the request.
        """
        if isinstance(req, str):
            tenant, req = req, maybe_req
        if req is None:
            raise TypeError("submit needs a Request")
        if tenant is not None:
            req.tenant = tenant
        now = self._now()
        # Deadlines anchor at *arrival* (a trace request submitted
        # early must not gain budget from the early submit).
        if ttl is not None and req.deadline is None:
            req.deadline = max(now, req.arrival_time) + ttl
        elif req.deadline is None and self.cfg.slo_default_s is not None:
            req.deadline = (max(now, req.arrival_time)
                            + self.cfg.slo_default_s)
        handle = prepare_request(req, self, now, sampling, on_token, None)
        self._handles[req.req_id] = handle
        if req.deadline is not None:
            self._deadlines_armed = True
        self.n_submitted += 1
        self._tenant(req.tenant).submitted += 1
        if req.arrival_time > now:
            # Trace replay: decision deferred to the arrival instant.
            heapq.heappush(self._future,
                           (req.arrival_time, next(self._seq), req))
        else:
            self._admit(req, now)
        return handle

    # The admission state machine: classify -> limit-check -> SLO
    # check -> enqueue (or reject). One GatewayDecision per request.
    def _admit(self, req: Request, now: float) -> None:
        ts = self._tenant(req.tenant)
        predict_request(self.predictor, req, self.cfg.max_predicted_output)
        lane = ("short" if self._intended_decode(req)
                <= self.cfg.short_lane_max_decode else "long")
        wait = self.predicted_wait_s(req.tenant)
        budget = (req.deadline - max(now, req.arrival_time)
                  if req.deadline is not None else None)

        if ts.queued >= ts.policy.max_queued:
            self._reject(req, ts, lane, "tenant_queue_full", now, wait,
                         budget)
            return
        if self._total_queued() >= self.cfg.max_queued_total:
            self._reject(req, ts, lane, "gateway_queue_full", now, wait,
                         budget)
            return

        action, reason = "admit", "ok"
        orig_cap = (req.sampling.max_new_tokens
                    if req.sampling is not None else None)
        new_cap = orig_cap
        if budget is not None:
            ttft_proj = wait + self._ttft_est
            if ttft_proj > budget:
                # Queue wait alone busts the deadline; shortening the
                # decode cannot help. Tell the client when to retry.
                self._reject(req, ts, lane, "predicted_slo_miss", now,
                             wait, budget,
                             retry_after=max(self.cfg.min_retry_after_s,
                                             ttft_proj - budget))
                return
            decode_proj = self._intended_decode(req) * self._s_per_tok
            if ttft_proj + decode_proj > budget:
                allowed = int((budget - ttft_proj) / self._s_per_tok
                              * self.cfg.degrade_safety)
                if allowed < self.cfg.degrade_floor_tokens:
                    self._reject(req, ts, lane, "deadline_infeasible",
                                 now, wait, budget,
                                 retry_after=max(self.cfg.min_retry_after_s,
                                                 wait))
                    return
                new_cap = (min(orig_cap, allowed) if orig_cap is not None
                           else allowed)
                req.sampling = dataclasses.replace(
                    req.sampling or GREEDY, max_new_tokens=new_cap)
                action, reason = "degrade", "predicted_slo_miss_full_decode"
                ts.degraded += 1
                self.n_degraded += 1

        cost = self._cost_of(req)
        self.lanes[lane].push(req, ts.policy.weight, float(cost))
        ts.queued += 1
        ts.queued_tokens += cost
        ts.admitted += 1
        self.n_admitted += 1
        self._queued_tokens += cost
        self._cost_tokens[req.req_id] = cost
        self._record_decision(GatewayDecision(
            req_id=req.req_id, tenant=req.tenant, action=action, lane=lane,
            reason=reason, t=now, predicted_wait_s=wait, budget_s=budget,
            max_new_tokens=new_cap, original_max_new_tokens=orig_cap))

    def _reject(self, req: Request, ts: _TenantState, lane: str,
                reason: str, now: float, wait: float,
                budget: Optional[float],
                retry_after: Optional[float] = None) -> None:
        if retry_after is None:
            retry_after = max(self.cfg.min_retry_after_s, wait)
        req.state = RequestState.REJECTED
        req.finish_time = now
        ts.rejected += 1
        self.n_rejected += 1
        handle = self._handles.get(req.req_id)
        if handle is not None:
            handle.retry_after = retry_after
        self._record_decision(GatewayDecision(
            req_id=req.req_id, tenant=req.tenant, action="reject",
            lane=None, reason=reason, t=now, predicted_wait_s=wait,
            budget_s=budget, retry_after_s=retry_after))

    def _record_decision(self, d: GatewayDecision) -> None:
        self.decisions[d.req_id] = d
        handle = self._handles.get(d.req_id)
        if handle is not None:
            handle.decision = d

    # ---------------------------------------------------------- stepping
    def step(self) -> None:
        """One gateway iteration: release due arrivals, expire stale
        queue entries, dispatch under the fairness/pressure policy, step
        the wrapped tier, account completions; advance DES time across
        idle gaps."""
        now = self._now()
        while self._future and self._future[0][0] <= now:
            _, _, req = heapq.heappop(self._future)
            if not req.terminal:            # cancelled while held
                self._admit(req, now)
        self._sweep_queued(now)
        self._dispatch()
        self.inner.step()
        self._reap_dispatched()
        if (self._future and not self.inner.busy()
                and not any(len(l) for l in self.lanes.values())):
            self._advance_clock(self._future[0][0])

    def _sweep_queued(self, now: float) -> None:
        if not self._deadlines_armed:
            return
        doomed = [r for lane in self.lanes.values() for r in lane.requests()
                  if r.deadline is not None and r.deadline <= now]
        for req in doomed:
            self._remove_queued(req)
            req.state = RequestState.EXPIRED
            req.finish_time = now
            ts = self._tenant(req.tenant)
            ts.expired_queued += 1
            self.n_expired_queued += 1

    def _remove_queued(self, req: Request) -> bool:
        for lane in self.lanes.values():
            if lane.remove(req):
                ts = self._tenant(req.tenant)
                ts.queued -= 1
                cost = self._cost_tokens.pop(req.req_id, 0)
                ts.queued_tokens -= cost
                self._queued_tokens -= cost
                return True
        return False

    def _dispatch(self) -> None:
        """Drain gateway lanes into the wrapped tier: weighted lane
        interleave on top, SFQ across tenants within a lane, stopping
        at the inner pressure ceiling / per-tenant in-flight caps."""
        while True:
            if self.inner.queue_pressure() >= self.cfg.dispatch_pressure_max:
                return

            def eligible(tenant: str) -> bool:
                ts = self._tenant(tenant)
                return ts.inflight < ts.policy.max_inflight

            req = None
            for _ in range(len(self._lane_pattern)):
                lane = self._lane_pattern[self._lane_idx]
                self._lane_idx = ((self._lane_idx + 1)
                                  % len(self._lane_pattern))
                req = self.lanes[lane].pop_fair(eligible)
                if req is not None:
                    break
            if req is None:
                return
            self._dispatch_one(req)

    def _dispatch_one(self, req: Request) -> None:
        gh = self._handles[req.req_id]
        ih = self.inner.submit(
            req, on_token=lambda tok, h=gh: h._push(len(h._tokens), tok))
        gh.node = ih.node
        self._inner_handles[req.req_id] = ih
        self._dispatched[req.req_id] = req
        ts = self._tenant(req.tenant)
        ts.queued -= 1
        ts.inflight += 1
        self.n_dispatched += 1
        cost = self._cost_tokens.get(req.req_id, 0)
        ts.queued_tokens -= cost
        self._queued_tokens -= cost
        self._inflight_tokens += cost

    def _reap_dispatched(self) -> None:
        done = [rid for rid, req in self._dispatched.items() if req.terminal]
        alpha = self.cfg.ema_alpha
        for rid in done:
            req = self._dispatched.pop(rid)
            self._inner_handles.pop(rid, None)
            self._inflight_tokens -= self._cost_tokens.pop(rid, 0)
            ts = self._tenant(req.tenant)
            ts.inflight -= 1
            if req.state is RequestState.FINISHED:
                ts.completed += 1
                ts.tokens_done += req.generated
            else:
                ts.failed += 1
            if not self.cfg.calibrate:
                continue
            # Self-calibrate the wait model from what actually happened.
            self.predictor.observe(req.adapter_id,
                                   max(1, req.generated))
            if (req.first_token_time is not None
                    and req.first_scheduled_time is not None):
                svc = req.first_token_time - req.first_scheduled_time
                if svc > 0:
                    self._ttft_est += alpha * (svc - self._ttft_est)
            if (req.state is RequestState.FINISHED
                    and req.finish_time is not None
                    and req.first_token_time is not None
                    and req.generated > 1):
                per_tok = ((req.finish_time - req.first_token_time)
                           / (req.generated - 1))
                if per_tok > 0:
                    self._s_per_tok += alpha * (per_tok - self._s_per_tok)

    # ---------------------------------------------------- serving verbs
    def busy(self) -> bool:
        return bool(self._future or self._total_queued()
                    or self.inner.busy())

    def drain(self, max_steps: int = DRAIN_MAX_STEPS) -> None:
        for _ in range(max_steps):
            if not self.busy():
                return
            self.step()

    def cancel(self, handle) -> bool:
        """Cancel wherever the request is: held (future), queued at the
        gateway, or already dispatched (delegated to the wrapped
        tier)."""
        req = handle.req if isinstance(handle, RequestHandle) else handle
        if req.terminal:
            return False
        if req.req_id in self._dispatched:
            return self.inner.cancel(self._inner_handles[req.req_id])
        for i, (_, _, r) in enumerate(self._future):
            if r is req:
                del self._future[i]
                heapq.heapify(self._future)
                req.state = RequestState.CANCELLED
                req.finish_time = self._now()
                self._tenant(req.tenant).cancelled_queued += 1
                self.n_cancelled_queued += 1
                return True
        if self._remove_queued(req):
            req.state = RequestState.CANCELLED
            req.finish_time = self._now()
            self._tenant(req.tenant).cancelled_queued += 1
            self.n_cancelled_queued += 1
            return True
        return False

    def queue_pressure(self) -> float:
        return self.inner.queue_pressure() + float(self._total_queued())

    # ------------------------------------------------------- observability
    def gateway_stats(self) -> dict:
        """The gateway's own health surface: aggregate admission
        counters, live depths, the current wait estimate, and one
        counter block per tenant."""
        return {
            "n_submitted": self.n_submitted,
            "n_admitted": self.n_admitted,
            "n_rejected": self.n_rejected,
            "n_degraded": self.n_degraded,
            "n_dispatched": self.n_dispatched,
            "n_expired_queued": self.n_expired_queued,
            "n_cancelled_queued": self.n_cancelled_queued,
            "lane_depths": {ln: len(l) for ln, l in self.lanes.items()},
            "queued_tokens": self._queued_tokens,
            "inflight_tokens": self._inflight_tokens,
            "predicted_wait_s": round(self.predicted_wait_s(), 4),
            "s_per_tok_est": round(self._s_per_tok, 6),
            "ttft_est_s": round(self._ttft_est, 4),
            "tenants": {
                name: {
                    "weight": ts.policy.weight,
                    "queued": ts.queued, "inflight": ts.inflight,
                    "submitted": ts.submitted, "admitted": ts.admitted,
                    "rejected": ts.rejected, "degraded": ts.degraded,
                    "completed": ts.completed, "failed": ts.failed,
                    "expired_queued": ts.expired_queued,
                    "cancelled_queued": ts.cancelled_queued,
                    "tokens_done": ts.tokens_done,
                } for name, ts in sorted(self.tenants.items())},
        }

    def _gauges(self) -> dict:
        n = max(1, self.n_submitted)
        return {
            "gw_submitted": self.n_submitted,
            "gw_admitted": self.n_admitted,
            "gw_rejected": self.n_rejected,
            "gw_degraded": self.n_degraded,
            "gw_queued": self._total_queued(),
            "gw_inflight": len(self._dispatched),
            "gw_reject_rate": round(self.n_rejected / n, 4),
            "gw_degrade_rate": round(self.n_degraded / n, 4),
            "gw_queue_wait_est_s": round(self.predicted_wait_s(), 4),
        }

    def stats(self) -> dict:
        s = dict(self.inner.stats())
        s["gateway"] = self.gateway_stats()
        return s

    def metrics(self):
        """Wrapped tier's metrics with the gateway's gauges merged into
        ``sched_stats`` and ``n_submitted`` widened to count *every*
        submit (the wrapped tier never saw the rejected ones)."""
        m = self.inner.metrics()
        merged = m[0] if isinstance(m, tuple) else m
        merged.n_submitted = self.n_submitted
        merged.sched_stats.update(self._gauges())
        return m
