"""Serving runtime: trace synthesis, cost model, simulator, JAX engine."""
from .cost_model import (A40, A100_80G, TPU_V5E, CostModel, HardwareSpec,
                         HW_PRESETS, MODEL_PRESETS, ModelSpec)
from .metrics import RequestRecord, RunMetrics, slo_from_lowload
from .simulator import LinkChannel, NodeSimulator, SimConfig
from .systems import SYSTEM_NAMES, NodeConfig, build_node
from .trace import Trace, TraceConfig, load_azure_csv, synthesize
from .cluster import Cluster, ClusterConfig, run_cluster
