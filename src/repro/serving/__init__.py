"""Serving runtime: trace synthesis, cost model, simulator, JAX engine.

``repro.serving.engine`` (the real JAX data plane) is intentionally not
imported here: the simulator path stays importable without pulling jax.
"""
from .cost_model import (A40, A100_80G, TPU_V5E, CostModel, HardwareSpec,
                         HW_PRESETS, MODEL_PRESETS, ModelSpec)
from .handles import (RequestHandle, RequestResult, ServingSystem,
                      prepare_request)
from .gateway import (Gateway, GatewayConfig, GatewayDecision,
                      TenantPolicy)
from .metrics import (GAUGES, RequestRecord, RunMetrics, merge_metrics,
                      slo_from_lowload)
from .simulator import LinkChannel, NodeSimulator, SimConfig
from .systems import (ENGINE_SYSTEMS, SYSTEM_NAMES, TIERS, NodeConfig,
                      build_engine, build_node, build_system)
from .trace import (Trace, TraceConfig, downscale_for_engine,
                    load_azure_csv, synthesize, synthesize_multitenant)
from .cluster import (POLICIES, Cluster, ClusterConfig, EngineCluster,
                      EngineClusterConfig, Router, run_cluster)
from .disagg import (DisaggCluster, DisaggConfig, KVHandoff,
                     RoleAutoscaler)
