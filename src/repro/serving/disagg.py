"""Disaggregated prefill/decode cluster with paged-KV handoff
(DESIGN §3.4, ROADMAP 3).

``EngineCluster`` replicas are symmetric: every node runs prefill and
decode interleaved, so one long prompt stalls that replica's in-flight
decodes for the whole monolithic prefill (or, with chunked prefill,
still steals every other step). ``DisaggCluster`` splits the fleet by
*role* instead:

- **prefill replicas** run admission + prefill and at most one decode
  step per request (the first token, produced by prefill itself);
- **decode replicas** run the steady-state continuous batch.

A request's life: route to a prefill replica (``prefix_affinity`` by
default, so warm radix trees keep working), prefill there, then its KV
pages + streamed-token state migrate to a decode replica over the
``KVHandoff`` plane and decode continues token-identically — the
shipped KV is bit-for-bit the source pages, the page-table indirection
makes physical page ids irrelevant, and greedy / position-seeded
sampling is deterministic. The handoff window is the ``MIGRATING``
request state: the source keeps the slot, pool holds and shared-page
refs (so prefix-tree eviction or cache shrink can never reclaim pages
mid-copy), and cancel / deadline expiry stay legal on both sides.

The link is modeled, not real (one host in CI): shipments serialize
over a single inter-replica link of ``link_gbps``; a shipment becomes
importable only once its modeled transfer completes on the shared
clock, so handoff cost scales with KV bytes exactly like the adapter
H2D model (``EngineConfig.h2d_gbps``).

Role-aware placement:

- decode destinations pack by *adapter rank* — an adapter's requests
  stick to one decode home (chosen resident-first, then least
  cumulative resident-rank load) so high-rank adapters spread instead
  of piling onto one replica, with a bounded least-loaded spill when
  the home is overloaded (same escape hatch as ``adapter_affinity``);
- the chosen home's histogram prefetcher is fed at *submit* time
  (``observe_arrival``), so the decode replica starts warming the
  adapter while the prompt is still prefilling — the handoff's
  adapter load overlaps prefill + link time;
- when the prefill tier saturates relative to decode
  (``spill_factor``), new requests **spill back** to a decode replica
  and run there monolithically — disaggregation degrades to the
  symmetric cluster instead of queueing behind a prefill convoy.

``RoleAutoscaler`` watches per-role token demand (queued prompt tokens
+ histogram-predicted imminent arrivals vs predicted remaining decode
tokens) and emits advisory per-role replica targets with concrete
``distributed.elastic`` mesh plans; ``autoscale_apply=True`` lets the
cluster actually move one idle replica across roles at a step boundary
(a moved prefill replica keeps its horizon-1 config — correctness is
config-independent, only its decode throughput is modest until the
next rebalance moves it back).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.request import Request, RequestState

from .cluster import Router, _SharedClock, prefix_route_key
from .handles import DRAIN_MAX_STEPS
from .metrics import RunMetrics, merge_metrics


@dataclass
class DisaggConfig:
    n_prefill: int = 1
    n_decode: int = 2
    system: str = "chameleon"            # see systems.ENGINE_SYSTEMS
    # Routing over the prefill tier (POLICIES); prefix_affinity keeps
    # warm radix trees effective even though prefill replicas hold a
    # request only briefly — the *prompt pages* stay cached there.
    prefill_policy: str = "prefix_affinity"
    affinity_overload_factor: float = 1.5
    # Modeled inter-replica link bandwidth (GB/s) the KV shipments
    # serialize over; 0 = infinitely fast (shipments land next step).
    link_gbps: float = 64.0
    # Spill-back: a new request bypasses the prefill tier when the
    # least-loaded prefill replica's pressure exceeds spill_factor x
    # the least-loaded decode replica's (>= 1; larger = stickier tiers).
    spill_factor: float = 4.0
    # Advisory per-role autoscaler (RoleAutoscaler); autoscale_apply
    # additionally lets the cluster move one idle replica across roles.
    autoscale: bool = True
    autoscale_apply: bool = False
    seed: int = 0


class KVHandoff:
    """The prefill->decode shipment plane: a single modeled link.

    ``begin`` stamps a shipment with its link-transfer completion time
    (transfers serialize: one link, FIFO); ``poll`` returns shipments
    whose modeled transfer has completed on the shared clock. The
    payload itself moved host-side at export time (``begin_migration``
    copied the pages out of the source pool), so nothing here can be
    invalidated by source-side eviction.
    """

    def __init__(self, clock, link_gbps: float):
        self._clock = clock
        self.link_gbps = link_gbps
        self.inflight: list[dict] = []
        self.n_begun = 0
        self.n_delivered = 0
        self.n_dropped = 0
        self.bytes_moved = 0
        self.waits: list[float] = []      # begin -> import latencies
        self._link_free_t = 0.0

    def begin(self, shipment: dict, src, dst) -> dict:
        now = self._clock()
        start = max(now, self._link_free_t)
        xfer = (shipment["nbytes"] / (self.link_gbps * 1e9)
                if self.link_gbps > 0 else 0.0)
        self._link_free_t = start + xfer
        entry = {"shipment": shipment, "src": src, "dst": dst,
                 "t_begin": now, "t_ready": start + xfer, "tries": 0}
        self.inflight.append(entry)
        self.n_begun += 1
        return entry

    def poll(self) -> list[dict]:
        now = self._clock()
        ready = [e for e in self.inflight if e["t_ready"] <= now]
        if ready:
            self.inflight = [e for e in self.inflight
                             if e["t_ready"] > now]
        return ready

    def drop(self, req_id: int) -> Optional[dict]:
        for i, e in enumerate(self.inflight):
            if e["shipment"]["req"].req_id == req_id:
                self.n_dropped += 1
                return self.inflight.pop(i)
        return None

    def delivered(self, entry: dict) -> None:
        self.n_delivered += 1
        self.bytes_moved += entry["shipment"]["nbytes"]
        self.waits.append(self._clock() - entry["t_begin"])

    def stats(self) -> dict:
        return {
            "handoffs": self.n_delivered,
            "handoff_gb": round(self.bytes_moved / 1e9, 6),
            "handoff_wait_s": round(float(np.mean(self.waits)), 6)
            if self.waits else 0.0,
            "handoffs_inflight": len(self.inflight),
            "handoffs_dropped": self.n_dropped,
        }


class RoleAutoscaler:
    """Advisory per-role scaling from demand signals (DESIGN §3.4).

    Tracks EWMAs of prefill-side token demand (queued + mid-chunk
    prompt tokens, plus histogram-predicted imminent arrivals — the
    same per-adapter arrival histograms the prefetcher builds) and
    decode-side demand (predicted remaining output tokens of live
    requests). ``plan`` splits the fixed fleet proportionally and
    attaches concrete ``distributed.elastic`` mesh plans for each
    role's target, so an operator (or ``autoscale_apply``) can act on
    it.
    """

    def __init__(self, alpha: float = 0.4):
        self.alpha = alpha
        self.prefill_ewma = 0.0
        self.decode_ewma = 0.0
        self.n_obs = 0

    def observe(self, prefill_tokens: float, decode_tokens: float) -> None:
        a = self.alpha if self.n_obs else 1.0
        self.prefill_ewma += a * (prefill_tokens - self.prefill_ewma)
        self.decode_ewma += a * (decode_tokens - self.decode_ewma)
        self.n_obs += 1

    def plan(self, n_prefill: int, n_decode: int) -> dict:
        total = n_prefill + n_decode
        demand = self.prefill_ewma + self.decode_ewma
        share = (self.prefill_ewma / demand) if demand > 0 else \
            n_prefill / total
        want_prefill = min(total - 1, max(1, round(total * share)))
        want_decode = total - want_prefill
        out = {"want_prefill": want_prefill, "want_decode": want_decode,
               "prefill_demand_tokens": round(self.prefill_ewma, 1),
               "decode_demand_tokens": round(self.decode_ewma, 1)}
        # Concrete reshard plans: each role is a (replicas, 1) data mesh
        # today; the elastic planner validates the resize and carries
        # the batch split an executor would apply. (Imported here so
        # ``repro.serving`` stays importable without jax.)
        from repro.distributed.elastic import scale_out_plan
        out["prefill_plan"] = scale_out_plan(
            (n_prefill, 1), ("data", "model"), want_prefill,
            global_batch=want_prefill)
        out["decode_plan"] = scale_out_plan(
            (n_decode, 1), ("data", "model"), want_decode,
            global_batch=want_decode)
        return out


class DisaggCluster:
    """Prefill/decode-disaggregated engine fleet behind the standard
    ``ServingSystem`` surface (DESIGN §3.4).

    Construction mirrors ``EngineCluster``: one shared
    ``AdapterCatalog`` (host weights are never duplicated), one shared
    wall clock, per-replica device state. Prefill replicas run with
    ``max_horizon=1`` and synchronous readback so the cluster can
    harvest a finished prefill at the very next step boundary instead
    of letting the source race ahead through decode horizons.
    """

    def __init__(self, cfg, params, ecfg=None, dcfg=None):
        from .engine import AdapterCatalog, EngineConfig
        from .systems import build_engine

        self.dcfg = dcfg or DisaggConfig()
        self.ecfg = ecfg or EngineConfig()
        if self.dcfg.n_prefill < 1 or self.dcfg.n_decode < 1:
            raise ValueError("DisaggCluster needs >=1 replica per role")
        self.catalog = AdapterCatalog(cfg, self.ecfg.n_adapters,
                                      self.ecfg.r_max,
                                      seed=self.dcfg.seed)
        self._clock = _SharedClock()
        prefill_ecfg = dataclasses.replace(
            self.ecfg, max_horizon=1, pipeline_readback=False)
        self.prefill = [
            build_engine(self.dcfg.system, cfg, params, prefill_ecfg,
                         catalog=self.catalog, clock=self._clock)
            for _ in range(self.dcfg.n_prefill)]
        self.decode = [
            build_engine(self.dcfg.system, cfg, params, self.ecfg,
                         catalog=self.catalog, clock=self._clock)
            for _ in range(self.dcfg.n_decode)]
        self.router = Router(self.dcfg.prefill_policy,
                             self.dcfg.n_prefill,
                             self.dcfg.affinity_overload_factor,
                             seed=self.dcfg.seed)
        self.handoff = KVHandoff(self._clock, self.dcfg.link_gbps)
        self.autoscaler = (RoleAutoscaler() if self.dcfg.autoscale
                           else None)
        self.last_role_plan: Optional[dict] = None
        # Shipments delivered by the link but not yet imported (decode
        # replica had no slot/pages/adapter room; retried every step).
        self._pending: list[dict] = []
        # req_id -> engine currently responsible (for cancel routing).
        self._loc: dict[int, object] = {}
        # Rank-aware decode placement state (engine objects as keys so
        # role rebalances never invalidate them).
        self._adapter_home: dict[int, object] = {}
        self._rank_load: dict[int, float] = {}   # id(engine) -> rank sum
        self.n_submitted = 0
        self.n_spilled = 0
        self.n_rebalances = 0
        self.routed_prefill = 0

    # ------------------------------------------------------------ misc
    @property
    def engines(self) -> list:
        return self.prefill + self.decode

    def now(self) -> float:
        return self._clock()

    def _index(self, engine) -> int:
        return next(i for i, e in enumerate(self.engines) if e is engine)

    def warmup(self) -> None:
        """Force the dominant jit compiles on every replica (both
        roles), then reset stats and the shared clock — identical to
        ``EngineCluster.warmup`` so disagg-vs-monolithic A/Bs start
        from the same warm state."""
        for e in self.engines:
            e.submit(Request(input_len=8, output_len=2, adapter_id=0))
            e.drain()
            e.reset_stats()
        self._clock.reset()

    # ---------------------------------------------------------- placement
    def _decode_home(self, req: Request):
        """Rank-aware decode placement: resident replica first, then
        the sticky per-adapter home, then the replica with the least
        cumulative resident-rank load (so big adapters spread), with a
        bounded least-loaded spill when the target is overloaded."""
        aid = req.adapter_id
        least = min(self.decode, key=lambda e: e.queue_pressure())
        floor = max(1.0, least.queue_pressure())

        def overloaded(e) -> bool:
            return e.queue_pressure() \
                > self.dcfg.affinity_overload_factor * floor

        home = self._adapter_home.get(aid)
        if home is not None and any(home is e for e in self.decode) \
                and not overloaded(home):
            return home
        resident = [e for e in self.decode if e.cache.resident(aid)]
        if resident:
            home = min(resident, key=lambda e: e.queue_pressure())
        else:
            home = min(self.decode,
                       key=lambda e: (self._rank_load.get(id(e), 0.0),
                                      e.queue_pressure()))
        if overloaded(home):
            home = least
        if self._adapter_home.get(aid) is not home:
            self._rank_load[id(home)] = (
                self._rank_load.get(id(home), 0.0)
                + self.catalog.rank_of(aid))
        self._adapter_home[aid] = home
        return home

    # ------------------------------------------------------------- serve
    def submit(self, req: Request, *, sampling=None, on_token=None,
               ttl=None):
        ploads = [e.queue_pressure() for e in self.prefill]
        dst = self._decode_home(req)
        # Feed the decode home's arrival histogram now: its predictive
        # prefetcher starts warming the adapter while the prompt is
        # still queued/prefilling on the other tier.
        if dst.h_prefetch is not None:
            dst.h_prefetch.observe_arrival(req.adapter_id, self.now())
        if min(ploads) > self.dcfg.spill_factor \
                * max(1.0, min(e.queue_pressure() for e in self.decode)):
            # Prefill tier saturated: run monolithically on decode.
            target = min(self.decode, key=lambda e: e.queue_pressure())
            self.n_spilled += 1
        else:
            node = self.router.route(
                req.adapter_id, ploads,
                [e.cache.resident(req.adapter_id) for e in self.prefill],
                prefix_key=prefix_route_key(req, self.ecfg.page_size))
            target = self.prefill[node]
            self.routed_prefill += 1
        handle = target.submit(req, sampling=sampling,
                               on_token=on_token, ttl=ttl)
        handle.node = self._index(target)
        handle._system = self       # stream() pumps the whole cluster
        self._loc[req.req_id] = target
        self.n_submitted += 1
        return handle

    def cancel(self, handle) -> bool:
        req = handle.req
        if req.terminal:
            return False
        if req.state is RequestState.MIGRATING:
            return self._abort_migrating(req, RequestState.CANCELLED)
        eng = self._loc.get(req.req_id)
        return eng.cancel(handle) if eng is not None else False

    def _abort_migrating(self, req: Request, state: RequestState) -> bool:
        """Tear down a handoff from either stage (on the link, or
        delivered but awaiting import): the source finalizes with the
        shipped streamed-token records restored."""
        rid = req.req_id
        entry = self.handoff.drop(rid)
        if entry is None:
            entry = next((e for e in self._pending
                          if e["shipment"]["req"].req_id == rid), None)
            if entry is not None:
                self._pending.remove(entry)
        if entry is None:
            return False
        return entry["src"].abort_migration(
            req, state, shipment=entry["shipment"])

    # -------------------------------------------------------------- step
    def _harvest(self) -> None:
        """Export every prefill-replica request that has produced its
        first token (prefill done) into the handoff plane."""
        for e in self.prefill:
            for slot in np.where(e.active)[0]:
                req = e.slot_req[slot]
                if req is None or req.generated < 1 \
                        or req.state is not RequestState.RUNNING:
                    continue
                shipment = e.begin_migration(req)
                if shipment is None:
                    continue
                self.handoff.begin(shipment, e, self._decode_home(req))

    def _sweep_migrating(self, now: float) -> None:
        """Cancel / deadline enforcement inside the handoff window —
        MIGRATING requests belong to the cluster, not any engine's
        lifecycle sweep."""
        for entry in list(self.handoff.inflight) + list(self._pending):
            req = entry["shipment"]["req"]
            if req.cancel_requested:
                self._abort_migrating(req, RequestState.CANCELLED)
            elif req.deadline is not None and now >= req.deadline:
                self._abort_migrating(req, RequestState.EXPIRED)

    def _deliver(self) -> None:
        """Import link-completed shipments into their decode replicas;
        a replica that cannot take one yet (no slot / pages / adapter
        room) keeps it pending and it retries every step, re-targeting
        the least-loaded replica after repeated refusals."""
        self._pending.extend(self.handoff.poll())
        still = []
        for entry in self._pending:
            req = entry["shipment"]["req"]
            dst = entry["dst"]
            if entry["tries"] >= 3:
                dst = entry["dst"] = min(
                    self.decode, key=lambda e: e.queue_pressure())
            if dst.import_request_kv(entry["shipment"]):
                entry["src"].complete_migration(req)
                self._loc[req.req_id] = dst
                handle = entry["shipment"]["handle"]
                if handle is not None:
                    handle.node = self._index(dst)
                self.handoff.delivered(entry)
            else:
                entry["tries"] += 1
                still.append(entry)
        self._pending = still

    def _demand_signals(self) -> tuple[float, float]:
        pre = 0.0
        for e in self.prefill:
            pre += sum(r.input_len
                       for r in e.sched.queued_requests_in_order())
            pre += sum(len(st["prompt"]) - st["done"]
                       for st in e._chunked.values())
        # Histogram-predicted imminent arrivals (next ~2s of the same
        # per-adapter inter-arrival histograms the prefetcher uses)
        # count toward prefill demand at the fleet's mean prompt size.
        now = self.now()
        mean_in = 0.0
        n_live = 0
        dec = 0.0
        for e in self.engines:
            for r in e.slot_req:
                if r is None:
                    continue
                mean_in += r.input_len
                n_live += 1
                if r.state in (RequestState.RUNNING,
                               RequestState.MIGRATING):
                    dec += max(0, r.predicted_output - r.generated)
            dec += sum(r.predicted_output
                       for r in e.sched.queued_requests_in_order())
        mean_in = mean_in / n_live if n_live else 0.0
        seen = set()
        for e in self.prefill:
            if e.h_prefetch is None:
                continue
            for aid in e.h_prefetch._last_arrival:
                if aid in seen:
                    continue
                seen.add(aid)
                t = e.h_prefetch._predict_next(aid)
                if t is not None and now <= t <= now + 2.0:
                    pre += mean_in
        for entry in self._pending:
            req = entry["shipment"]["req"]
            dec += max(0, req.predicted_output - req.generated)
        return pre, dec

    def _maybe_rebalance(self) -> None:
        plan = self.last_role_plan
        if plan is None:
            return
        want = plan["want_prefill"]
        if want > len(self.prefill) and len(self.decode) > 1:
            src_pool, dst_pool, to_prefill = self.decode, self.prefill, True
        elif want < len(self.prefill) and len(self.prefill) > 1:
            src_pool, dst_pool, to_prefill = self.prefill, self.decode, False
        else:
            return
        dst_ids = {id(e["dst"]) for e in
                   self.handoff.inflight + self._pending}
        idle = [e for e in src_pool
                if not e.busy() and not e._migrating
                and id(e) not in dst_ids]
        if not idle:
            return
        moved = idle[0]
        src_pool.remove(moved)
        dst_pool.append(moved)
        if to_prefill:
            # Decode homes must not point at a prefill replica.
            self._adapter_home = {a: h for a, h
                                  in self._adapter_home.items()
                                  if h is not moved}
            self._rank_load.pop(id(moved), None)
        self.router = Router(self.dcfg.prefill_policy,
                             len(self.prefill),
                             self.dcfg.affinity_overload_factor,
                             seed=self.dcfg.seed)
        self.n_rebalances += 1

    def step(self) -> None:
        for e in self.prefill:
            e.step()
        self._harvest()
        now = self.now()
        self._sweep_migrating(now)
        self._deliver()
        for e in self.decode:
            e.step()
        if self.autoscaler is not None:
            pre, dec = self._demand_signals()
            self.autoscaler.observe(pre, dec)
            self.last_role_plan = self.autoscaler.plan(
                len(self.prefill), len(self.decode))
            if self.dcfg.autoscale_apply:
                self._maybe_rebalance()

    def busy(self) -> bool:
        return (any(e.busy() for e in self.engines)
                or bool(self.handoff.inflight) or bool(self._pending)
                or any(e._migrating for e in self.engines))

    def drain(self, max_steps: int = DRAIN_MAX_STEPS) -> None:
        for _ in range(max_steps):
            if not self.busy():
                break
            self.step()

    def queue_pressure(self) -> float:
        return float(sum(e.queue_pressure() for e in self.engines)
                     + len(self.handoff.inflight) + len(self._pending))

    def run(self, requests, max_steps: int = 100_000,
            ) -> tuple[RunMetrics, list[RunMetrics]]:
        """Wall-clock replay, same contract as ``EngineCluster.run``."""
        import time as _time
        import warnings

        pending = sorted(requests, key=lambda r: r.arrival_time)
        i = 0
        steps = 0
        while steps < max_steps:
            now = self.now()
            while i < len(pending) and pending[i].arrival_time <= now:
                self.submit(pending[i])
                i += 1
            if not self.busy():
                if i >= len(pending):
                    break
                _time.sleep(min(0.05, max(0.0,
                            pending[i].arrival_time - self.now())))
                continue
            self.step()
            steps += 1
        if i < len(pending) or self.busy():
            warnings.warn(
                f"DisaggCluster.run hit max_steps={max_steps} with "
                f"{len(pending) - i} unsubmitted and work in flight; "
                f"metrics cover a truncated run", RuntimeWarning)
        return self.metrics()

    # --------------------------------------------------------- reporting
    def _role_util(self, engines) -> float:
        occ = []
        for e in engines:
            if e.batch_occupancy:
                occ.append(float(np.mean(e.batch_occupancy))
                           / e.ecfg.max_slots)
        return round(float(np.mean(occ)), 4) if occ else 0.0

    def metrics(self) -> tuple[RunMetrics, list[RunMetrics]]:
        per_node = [e.metrics() for e in self.engines]
        merged = merge_metrics(per_node, n_submitted=self.n_submitted)
        merged.sched_stats.update({
            "prefill_nodes": len(self.prefill),
            "decode_nodes": len(self.decode),
            "spilled_prefills": self.n_spilled,
            "role_rebalances": self.n_rebalances,
            "prefill_util": self._role_util(self.prefill),
            "decode_util": self._role_util(self.decode),
            **self.handoff.stats(),
        })
        return merged, per_node

    def stats(self) -> dict:
        out = {
            "prefill_nodes": len(self.prefill),
            "decode_nodes": len(self.decode),
            "routed_prefill": self.routed_prefill,
            "spilled_prefills": self.n_spilled,
            "role_rebalances": self.n_rebalances,
            "pending_imports": len(self._pending),
            "rank_load": {self._index(e): self._rank_load.get(id(e), 0.0)
                          for e in self.decode},
            "handoff": self.handoff.stats(),
            "per_engine": [e.stats() for e in self.engines],
        }
        if self.last_role_plan is not None:
            plan = dict(self.last_role_plan)
            for k in ("prefill_plan", "decode_plan"):
                p = plan[k]
                plan[k] = {"shape": list(p.shape),
                           "n_devices": p.n_devices,
                           "global_batch": p.global_batch}
            out["role_plan"] = plan
        return out
