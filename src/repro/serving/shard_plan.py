"""ShardPlan: the serving data plane's tensor→device placement map.

One object, built once per engine from (mesh, model config), that turns
the rule table in ``distributed/sharding.py`` into the concrete
``NamedSharding``s the engine needs (DESIGN §4):

- weights           — "model" axis (tensor parallel; resident, never
                      gathered per step)
- LoRA slot arena   — A replicated, B dout over "model" (the delta adds
                      to the projection output without a reshard)
- dense KV caches   — batch over "data", kv heads over "model" when
                      divisible
- paged KV pool     — *pages* over "data" (per-device HBM sizing), kv
                      heads over "model"
- batch state       — (B,)/(B, X) vectors over "data"
- page tables       — host-side and global; uploaded replicated (page
                      ids address the logical pool, GSPMD routes the
                      gather)

Everything routes through ``fit_spec`` so shapes that don't divide the
mesh (a B=1 prefill bucket on a 2-way data axis) degrade to replicated
instead of erroring — pjit *input* shardings require exact
divisibility. The plan is pure metadata: no jax computation happens
here, so control-plane behavior (and therefore emitted tokens) cannot
depend on it.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (fit_spec, kv_cache_spec,
                                        kv_pages_spec, lora_spec,
                                        param_shardings)
from repro.models.base import ModelConfig


class ShardPlan:
    """Shardings for every tensor class the serving engine moves."""

    def __init__(self, mesh: Mesh, model_cfg: ModelConfig):
        self.mesh = mesh
        self.cfg = model_cfg
        self.data_size = mesh.shape["data"]
        self.model_size = mesh.shape["model"]

    # -------------------------------------------------------------- core
    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def fitted(self, shape: tuple, spec: P, *,
               warn_label: str | None = None) -> NamedSharding:
        return self.named(fit_spec(tuple(shape), spec, self.mesh,
                                   warn_label=warn_label))

    @property
    def replicated(self) -> NamedSharding:
        return self.named(P())

    def put(self, x, sharding: NamedSharding):
        """Commit a host/device value to this plan's placement."""
        return jax.device_put(x, sharding)

    # ----------------------------------------------------------- weights
    def params(self, params: dict) -> dict:
        """{path: NamedSharding} for the serving weights ("model" only —
        inference never FSDP-shards; warns once per tensor whose spec
        axis doesn't divide)."""
        return param_shardings(self.cfg, params, self.mesh, kind="decode")

    # -------------------------------------------------------- LoRA slots
    def lora_slots(self, slots: dict) -> dict:
        """Slot-arena shardings, same pytree as ``init_lora_slots``:
        {proj: (A_sharding, B_sharding)} over (L, slots, din|r, r|dout)."""
        out = {}
        for proj, (a, b) in slots.items():
            sh_a = self.fitted(a.shape, lora_spec(proj, "a", self.mesh))
            sh_b = self.fitted(b.shape, lora_spec(proj, "b", self.mesh),
                               warn_label=f"lora/{proj}/b")
            out[proj] = (sh_a, sh_b)
        return out

    def adapter_weights(self, weights: dict) -> dict:
        """Shardings for one *host* adapter's weights (L, din, r) /
        (L, r, dout) so ``AdapterCatalog`` uploads straight into the
        sharded slot layout — each device receives only its B-column
        slice, never the full tensor."""
        out = {}
        for proj, (a, b) in weights.items():
            spec_a = lora_spec(proj, "a", self.mesh)
            spec_b = lora_spec(proj, "b", self.mesh)
            # Slot specs are (L, slots, din|r, ...); per-adapter weights
            # drop the slot axis.
            out[proj] = (
                self.fitted(a.shape, P(*([*spec_a][:1] + [*spec_a][2:]))),
                self.fitted(b.shape, P(*([*spec_b][:1] + [*spec_b][2:]))),
            )
        return out

    # ---------------------------------------------------------------- KV
    def kv_dense(self, shape: tuple) -> NamedSharding:
        """(L, B, Smax, Kh, Dh) dense cache."""
        return self.named(kv_cache_spec(self.mesh, tuple(shape)))

    def kv_pages(self, shape: tuple) -> NamedSharding:
        """(L, n_pages, page, Kh, Dh) paged pool."""
        return self.named(kv_pages_spec(self.mesh, tuple(shape)))

    # ------------------------------------------------------- batch state
    def batch(self, shape: tuple) -> NamedSharding:
        """Per-request state: leading dim over "data", rest replicated —
        (B,) cache_len/adapter_slot/seeds, (B, 1) tokens, (B, P) page
        tables' device mirror, (B, n_stop) stop sets, (K, B) horizon
        outputs use :meth:`horizon`."""
        spec = P("data", *([None] * (len(shape) - 1)))
        return self.fitted(shape, spec)

    def horizon(self, shape: tuple) -> NamedSharding:
        """(K, B) per-horizon-step outputs: batch dim is second."""
        return self.fitted(shape, P(None, "data"))

    def logits(self, shape: tuple) -> NamedSharding:
        """(B, V) logits: batch rows over "data", vocab *unsharded* —
        the host-side sampler (sort / cumsum / top-k over V) must see
        each row whole, in single-device FP order, for token parity.
        Row-sharding is safe: every sampling op is per-row."""
        return self.fitted(shape, P("data"))
