"""System assembly: build (pool, cache, scheduler, simulator) per design.

Names match the paper's evaluation:

- ``slora``              FIFO, no adapter cache (drop-on-idle), queued prefetch
- ``userve-sjf``         SJF + aging, no adapter cache
- ``chameleon``          full design (cache w/ cost-aware eviction + MLQ)
- ``chameleon-nocache``  scheduler only (ChameleonNoCache in Fig. 10/13)
- ``chameleon-nosched``  cache only, FIFO   (ChameleonNoSched in Fig. 10)
- ``chameleon-lru``      full sched + LRU cache          (Fig. 14)
- ``chameleon-fairshare``full sched + equal-weight cache (Fig. 14)
- ``chameleon-prefetch`` full design + histogram prefetcher (Fig. 15)
- ``chameleon-outputonly`` WRS = predicted output only   (Fig. 16)
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core import (AdapterCache, ChameleonScheduler, CostAwareEviction,
                        FairShareEviction, FIFOScheduler, LRUEviction,
                        MemoryPool, NoisyOraclePredictor, SJFScheduler,
                        build_adapter_pool, kv_token_bytes)
from repro.core.wrs import OutputOnlyCalculator

from .cost_model import CostModel, HW_PRESETS, MODEL_PRESETS
from .simulator import NodeSimulator, SimConfig

SYSTEM_NAMES = ("slora", "userve-sjf", "chameleon", "chameleon-nocache",
                "chameleon-nosched", "chameleon-lru", "chameleon-fairshare",
                "chameleon-prefetch", "chameleon-outputonly")


@dataclass
class NodeConfig:
    hw: str = "a40"
    model: str = "llama-7b"
    n_adapters: int = 100
    predictor_accuracy: float = 0.8
    slo_ttft_s: float = 5.0            # refined by benchmarks via lowload
    max_batch_requests: int = 256
    t_refresh: float = 20.0
    workspace_frac: float = 0.10       # HBM held back for activations etc.
    seed: int = 0
    sim: SimConfig = field(default_factory=SimConfig)


def build_node(system: str, cfg: NodeConfig):
    """Returns (simulator, adapters_catalog, cost_model)."""
    if system not in SYSTEM_NAMES:
        raise ValueError(f"unknown system {system!r}; one of {SYSTEM_NAMES}")
    hw = HW_PRESETS[cfg.hw]
    model = MODEL_PRESETS[cfg.model]
    cost = CostModel(hw=hw, model=model)

    tok_bytes = model.kv_bytes_per_token
    hbm_free = (hw.hbm_gb * 1e9) * (1 - cfg.workspace_frac) \
        - model.param_bytes
    if hbm_free <= 0:
        raise ValueError(f"{model.name} does not fit {hw.name}")
    capacity_tokens = int(hbm_free // tok_bytes)
    pool = MemoryPool(capacity_tokens=capacity_tokens)

    adapters = {a.adapter_id: a for a in build_adapter_pool(
        cfg.n_adapters, model.d_model, model.n_layers, tok_bytes,
        n_proj=model.n_proj_adapted, dtype_bytes=model.dtype_bytes)}

    cache_enabled = system not in ("slora", "userve-sjf",
                                   "chameleon-nocache")
    policy = CostAwareEviction()
    if system == "chameleon-lru":
        policy = LRUEviction()
    elif system == "chameleon-fairshare":
        policy = FairShareEviction()
    cache = AdapterCache(pool, adapters, policy=policy,
                         enabled=cache_enabled)

    pred = NoisyOraclePredictor(accuracy=cfg.predictor_accuracy,
                                seed=cfg.seed)

    if system in ("slora", "chameleon-nosched"):
        sched = FIFOScheduler(pool, cache, adapters, pred,
                              max_batch_requests=cfg.max_batch_requests)
    elif system == "userve-sjf":
        sched = SJFScheduler(pool, cache, adapters, pred,
                             max_batch_requests=cfg.max_batch_requests)
    else:
        wrs_calc = (OutputOnlyCalculator()
                    if system == "chameleon-outputonly" else None)
        sched = ChameleonScheduler(
            pool, cache, adapters, pred, wrs_calc=wrs_calc,
            slo=cfg.slo_ttft_s, t_refresh=cfg.t_refresh,
            max_batch_requests=cfg.max_batch_requests, seed=cfg.seed)

    sim_cfg = SimConfig(**cfg.sim.__dict__)
    if system == "chameleon-prefetch":
        sim_cfg.histogram_prefetch = True
    if system in ("slora", "userve-sjf"):
        # Paper Fig. 1: conventional systems load missing adapters before
        # launching the batch -> the engine stalls on the load.
        sim_cfg.sync_adapter_load = True
    sim = NodeSimulator(cost, pool, cache, sched, adapters, sim_cfg)
    return sim, adapters, cost


# System -> (scheduler class, adapter cache enabled) for the *real*
# engine data plane. The subset of SYSTEM_NAMES whose behavioural
# difference lives in the control plane the engine actually runs
# (cost-model-only variants like -prefetch stay simulator-only).
ENGINE_SYSTEMS = {
    "chameleon": (ChameleonScheduler, True),
    "chameleon-nocache": (ChameleonScheduler, False),
    "chameleon-nosched": (FIFOScheduler, True),
    "slora": (FIFOScheduler, False),
    "userve-sjf": (SJFScheduler, False),
}


def build_engine(system: str, cfg, params, ecfg=None, catalog=None,
                 clock=None):
    """Assemble one real-engine replica for ``system``.

    Mirrors ``build_node`` for the JAX data plane: same policy matrix,
    but the returned object runs jit'd prefill/decode on real tokens.
    ``catalog`` (shared AdapterCatalog) and ``clock`` let a cluster
    deduplicate host adapter weights and share a timebase across
    replicas.
    """
    from .engine import ChameleonEngine
    if system not in ENGINE_SYSTEMS:
        raise ValueError(f"unknown engine system {system!r}; "
                         f"one of {tuple(ENGINE_SYSTEMS)}")
    sched_cls, cache_enabled = ENGINE_SYSTEMS[system]
    return ChameleonEngine(cfg, params, ecfg, scheduler_cls=sched_cls,
                           cache_enabled=cache_enabled, catalog=catalog,
                           clock=clock)


# ------------------------------------------------------------------
# The single serving factory (DESIGN §3): one system matrix, three
# execution tiers, one ServingSystem surface.
# ------------------------------------------------------------------
TIERS = ("sim", "engine", "cluster", "sim-cluster", "disagg")


def _default_model():
    """Reduced Llama-style model for the real-engine tiers (the same
    default the examples and tests use)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import api

    cfg = get_config("chameleon-llama-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def build_system(system: str = "chameleon", tier: str = "engine", *,
                 node: NodeConfig | None = None,
                 model_cfg=None, params=None, ecfg=None,
                 n_nodes: int = 2, policy: str = "adapter_affinity",
                 seed: int = 0, mesh_shape: tuple | None = None,
                 gateway=None):
    """Build a ``ServingSystem`` (see ``serving.handles``): one factory
    over the full system × tier matrix.

    tier="sim"          one DES node (``NodeSimulator``): paper-scale
                        traffic in seconds of CPU time;
    tier="engine"       one real JAX engine (``ChameleonEngine``);
    tier="cluster"      N real engines behind a router
                        (``EngineCluster``, shared AdapterCatalog);
    tier="sim-cluster"  N DES nodes behind the same router
                        (``Cluster``);
    tier="disagg"       N real engines split into prefill and decode
                        roles with a paged-KV handoff between them
                        (``DisaggCluster``; n_nodes splits
                        floor(n/2) prefill / rest decode).

    Every tier serves the same surface: ``submit() -> RequestHandle``,
    ``step``, ``busy``, ``drain``, ``cancel``, ``queue_pressure``,
    ``stats``, ``metrics``. The engine tiers build a reduced model
    when ``model_cfg``/``params`` are not supplied.

    ``mesh_shape`` ((data, model), real-engine tiers only): shard each
    engine's data plane over a device mesh — resolved through
    ``launch.mesh.make_serving_mesh``, so device availability is
    validated before any buffer lands. At tier="cluster" every replica
    gets the same shape; the cluster validates replicas × mesh size
    against the device count.

    ``gateway``: wrap the built tier in the multi-tenant admission
    layer (``serving.gateway.Gateway``) — pass ``True`` for the default
    policy or a ``GatewayConfig``. The return value is then the Gateway
    (itself a ``ServingSystem``); on the sim tier it inherits the
    node's cost model so SLO wait estimates start calibrated.
    """
    if tier not in TIERS:
        raise ValueError(f"unknown tier {tier!r}; one of {TIERS}")
    if mesh_shape is not None and tier not in ("engine", "cluster",
                                               "disagg"):
        raise ValueError(
            f"mesh_shape applies to the real-engine tiers, not {tier!r}")

    def _gated(sys_, cost=None):
        if not gateway:
            return sys_
        from .gateway import Gateway, GatewayConfig
        gcfg = gateway if isinstance(gateway, GatewayConfig) else None
        return Gateway(sys_, gcfg, cost_model=cost)

    if tier == "sim":
        sim, _, cost = build_node(system, node or NodeConfig(seed=seed))
        return _gated(sim, cost)
    if tier == "sim-cluster":
        from .cluster import Cluster, ClusterConfig
        cl = Cluster(ClusterConfig(
            n_nodes=n_nodes, system=system, policy=policy,
            node=node or NodeConfig(seed=seed)))
        return _gated(cl, cl.nodes[0].cost if cl.nodes else None)
    if model_cfg is None or params is None:
        model_cfg, params = _default_model()
    if mesh_shape is not None:
        import dataclasses

        from .engine import EngineConfig
        ecfg = dataclasses.replace(ecfg or EngineConfig(),
                                   mesh_shape=tuple(mesh_shape))
    if tier == "engine":
        return _gated(build_engine(system, model_cfg, params, ecfg))
    if tier == "disagg":
        from .disagg import DisaggCluster, DisaggConfig
        n_prefill = max(1, n_nodes // 2)
        return _gated(DisaggCluster(model_cfg, params, ecfg, DisaggConfig(
            n_prefill=n_prefill, n_decode=max(1, n_nodes - n_prefill),
            system=system, seed=seed)))
    from .cluster import EngineCluster, EngineClusterConfig
    return _gated(EngineCluster(model_cfg, params, ecfg, EngineClusterConfig(
        n_engines=n_nodes, system=system, policy=policy, seed=seed)))
