"""Discrete-event simulator of one serving node (DESIGN §2, tier 3).

Replays a trace through the *real* control plane — the same
ChameleonScheduler / AdapterCache / MemoryPool objects the JAX engine
uses — while charging time from the calibrated CostModel instead of
running the model. This is how the paper's production-scale figures
(Llama-7B, 100 adapters, 6–13 RPS, minutes of wall time) are reproduced
on a CPU-only container.

Fidelity notes:
- iteration-level (continuous) batching: one decode iteration advances
  every running request by one token; finished requests leave, new ones
  are admitted every iteration boundary (Orca/S-LoRA style);
- adapter loads serialise on a FIFO host→device link (PCIe contention,
  paper Fig. 4); prefill of a request cannot start before its load
  completes; prefetches occupy the same link;
- squash path: bypassed requests that exceed their predicted length are
  squashed and re-queued (paper §4.2);
- reservation growth: requests that exceed their predicted output grow
  their pool hold token-by-token, shrinking the cache on demand.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core import (AdapterCache, ChameleonScheduler, MemoryPool,
                        PoolError, QueuedRequestPrefetcher, Request,
                        RequestState)
from repro.core.prefetcher import HistogramPrefetcher

from .cost_model import CostModel
from .metrics import RequestRecord, RunMetrics
from .trace import Trace


class LinkChannel:
    """FIFO host→device link: transfers serialise (PCIe contention)."""

    def __init__(self, bytes_per_s: float, latency_s: float = 150e-6):
        self.bps = bytes_per_s
        self.latency = latency_s
        self.busy_until = 0.0
        self.bytes_total = 0
        self.busy_time = 0.0

    def transfer(self, nbytes: int, now: float) -> float:
        start = max(now, self.busy_until)
        dur = self.latency + nbytes / self.bps
        self.busy_until = start + dur
        self.bytes_total += nbytes
        self.busy_time += dur
        return self.busy_until


@dataclass
class SimConfig:
    max_iters: int = 2_000_000
    prefill_chunk_tokens: int = 2048     # max tokens per prefill iteration
    drain: bool = True                   # run queue dry after last arrival
    histogram_prefetch: bool = False
    queued_prefetch: bool = True
    headroom_tokens: int = 0             # engine slack kept free in the pool
    # S-LoRA semantics (paper Fig. 1): missing adapters are loaded before
    # the batch is sent to the GPU — the *engine* stalls on the load.
    # Chameleon's cache manager is invoked at scheduling time, so loads
    # overlap with the current iteration and only the affected request
    # waits (async). Baselines set True.
    sync_adapter_load: bool = False


class NodeSimulator:
    def __init__(self, cost_model: CostModel, pool: MemoryPool,
                 cache: AdapterCache, scheduler, adapters: dict,
                 config: SimConfig | None = None):
        self.cost = cost_model
        self.pool = pool
        self.cache = cache
        self.sched = scheduler
        self.adapters = adapters
        self.cfg = config or SimConfig()
        self.link = LinkChannel(cost_model.hw.link_bps,
                                cost_model.link_latency_us * 1e-6)
        self.now = 0.0
        self._adapter_ready: dict[int, float] = {}
        # Wire the cache's load hook to the link channel.
        cache.on_load = self._on_adapter_load
        self.q_prefetch = (QueuedRequestPrefetcher(cache)
                           if self.cfg.queued_prefetch else None)
        self.h_prefetch = (HistogramPrefetcher(cache)
                           if self.cfg.histogram_prefetch else None)
        self._tbt: dict[int, list[float]] = {}
        self._last_tok: dict[int, float] = {}
        self._isolated_cache: dict[tuple, float] = {}

    # ------------------------------------------------------------------
    def _on_adapter_load(self, info) -> None:
        self._adapter_ready[info.adapter_id] = self.link.transfer(
            info.size_bytes, self.now)

    def _adapter_ready_time(self, adapter_id: int) -> float:
        return self._adapter_ready.get(adapter_id, 0.0)

    def _rank(self, adapter_id: int) -> int:
        return self.adapters[adapter_id].rank

    def _isolated(self, req: Request) -> float:
        key = (req.input_len, req.output_len, self._rank(req.adapter_id))
        if key not in self._isolated_cache:
            self._isolated_cache[key] = self.cost.isolated_time(
                req.input_len, req.output_len, key[2])
        return self._isolated_cache[key]

    # ------------------------------------------------------------------
    def run(self, trace: Trace) -> RunMetrics:
        arrivals = sorted(trace.requests, key=lambda r: r.arrival_time)
        n_arr = len(arrivals)
        ai = 0
        waiting_load: list[Request] = []     # admitted, adapter in flight
        prefill_pending: list[Request] = []  # admitted, ready to prefill
        decoding: list[Request] = []
        metrics = RunMetrics(n_submitted=n_arr)

        iters = 0
        while iters < self.cfg.max_iters:
            iters += 1
            # 1. Ingest arrivals up to `now`.
            while ai < n_arr and arrivals[ai].arrival_time <= self.now:
                req = arrivals[ai]
                self.sched.submit(req, self.now)
                if self.h_prefetch:
                    self.h_prefetch.observe_arrival(req.adapter_id,
                                                    self.now)
                ai += 1

            running = decoding + prefill_pending + waiting_load
            # 2. Admission (scheduler owns the policy).
            admitted = self.sched.schedule(self.now, running)
            for req in admitted:
                ready = self._adapter_ready_time(req.adapter_id)
                if ready > self.now and not self.cfg.sync_adapter_load:
                    waiting_load.append(req)
                else:
                    prefill_pending.append(req)

            # 3. Prefetch for queued requests (async, consumes link bw).
            if self.q_prefetch and hasattr(self.sched,
                                           "queued_requests_in_order"):
                self.q_prefetch.run(self.sched.queued_requests_in_order(),
                                    self.now)
            if self.h_prefetch:
                # §4.1 second tier: a predictive prefetch must not
                # evict an adapter a queued request is about to need.
                self.h_prefetch.run(
                    self.now,
                    queued_protect=self.sched.queued_adapter_ids())

            # 4. Promote loads that completed.
            still = []
            for req in waiting_load:
                ready = self._adapter_ready_time(req.adapter_id)
                if ready <= self.now:
                    req.adapter_load_wait = ready - req.arrival_time
                    prefill_pending.append(req)
                else:
                    still.append(req)
            waiting_load = still

            stepped = False
            # 5. One prefill iteration (chunked).
            if prefill_pending:
                chunk, tok = [], 0
                for req in list(prefill_pending):
                    if chunk and tok + req.input_len > \
                            self.cfg.prefill_chunk_tokens:
                        break
                    chunk.append(req)
                    tok += req.input_len
                if self.cfg.sync_adapter_load:
                    # Engine blocks until every chunk member's adapter
                    # finished loading (S-LoRA batch-launch semantics).
                    ready = max(self._adapter_ready_time(r.adapter_id)
                                for r in chunk)
                    if ready > self.now:
                        self.now = ready
                t = self.cost.prefill_time(
                    [r.input_len for r in chunk],
                    [self._rank(r.adapter_id) for r in chunk])
                self.now += t
                for req in chunk:
                    prefill_pending.remove(req)
                    req.first_token_time = self.now
                    req.generated = 1      # prefill emits the first token
                    self._last_tok[req.req_id] = self.now
                    self._tbt[req.req_id] = []
                    if req.done:
                        self._finish(req, metrics)
                    else:
                        decoding.append(req)
                stepped = True

            # 6. One decode iteration for the running batch.
            if decoding:
                kv_tokens = sum(r.input_len + r.generated for r in decoding)
                t = self.cost.decode_time(
                    len(decoding), kv_tokens,
                    [self._rank(r.adapter_id) for r in decoding])
                self.now += t
                finished, squashed = [], []
                for req in decoding:
                    req.generated += 1
                    self._tbt[req.req_id].append(
                        self.now - self._last_tok[req.req_id])
                    self._last_tok[req.req_id] = self.now
                    if req.done:
                        finished.append(req)
                        continue
                    if req.bypassed and req.exceeded_prediction():
                        squashed.append(req)
                        continue
                    if req.generated > req.predicted_output:
                        self._grow_reservation(req, squashed)
                for req in finished:
                    decoding.remove(req)
                    self._finish(req, metrics)
                for req in squashed:
                    if req in decoding:
                        decoding.remove(req)
                    self._squash(req)
                stepped = True

            # 7. Advance the clock when idle.
            if not stepped:
                if ai < n_arr:
                    self.now = max(self.now, arrivals[ai].arrival_time)
                    continue
                if not (waiting_load or prefill_pending or decoding
                        or self.sched.pending_count()):
                    break
                if waiting_load:
                    self.now = max(self.now, min(
                        self._adapter_ready_time(r.adapter_id)
                        for r in waiting_load))
                    continue
                # Queue non-empty but nothing admitted and nothing runs:
                # deadlocked admission (should not happen) — bail out.
                if self.sched.pending_count():
                    self._force_drain_step()
                    if self._deadlock_detect():
                        break
            if not self.cfg.drain and ai >= n_arr:
                break

        metrics.horizon = self.now
        metrics.cache_stats = {
            "hit_rate": round(self.cache.stats.hit_rate, 4),
            "hits": self.cache.stats.hits,
            "misses": self.cache.stats.misses,
            "evictions": self.cache.stats.evictions,
            "gb_loaded": round(self.cache.stats.bytes_loaded / 1e9, 3),
            "link_busy_frac": round(
                self.link.busy_time / max(self.now, 1e-9), 4),
        }
        if isinstance(self.sched, ChameleonScheduler):
            metrics.sched_stats = {
                "bypassed": self.sched.n_bypassed,
                "squashed": self.sched.n_squashed,
                "queues": len(self.sched.queues),
            }
        return metrics

    # ------------------------------------------------------------------
    _drain_attempts: int = 0

    def _deadlock_detect(self) -> bool:
        self._drain_attempts += 1
        return self._drain_attempts > 1000

    def _force_drain_step(self) -> None:
        """Nothing admitted while idle: nudge time forward so timers
        (t_refresh, aging) can unblock admission."""
        self.now += 0.01

    def _grow_reservation(self, req: Request, squashed: list) -> None:
        """Mispredicted-long request: extend its KV hold by one token."""
        try:
            self.pool.grow_request(req.req_id, 1)
            req.reserved_tokens += 1
            return
        except PoolError:
            pass
        if self.cache.shrink_for_requests(1, self.now,
                                          self.sched.queued_adapter_ids()):
            self.pool.grow_request(req.req_id, 1)
            req.reserved_tokens += 1
            return
        # Last resort: squash *this* over-budget request (it is the one
        # whose prediction was wrong — same rule the paper applies to
        # bypassers). Extremely rare with sane pool sizes.
        squashed.append(req)

    def _squash(self, req: Request) -> None:
        if hasattr(self.sched, "on_squash"):
            self.sched.on_squash(req, self.now)
        self._tbt.pop(req.req_id, None)
        self._last_tok.pop(req.req_id, None)

    def _finish(self, req: Request, metrics: RunMetrics) -> None:
        req.state = RequestState.FINISHED
        req.finish_time = self.now
        self.sched.on_finish(req, self.now)
        tbts = self._tbt.pop(req.req_id, [])
        self._last_tok.pop(req.req_id, None)
        iso = self._isolated(req)
        metrics.records.append(RequestRecord(
            req_id=req.req_id, adapter_id=req.adapter_id,
            rank=self._rank(req.adapter_id),
            input_len=req.input_len, output_len=req.output_len,
            arrival=req.arrival_time,
            ttft=req.ttft() or 0.0, e2e=req.e2e() or 0.0,
            tbt_mean=float(np.mean(tbts)) if tbts else 0.0,
            tbt_p99=float(np.percentile(tbts, 99)) if tbts else 0.0,
            slowdown=(req.e2e() or 0.0) / max(iso, 1e-9),
            squashes=req.squash_count, bypassed=req.bypassed))
