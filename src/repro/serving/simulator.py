"""Discrete-event simulator of one serving node (DESIGN §2, tier 3).

Replays a trace through the *real* control plane — the same
ChameleonScheduler / AdapterCache / MemoryPool objects the JAX engine
uses — while charging time from the calibrated CostModel instead of
running the model. This is how the paper's production-scale figures
(Llama-7B, 100 adapters, 6–13 RPS, minutes of wall time) are reproduced
on a CPU-only container.

The simulator implements the same ``ServingSystem`` surface as the real
engine (DESIGN §3): ``submit`` returns a ``RequestHandle``, ``step``
advances virtual time by one iteration, ``busy``/``drain`` round it
out, and cancellation/deadlines are enforced at the same points the
engine enforces them. Tokens have no content at this tier, so the
stream carries deterministic position-keyed placeholder ids — the
contract (a handle's stream equals the node's output record, positions
never re-stream after a squash) is identical across tiers.
``run(trace)`` remains the one-shot replay wrapper the benchmarks use.

Fidelity notes:
- iteration-level (continuous) batching: one decode iteration advances
  every running request by one token; finished requests leave, new ones
  are admitted every iteration boundary (Orca/S-LoRA style);
- adapter loads serialise on a FIFO host→device link (PCIe contention,
  paper Fig. 4); prefill of a request cannot start before its load
  completes; prefetches occupy the same link;
- squash path: bypassed requests that exceed their predicted length are
  squashed and re-queued (paper §4.2), keeping their streamed prefix;
- reservation growth: requests that exceed their predicted output grow
  their pool hold token-by-token, shrinking the cache on demand.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core import (AdapterCache, ChameleonScheduler, MemoryPool,
                        PoolError, QueuedRequestPrefetcher, Request,
                        RequestState, SamplingParams)
from repro.core.prefetcher import HistogramPrefetcher

from .cost_model import CostModel
from .handles import DRAIN_MAX_STEPS, RequestHandle, prepare_request
from .metrics import RequestRecord, RunMetrics
from .trace import Trace


class LinkChannel:
    """FIFO host→device link: transfers serialise (PCIe contention)."""

    def __init__(self, bytes_per_s: float, latency_s: float = 150e-6):
        self.bps = bytes_per_s
        self.latency = latency_s
        self.busy_until = 0.0
        self.bytes_total = 0
        self.busy_time = 0.0

    def transfer(self, nbytes: int, now: float) -> float:
        start = max(now, self.busy_until)
        dur = self.latency + nbytes / self.bps
        self.busy_until = start + dur
        self.bytes_total += nbytes
        self.busy_time += dur
        return self.busy_until

@dataclass
class SimConfig:
    max_iters: int = 2_000_000
    prefill_chunk_tokens: int = 2048     # max tokens per prefill iteration
    drain: bool = True                   # run queue dry after last arrival
    histogram_prefetch: bool = False
    queued_prefetch: bool = True
    headroom_tokens: int = 0             # engine slack kept free in the pool
    # S-LoRA semantics (paper Fig. 1): missing adapters are loaded before
    # the batch is sent to the GPU — the *engine* stalls on the load.
    # Chameleon's cache manager is invoked at scheduling time, so loads
    # overlap with the current iteration and only the affected request
    # waits (async). Baselines set True.
    sync_adapter_load: bool = False


# Deterministic placeholder token for (request, position): the DES has
# no logits, but the streaming contract still needs concrete ids whose
# regeneration after a squash is position-stable.
def _synth_token(req: Request, pos: int, vocab: int = 50257) -> int:
    return (req.req_id * 2654435761 + pos * 40503) % vocab


class NodeSimulator:
    def __init__(self, cost_model: CostModel, pool: MemoryPool,
                 cache: AdapterCache, scheduler, adapters: dict,
                 config: SimConfig | None = None):
        self.cost = cost_model
        self.pool = pool
        self.cache = cache
        self.sched = scheduler
        self.adapters = adapters
        self.cfg = config or SimConfig()
        self.link = LinkChannel(cost_model.hw.link_bps,
                                cost_model.link_latency_us * 1e-6)
        self.now = 0.0
        self._adapter_ready: dict[int, float] = {}
        # Wire the cache's load hook to the link channel.
        cache.on_load = self._on_adapter_load
        self.q_prefetch = (QueuedRequestPrefetcher(cache)
                           if self.cfg.queued_prefetch else None)
        self.h_prefetch = (HistogramPrefetcher(cache)
                           if self.cfg.histogram_prefetch else None)
        self._tbt: dict[int, list[float]] = {}
        self._last_tok: dict[int, float] = {}
        self._isolated_cache: dict[tuple, float] = {}
        # ServingSystem state (steppable DES).
        self._pending: list[tuple[float, int, Request]] = []  # arrival heap
        self._seq = itertools.count()
        self._waiting_load: list[Request] = []   # admitted, adapter in flight
        self._prefill_pending: list[Request] = []
        self._decoding: list[Request] = []
        self._metrics = RunMetrics()
        self.handles: dict[int, RequestHandle] = {}
        self.outputs: dict[int, list[int]] = {}
        self.n_cancelled = 0
        self.n_expired = 0
        self._drain_attempts = 0
        # Lifecycle fast path: the per-step deadline/cancel sweeps are
        # skipped entirely unless some request armed them (a 2M-iter
        # DES replay must not pay an O(queue) scan per iteration).
        self._deadlines_armed = False
        self._cancel_races: list[Request] = []
        # Interactive serving keeps per-request handles/output records
        # for the caller; ``run(trace)`` replays flip this off so a
        # paper-scale replay does not retain every token of every
        # completed request for the run's lifetime.
        self._retain_records = True

    # ------------------------------------------------------------------
    def _on_adapter_load(self, info) -> None:
        self._adapter_ready[info.adapter_id] = self.link.transfer(
            info.size_bytes, self.now)

    def _adapter_ready_time(self, adapter_id: int) -> float:
        return self._adapter_ready.get(adapter_id, 0.0)

    def _rank(self, adapter_id: int) -> int:
        return self.adapters[adapter_id].rank

    def _isolated(self, req: Request) -> float:
        key = (req.input_len, req.output_len, self._rank(req.adapter_id))
        if key not in self._isolated_cache:
            self._isolated_cache[key] = self.cost.isolated_time(
                req.input_len, req.output_len, key[2])
        return self._isolated_cache[key]

    # ------------------------------------------------- serving surface
    def submit(self, req: Request, *,
               sampling: Optional[SamplingParams] = None,
               on_token=None, ttl: Optional[float] = None,
               ) -> RequestHandle:
        """Non-blocking enqueue; the request enters the scheduler once
        virtual time reaches its ``arrival_time``."""
        handle = prepare_request(req, self, self.now, sampling, on_token,
                                 ttl)
        self.handles[req.req_id] = handle
        if req.deadline is not None:
            self._deadlines_armed = True
        heapq.heappush(self._pending,
                       (req.arrival_time, next(self._seq), req))
        self._metrics.n_submitted += 1
        return handle

    def busy(self) -> bool:
        return bool(self._pending or self._waiting_load
                    or self._prefill_pending or self._decoding
                    or self.sched.pending_count())

    def queue_pressure(self) -> float:
        """Routing signal: scheduler backlog plus in-flight requests
        (due arrivals still in the heap count — a router must see load
        the instant it is submitted, not an iteration later)."""
        due = sum(1 for t, _, _ in self._pending if t <= self.now)
        return self.sched.queue_pressure() + float(
            due + len(self._decoding) + len(self._prefill_pending)
            + len(self._waiting_load))

    def cancel(self, handle) -> bool:
        """Cancel wherever the request currently is: the arrival heap
        and the wait queues resolve immediately (releasing the adapter
        pin); *admitted* requests (waiting on a load, pending prefill,
        decoding) are deferred to the next step-top sweep — resolving
        them here would mutate the very lists a cancel issued from an
        ``on_token`` callback is being iterated inside of."""
        req = handle.req if isinstance(handle, RequestHandle) else handle
        if req.terminal:
            return False
        for i, (_, _, r) in enumerate(self._pending):
            if r is req:
                del self._pending[i]
                heapq.heapify(self._pending)
                self._finalize_unplaced(req, RequestState.CANCELLED)
                return True
        if self.sched.cancel(req, self.now):
            self._finalize_unplaced(req, RequestState.CANCELLED)
            return True
        req.cancel_requested = True
        self._cancel_races.append(req)
        return True

    def _finalize_unplaced(self, req: Request,
                           state: RequestState) -> None:
        req.state = state
        req.finish_time = self.now
        if state is RequestState.CANCELLED:
            self.n_cancelled += 1
        else:
            self.n_expired += 1
        self._drop_terminal_records(req)

    def _release_running(self, req: Request, state: RequestState) -> None:
        """Terminal transition for an admitted request: ``on_finish``
        returns its quota charges, pool reservation and cache pin."""
        self.sched.on_finish(req, self.now)
        req.preserved_tbts = self._tbt.pop(req.req_id, [])
        self._last_tok.pop(req.req_id, None)
        self._finalize_unplaced(req, state)

    def _drop_terminal_records(self, req: Request) -> None:
        """Replay mode: a terminal request's handle and output record
        have no consumer — free them so a 2M-iteration replay holds
        only in-flight state (interactive submits keep both)."""
        if not self._retain_records:
            self.handles.pop(req.req_id, None)
            self.outputs.pop(req.req_id, None)

    def _sweep_lifecycle(self) -> None:
        if self._deadlines_armed:
            for req in self.sched.reap_expired(self.now):
                self._finalize_unplaced(req, RequestState.EXPIRED)
            for group in (self._waiting_load, self._prefill_pending,
                          self._decoding):
                doomed = [r for r in group
                          if r.deadline is not None
                          and r.deadline <= self.now]
                for r in doomed:
                    group.remove(r)
                    self._release_running(r, RequestState.EXPIRED)
        if self._cancel_races:
            # Deferred cancels settle here, at the step top, where no
            # list is mid-iteration: admitted requests release their
            # holds; anything that moved back to a queue (squash) or
            # is still in transition retries via cancel().
            races, self._cancel_races = self._cancel_races, []
            for req in races:
                if req.terminal:
                    continue
                for group in (self._waiting_load,
                              self._prefill_pending, self._decoding):
                    if req in group:
                        group.remove(req)
                        self._release_running(req,
                                              RequestState.CANCELLED)
                        break
                else:
                    self.cancel(req)

    def _record_token(self, req: Request, pos: int) -> None:
        out = self.outputs.setdefault(req.req_id, [])
        tok = _synth_token(req, pos)
        if pos < len(out):
            out[pos] = tok     # squash re-execution: never re-streams
            return
        out.append(tok)
        handle = self.handles.get(req.req_id)
        if handle is not None:
            handle._push(pos, tok)

    # ---------------------------------------------------------- stepping
    def step(self) -> None:
        """One DES iteration: ingest arrivals, enforce lifecycle, admit,
        prefetch, one prefill chunk, one decode iteration; advance
        virtual time to the next event when idle."""
        # 1. Ingest arrivals up to `now`.
        while self._pending and self._pending[0][0] <= self.now:
            _, _, req = heapq.heappop(self._pending)
            self.sched.submit(req, self.now)
            if self.h_prefetch:
                self.h_prefetch.observe_arrival(req.adapter_id, self.now)
        self._sweep_lifecycle()

        running = self._decoding + self._prefill_pending \
            + self._waiting_load
        # 2. Admission (scheduler owns the policy).
        admitted = self.sched.schedule(self.now, running)
        for req in admitted:
            ready = self._adapter_ready_time(req.adapter_id)
            if ready > self.now and not self.cfg.sync_adapter_load:
                req.load_wait_start = self.now   # stall begins here
                self._waiting_load.append(req)
            else:
                self._prefill_pending.append(req)

        # 3. Prefetch for queued requests (async, consumes link bw).
        if self.q_prefetch and hasattr(self.sched,
                                       "queued_requests_in_order"):
            self.q_prefetch.run(self.sched.queued_requests_in_order(),
                                self.now)
        if self.h_prefetch:
            # §4.1 second tier: a predictive prefetch must not
            # evict an adapter a queued request is about to need.
            self.h_prefetch.run(
                self.now,
                queued_protect=self.sched.queued_adapter_ids())

        # 4. Promote loads that completed. The metered load wait is
        # admission-stall -> load completion (mirrors the engine's
        # ``load_wait_start`` accounting); measuring from arrival would
        # double-count queue wait in the latency breakdown.
        still = []
        for req in self._waiting_load:
            ready = self._adapter_ready_time(req.adapter_id)
            if ready <= self.now:
                start = (req.load_wait_start
                         if req.load_wait_start is not None
                         else req.arrival_time)
                req.adapter_load_wait += max(0.0, ready - start)
                req.load_wait_start = None
                self._prefill_pending.append(req)
            else:
                still.append(req)
        self._waiting_load = still

        stepped = False
        # 5. One prefill iteration (chunked).
        if self._prefill_pending:
            chunk, tok = [], 0
            for req in list(self._prefill_pending):
                if chunk and tok + req.input_len > \
                        self.cfg.prefill_chunk_tokens:
                    break
                chunk.append(req)
                tok += req.input_len
            if self.cfg.sync_adapter_load:
                # Engine blocks until every chunk member's adapter
                # finished loading (S-LoRA batch-launch semantics).
                ready = max(self._adapter_ready_time(r.adapter_id)
                            for r in chunk)
                if ready > self.now:
                    self.now = ready
            t = self.cost.prefill_time(
                [r.input_len for r in chunk],
                [self._rank(r.adapter_id) for r in chunk])
            self.now += t
            for req in chunk:
                self._prefill_pending.remove(req)
                req.generated = 1      # prefill emits the first token
                if req.preserved_tokens:
                    # Squash survivor: streamed prefix + TBTs live on;
                    # the TBT of the first *new* token is measured from
                    # the last token the user actually saw.
                    self.outputs[req.req_id] = list(req.preserved_tokens)
                    self._tbt[req.req_id] = list(req.preserved_tbts)
                    self._last_tok[req.req_id] = (
                        req.last_stream_time if req.last_stream_time
                        is not None else self.now)
                else:
                    req.first_token_time = self.now
                    self.outputs[req.req_id] = []
                    self._tbt[req.req_id] = []
                    self._last_tok[req.req_id] = self.now
                self._record_token(req, 0)
                if req.done:
                    self._finish(req)
                else:
                    self._decoding.append(req)
            stepped = True

        # 6. One decode iteration for the running batch.
        if self._decoding:
            kv_tokens = sum(r.input_len + r.generated
                            for r in self._decoding)
            t = self.cost.decode_time(
                len(self._decoding), kv_tokens,
                [self._rank(r.adapter_id) for r in self._decoding])
            self.now += t
            finished, squashed = [], []
            for req in self._decoding:
                pos = req.generated
                req.generated += 1
                new = pos >= len(self.outputs.get(req.req_id, []))
                self._record_token(req, pos)
                if new:
                    self._tbt[req.req_id].append(
                        self.now - self._last_tok[req.req_id])
                    self._last_tok[req.req_id] = self.now
                if req.done:
                    finished.append(req)
                    continue
                if req.bypassed and req.exceeded_prediction():
                    squashed.append(req)
                    continue
                if req.generated > req.predicted_output:
                    self._grow_reservation(req, squashed)
            for req in finished:
                self._decoding.remove(req)
                self._finish(req)
            for req in squashed:
                if req in self._decoding:
                    self._decoding.remove(req)
                self._squash(req)
            stepped = True

        # 7. Advance the clock when idle.
        if not stepped:
            if self._pending:
                self.now = max(self.now, self._pending[0][0])
                return
            if not self.busy():
                return
            if self._waiting_load:
                self.now = max(self.now, min(
                    self._adapter_ready_time(r.adapter_id)
                    for r in self._waiting_load))
                return
            # Queue non-empty but nothing admitted and nothing runs:
            # nudge timers (t_refresh, aging) so admission can unblock.
            if self.sched.pending_count():
                self._force_drain_step()

    def drain(self, max_steps: int = DRAIN_MAX_STEPS) -> None:
        self._drain_attempts = 0
        for _ in range(max_steps):
            if not self.busy() or self._deadlocked():
                break
            self.step()

    # ------------------------------------------------------------------
    def run(self, trace: Trace) -> RunMetrics:
        """One-shot replay: submit the whole trace, run the DES dry
        (or to the last arrival with ``cfg.drain=False``), and return
        the metrics — the historical benchmark surface."""
        self._metrics = RunMetrics()
        self._drain_attempts = 0
        self._retain_records = False    # replay: nobody reads handles
        for req in trace.requests:
            self.submit(req)
        iters = 0
        while iters < self.cfg.max_iters and self.busy():
            if self._deadlocked():
                break
            self.step()
            iters += 1
            if not self.cfg.drain and not self._pending:
                break
        return self.metrics()

    # ------------------------------------------------------------------
    def _deadlocked(self) -> bool:
        return self._drain_attempts > 1000

    def _force_drain_step(self) -> None:
        """Nothing admitted while idle: nudge time forward so timers
        (t_refresh, aging) can unblock admission."""
        self._drain_attempts += 1
        self.now += 0.01

    def _grow_reservation(self, req: Request, squashed: list) -> None:
        """Mispredicted-long request: extend its KV hold by one token."""
        try:
            self.pool.grow_request(req.req_id, 1)
            req.reserved_tokens += 1
            return
        except PoolError:
            pass
        if self.cache.shrink_for_requests(1, self.now,
                                          self.sched.queued_adapter_ids()):
            self.pool.grow_request(req.req_id, 1)
            req.reserved_tokens += 1
            return
        # Last resort: squash *this* over-budget request (it is the one
        # whose prediction was wrong — same rule the paper applies to
        # bypassers). Extremely rare with sane pool sizes.
        squashed.append(req)

    def _squash(self, req: Request) -> None:
        # Keep the streamed prefix and its latency accounting across
        # the requeue (re-execution regenerates the same positions).
        req.stash_progress(self.outputs.pop(req.req_id, None),
                           self._tbt.pop(req.req_id, None),
                           self._last_tok.pop(req.req_id, None))
        if hasattr(self.sched, "on_squash"):
            self.sched.on_squash(req, self.now)

    def _finish(self, req: Request) -> None:
        if req.cancel_requested:
            # Cancel raced the final token: honour the cancel()
            # contract — terminate CANCELLED, no RequestRecord.
            self._release_running(req, RequestState.CANCELLED)
            return
        req.state = RequestState.FINISHED
        req.finish_time = self.now
        self.sched.on_finish(req, self.now)
        tbts = self._tbt.pop(req.req_id, [])
        req.preserved_tbts = tbts     # handle.result() reads these
        self._last_tok.pop(req.req_id, None)
        iso = self._isolated(req)
        self._metrics.records.append(RequestRecord(
            req_id=req.req_id, adapter_id=req.adapter_id,
            rank=self._rank(req.adapter_id),
            input_len=req.input_len, output_len=req.output_len,
            arrival=req.arrival_time,
            ttft=req.ttft() or 0.0, e2e=req.e2e() or 0.0,
            tbt_mean=float(np.mean(tbts)) if tbts else 0.0,
            tbt_p99=float(np.percentile(tbts, 99)) if tbts else 0.0,
            slowdown=(req.e2e() or 0.0) / max(iso, 1e-9),
            squashes=req.squash_count, bypassed=req.bypassed,
            queue_wait=req.queue_wait() or 0.0,
            load_wait=max(0.0, req.adapter_load_wait)))
        self._drop_terminal_records(req)

    # ---------------------------------------------------------- reporting
    def stats(self) -> dict:
        return {
            "completed": len(self._metrics.records),
            "cache": self.cache.stats.__dict__.copy(),
            "bypassed": getattr(self.sched, "n_bypassed", 0),
            "squashed": getattr(self.sched, "n_squashed", 0),
            "cancelled": self.n_cancelled,
            "expired": self.n_expired,
            "pool": self.pool.snapshot(),
        }

    def metrics(self) -> RunMetrics:
        m = self._metrics
        m.horizon = self.now
        m.cache_stats = {
            "hit_rate": round(self.cache.stats.hit_rate, 4),
            "hits": self.cache.stats.hits,
            "misses": self.cache.stats.misses,
            "evictions": self.cache.stats.evictions,
            "gb_loaded": round(self.cache.stats.bytes_loaded / 1e9, 3),
            "link_busy_frac": round(
                self.link.busy_time / max(self.now, 1e-9), 4),
        }
        if isinstance(self.sched, ChameleonScheduler):
            m.sched_stats = {
                "bypassed": self.sched.n_bypassed,
                "squashed": self.sched.n_squashed,
                "queues": len(self.sched.queues),
            }
        return m
