"""Workload/trace synthesis (paper §5.1).

The paper replays the open-source Azure LLM inference trace [39]
("conversation" service) for input/output lengths, Poisson inter-arrival
times at a target RPS, and attaches adapters by a power-law over ranks
{8,16,32,64,128} with uniform choice within a rank.

The Azure conversation trace's published length statistics are
heavy-tailed; we synthesise lengths from the distributions reported in
Splitwise [39] (conversation: median input ≈ 1020, p90 ≈ 2.2k; median
output ≈ 129, long tail to 1k+), using log-normal bodies with Pareto
tails. Seeds make every experiment reproducible. A loader for the real
CSV (same schema) is included for environments where the trace file is
available: ``load_azure_csv``.
"""
from __future__ import annotations

import csv
import dataclasses
import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.lora import AdapterInfo, assign_adapters
from repro.core.request import Request


@dataclass
class TraceConfig:
    rps: float = 8.0
    duration_s: float = 120.0
    n_adapters: int = 100
    seed: int = 0
    adapter_alpha: float = 1.0         # power-law exponent over ranks
    # Azure-conversation-calibrated length model [39]:
    input_lognorm_mu: float = 5.1      # exp(5.1) ≈ 164 median body
    input_lognorm_sigma: float = 0.65
    input_max: int = 4096
    output_lognorm_mu: float = 4.2     # exp(4.2) ≈ 67 median body
    output_lognorm_sigma: float = 0.7
    output_pareto_frac: float = 0.05   # fraction of requests in the tail
    output_pareto_alpha: float = 1.6
    output_max: int = 1024
    burstiness: float = 0.0            # 0 = Poisson; >0 adds load spikes
    spike_period_s: float = 60.0
    spike_width_s: float = 8.0


@dataclass
class Trace:
    requests: list[Request]
    config: TraceConfig

    @property
    def n(self) -> int:
        return len(self.requests)

    def rps_realised(self) -> float:
        if not self.requests:
            return 0.0
        span = self.requests[-1].arrival_time - self.requests[0].arrival_time
        return (self.n - 1) / span if span > 0 else 0.0


def _sample_lengths(cfg: TraceConfig, n: int, rng: np.random.Generator,
                    ) -> tuple[np.ndarray, np.ndarray]:
    inp = rng.lognormal(cfg.input_lognorm_mu, cfg.input_lognorm_sigma, n)
    inp = np.clip(inp, 8, cfg.input_max).astype(np.int64)
    out = rng.lognormal(cfg.output_lognorm_mu, cfg.output_lognorm_sigma, n)
    # Heavy tail: a Pareto component captures the paper's Fig. 6 shape
    # (most requests short, a few very long).
    tail = rng.random(n) < cfg.output_pareto_frac
    pareto = (rng.pareto(cfg.output_pareto_alpha, n) + 1.0) * 128.0
    out = np.where(tail, np.maximum(out, pareto), out)
    out = np.clip(out, 1, cfg.output_max).astype(np.int64)
    return inp, out


def _arrival_times(cfg: TraceConfig, rng: np.random.Generator) -> np.ndarray:
    """Poisson arrivals; optional deterministic load spikes (Fig. 5/16)."""
    times = []
    t = 0.0
    while t < cfg.duration_s:
        rate = cfg.rps
        if cfg.burstiness > 0.0:
            phase = t % cfg.spike_period_s
            if phase < cfg.spike_width_s:
                rate = cfg.rps * (1.0 + cfg.burstiness)
        t += rng.exponential(1.0 / max(rate, 1e-9))
        if t < cfg.duration_s:
            times.append(t)
    return np.array(times)


def synthesize(cfg: TraceConfig, pool: list[AdapterInfo]) -> Trace:
    rng = np.random.default_rng(cfg.seed)
    times = _arrival_times(cfg, rng)
    n = len(times)
    inp, out = _sample_lengths(cfg, n, rng)
    adapters = assign_adapters(n, pool, rng, alpha=cfg.adapter_alpha)
    reqs = [Request(input_len=int(inp[i]), output_len=int(out[i]),
                    adapter_id=int(adapters[i]), arrival_time=float(times[i]))
            for i in range(n)]
    return Trace(requests=reqs, config=cfg)


def synthesize_shared_prefix(cfg: TraceConfig, pool: list[AdapterInfo],
                             n_prefixes: int = 4, prefix_len: int = 48,
                             suffix_min: int = 4, suffix_max: int = 16,
                             vocab_size: int = 32000) -> Trace:
    """Shared-prefix-heavy workload (prefix-cache A/B substrate).

    Production multi-tenant traffic concentrates on a handful of system
    prompts / few-shot preambles; this variant makes that structure
    explicit: every request's prompt is one of ``n_prefixes`` fixed
    preambles of ``prefix_len`` tokens (popularity power-law, like the
    paper's adapter skew) followed by a unique random suffix. Real
    token ids are attached (``Request.prompt``) so the engine's radix
    tree has material to match — the plain ``synthesize`` carries
    lengths only. Arrivals and adapter assignment follow ``cfg``
    exactly as in ``synthesize``.
    """
    rng = np.random.default_rng(cfg.seed)
    times = _arrival_times(cfg, rng)
    n = len(times)
    adapters = assign_adapters(n, pool, rng, alpha=cfg.adapter_alpha)
    _, out = _sample_lengths(cfg, n, rng)
    prefixes = [rng.integers(0, vocab_size, size=prefix_len).tolist()
                for _ in range(n_prefixes)]
    pop = 1.0 / np.arange(1, n_prefixes + 1)
    pop /= pop.sum()
    reqs = []
    for i in range(n):
        pre = prefixes[int(rng.choice(n_prefixes, p=pop))]
        suffix = rng.integers(
            0, vocab_size,
            size=int(rng.integers(suffix_min, suffix_max + 1))).tolist()
        prompt = pre + suffix
        reqs.append(Request(input_len=len(prompt), output_len=int(out[i]),
                            adapter_id=int(adapters[i]),
                            arrival_time=float(times[i]), prompt=prompt))
    return Trace(requests=reqs, config=cfg)


def synthesize_multitenant(cfg: TraceConfig, pool: list[AdapterInfo],
                           tenants: tuple = ("acme", "globex", "initech",
                                             "umbrella"),
                           heavy_hitter: str = "floodcorp",
                           heavy_rps_factor: float = 8.0,
                           heavy_output_factor: float = 4.0) -> Trace:
    """Multi-tenant workload with one adversarial heavy hitter
    (gateway A/B substrate).

    Each well-behaved tenant independently submits a ``cfg``-shaped
    stream at ``cfg.rps`` (same Azure-calibrated length model as
    ``synthesize``); ``heavy_hitter`` floods ``heavy_rps_factor``× that
    rate with ``heavy_output_factor``× longer decodes — the tenant a
    per-engine scheduler cannot tell apart from everyone else but a
    gateway must bound. Tenant streams use derived seeds and merge by
    arrival time, so the offered load is identical across A/B arms.
    ``Request.tenant`` carries the attribution.
    """
    streams = []
    for i, name in enumerate(tenants):
        sub = dataclasses.replace(cfg, seed=cfg.seed + 1 + i)
        t = synthesize(sub, pool)
        for r in t.requests:
            r.tenant = name
        streams.extend(t.requests)
    hcfg = dataclasses.replace(
        cfg, seed=cfg.seed + 101,
        rps=cfg.rps * heavy_rps_factor,
        output_lognorm_mu=cfg.output_lognorm_mu
        + math.log(heavy_output_factor))
    ht = synthesize(hcfg, pool)
    for r in ht.requests:
        r.tenant = heavy_hitter
    streams.extend(ht.requests)
    streams.sort(key=lambda r: r.arrival_time)
    return Trace(requests=streams, config=cfg)


def downscale_for_engine(trace: Trace, n_adapters: int,
                         max_input: int, max_output: int,
                         time_scale: float = 1.0) -> Trace:
    """Map a production-scale trace onto the reduced real-engine setting.

    The JAX engine in this container runs a reduced model with short
    context; this shrinks lengths *proportionally* (preserving the
    heavy-tailed shape that drives the paper's scheduling results),
    folds adapter ids into the engine's catalog (preserving the
    power-law popularity skew), and compresses arrival times by
    ``time_scale`` so minutes of trace replay in seconds of wall time.
    Fresh Request objects are returned — replaying the same trace twice
    (e.g. per routing policy) must not share mutable request state.
    """
    src = trace.requests
    if not src:
        return Trace(requests=[], config=trace.config)
    in_hi = max(r.input_len for r in src)
    out_hi = max(r.output_len for r in src)
    reqs = []
    for r in src:
        inp = max(4, int(round(r.input_len * max_input / max(in_hi, 1))))
        out = max(1, int(round(r.output_len * max_output / max(out_hi, 1))))
        reqs.append(Request(
            input_len=min(inp, max_input),
            output_len=min(out, max_output),
            adapter_id=r.adapter_id % n_adapters,
            arrival_time=r.arrival_time * time_scale))
    return Trace(requests=reqs, config=trace.config)


def load_azure_csv(path: str, cfg: TraceConfig,
                   pool: list[AdapterInfo]) -> Trace:
    """Load a real trace CSV (columns: arrival_s,input_tokens,output_tokens).

    Adapters are attached with the same power-law model as ``synthesize``
    (the Azure trace has no adapter column — the paper does the same).
    """
    rng = np.random.default_rng(cfg.seed)
    rows = []
    with open(path) as f:
        for row in csv.DictReader(f):
            rows.append((float(row["arrival_s"]),
                         int(row["input_tokens"]),
                         int(row["output_tokens"])))
    rows.sort()
    adapters = assign_adapters(len(rows), pool, rng, alpha=cfg.adapter_alpha)
    reqs = [Request(input_len=i, output_len=o, adapter_id=int(adapters[k]),
                    arrival_time=t) for k, (t, i, o) in enumerate(rows)]
    return Trace(requests=reqs, config=cfg)
