"""repro: Chameleon many-adapter LLM serving framework on JAX/TPU."""
__version__ = "0.1.0"
