"""Train a ~15M-param LM for a few hundred steps with fault tolerance.

Demonstrates the training substrate end-to-end: AdamW, deterministic
synthetic data, async checkpointing, and checkpoint/restart recovery
from injected node failures (the loop any 1000-node deployment runs).

    PYTHONPATH=src python examples/train_with_recovery.py [--steps 200]
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.training import (AdamWConfig, AsyncCheckpointer, DataConfig,
                            NodeFailure, SyntheticLM, init_train_state,
                            latest_step, make_train_step,
                            restore_checkpoint, run_with_recovery)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[60, 140])
    args = ap.parse_args()

    cfg = get_config("internlm2-1.8b").reduced(
        n_layers=4, d_model=192, vocab_size=4096, d_ff=512)
    n_params_cfg = cfg.param_count()
    print(f"model: {cfg.name} reduced -> {n_params_cfg/1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    params, opt = init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0),
                                   jnp.float32)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=16))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    ck = AsyncCheckpointer(ckpt_dir, keep=2)
    state = {"params": params, "opt": opt}
    fail_at = set(args.fail_at)
    losses = []

    def train_one(step):
        if step in fail_at:
            fail_at.discard(step)
            raise NodeFailure(host=step % 7)
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state["params"], state["opt"], m = step_fn(
            state["params"], state["opt"], batch)
        losses.append(float(m["loss"]))
        if step % 25 == 0:
            print(f"  step {step:4d} loss {m['loss']:.3f} "
                  f"lr {m['lr']:.2e}")
        return {"loss": float(m["loss"])}

    def save(step):
        ck.save(step, {"params": state["params"], "opt": state["opt"]})

    def restore():
        ck.wait()
        last = latest_step(ckpt_dir)
        if last is None:
            return 0
        step, trees = restore_checkpoint(ckpt_dir)
        state["params"], state["opt"] = trees["params"], trees["opt"]
        print(f"  [recovery] restored step {step}")
        return step

    out = run_with_recovery(train_one, save, restore, n_steps=args.steps,
                            checkpoint_every=50)
    ck.wait()
    print(f"\ndone: {out['steps_done']} steps, "
          f"{out['recoveries']} recoveries, "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training must make progress"
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
