"""Long-context decode with attention-free SSM (why long_500k is theirs).

Falcon-Mamba-style reduced model: prefill a long prompt, then decode
with O(1) per-token state — the serve state size is independent of the
context length, unlike a KV cache. Prints the crossover math for the
full falcon-mamba-7b at 500k context.

    PYTHONPATH=src python examples/long_context_ssm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api


def main() -> None:
    cfg = get_config("falcon-mamba-7b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    B = 1
    for S in (64, 256, 1024):
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                    cfg.vocab_size)
        last, state = api.prefill(cfg, params, tokens)
        ssm, conv = state
        state_bytes = ssm.size * 4 + conv.size * 4
        # Decode 8 tokens — state size never grows.
        tok = jnp.argmax(last, -1)[:, None]
        for _ in range(8):
            logits, state = api.decode_step(cfg, params, tok, state)
            tok = jnp.argmax(logits, -1)[:, None]
        ssm2, conv2 = state
        assert ssm2.shape == ssm.shape and conv2.shape == conv.shape
        print(f"context {S:5d}: serve state {state_bytes/1e3:8.1f} kB "
              f"(constant in S)")

    full = get_config("falcon-mamba-7b")
    ssm_bytes = (full.n_layers * full.d_inner * full.d_state * 4
                 + full.n_layers * (full.d_conv - 1) * full.d_inner * 2)
    # Equivalent full-attention KV at 500k (llama-7B-ish geometry).
    kv_bytes = 2 * 32 * 32 * 128 * 2 * 524288
    print(f"\nfull falcon-mamba-7b serve state: {ssm_bytes/1e6:.1f} MB")
    print(f"full-attention KV at 500k context: {kv_bytes/1e9:.1f} GB "
          f"({kv_bytes/ssm_bytes:,.0f}x larger)")
    print("=> long_500k is assigned to SSM/hybrid archs; "
          "pure-attention archs skip it (DESIGN.md §3)")


if __name__ == "__main__":
    main()
