"""End-to-end serving driver (the paper's kind of workload).

Replays a production-style trace (heavy-tailed lengths, Poisson
arrivals, power-law adapter popularity, 100 adapters) through the
Chameleon node and the S-LoRA baseline, and prints the paper's headline
comparison. Uses the calibrated simulator so a 2-minute production
window runs in seconds of wall time; `--engine` instead drives the real
JAX engine on a reduced model with a scaled-down trace, and `--cluster`
drives N real engine replicas behind adapter-affinity routing (shared
AdapterCatalog, per-node cache/scheduler stats — DESIGN §3).

    PYTHONPATH=src python examples/serve_manyadapter.py [--rps 12]
    PYTHONPATH=src python examples/serve_manyadapter.py --engine
    PYTHONPATH=src python examples/serve_manyadapter.py --cluster
"""
import argparse

import numpy as np

from repro.serving import NodeConfig, TraceConfig, build_node, synthesize
from repro.serving.metrics import slo_from_lowload


def run_sim(rps: float) -> None:
    print(f"=== many-adapter serving @ {rps} RPS "
          f"(Llama-7B / A40 / 100 adapters) ===")
    rows = {}
    for system in ("slora", "userve-sjf", "chameleon"):
        sim, adapters, cost = build_node(system, NodeConfig())
        trace = synthesize(TraceConfig(rps=rps, duration_s=120.0, seed=1),
                           list(adapters.values()))
        m = sim.run(trace)
        rows[system] = m
        print(f"{system:>12}: p50 TTFT {m.p50_ttft():7.3f}s   "
              f"p99 TTFT {m.p99_ttft():8.3f}s   "
              f"p99 TBT {m.p99_tbt():6.3f}s   "
              f"hit {m.cache_stats['hit_rate']:.2f}   "
              f"loaded {m.cache_stats['gb_loaded']:.1f} GB")
    s, c = rows["slora"], rows["chameleon"]
    print(f"\nChameleon vs S-LoRA: P99 TTFT −{1 - c.p99_ttft()/s.p99_ttft():.1%}, "
          f"P50 TTFT −{1 - c.p50_ttft()/s.p50_ttft():.1%} "
          f"(paper at high load: −80.7 % / −48.1 %)")


def run_engine() -> None:
    from repro.core import Request, RequestState
    from repro.serving import build_system
    from repro.serving.engine import EngineConfig

    print("=== real JAX engine (reduced model, unified surface) ===")
    eng = build_system("chameleon", tier="engine", ecfg=EngineConfig(
        max_slots=6, max_len=128, n_lora_slots=4, n_adapters=12))
    rng = np.random.default_rng(1)
    handles = [eng.submit(Request(input_len=int(rng.integers(4, 40)),
                                  output_len=int(rng.integers(4, 30)),
                                  adapter_id=int(rng.integers(0, 12))))
               for _ in range(24)]
    # Stream one request live; cancel another mid-queue (the api-smoke
    # contract: at least one streamed token, one clean cancellation).
    first_tok = next(iter(handles[0]))
    victim = handles[-1]
    assert victim.cancel()
    eng.drain()
    assert victim.state is RequestState.CANCELLED
    done = [h.result() for h in handles
            if h.state is RequestState.FINISHED]
    assert len(done) == 23 and first_tok == handles[0].tokens[0]
    ttfts = sorted(r.ttft for r in done)
    print(f"completed {len(done)} (+1 cancelled); "
          f"p50 TTFT {ttfts[len(ttfts)//2]:.3f}s  "
          f"p99 TTFT {ttfts[-1]:.3f}s")
    print("cache:", eng.stats()["cache"])
    print("api-smoke ok: streamed tokens + clean cancellation")


def run_engine_cluster(n_engines: int) -> None:
    from repro.core.lora import build_adapter_pool
    from repro.serving import build_system
    from repro.serving.engine import EngineConfig
    from repro.serving.trace import (TraceConfig, downscale_for_engine,
                                     synthesize)

    print(f"=== real-engine cluster ({n_engines} replicas, "
          f"adapter-affinity routing) ===")
    ecfg = EngineConfig(max_slots=4, max_len=128, n_lora_slots=3,
                        n_adapters=12)
    base = synthesize(TraceConfig(rps=12.0, duration_s=4.0,
                                  n_adapters=ecfg.n_adapters, seed=1),
                      build_adapter_pool(ecfg.n_adapters, 64, 4, 64))
    trace = downscale_for_engine(base, ecfg.n_adapters,
                                 max_input=48, max_output=16)
    cluster = build_system("chameleon", tier="cluster", ecfg=ecfg,
                           n_nodes=n_engines, policy="adapter_affinity")
    cluster.warmup()
    merged, per_node = cluster.run(trace.requests)
    print(f"completed {merged.completed()}/{merged.n_submitted}  "
          f"p50 TTFT {merged.p50_ttft():.3f}s  "
          f"p99 TTFT {merged.p99_ttft():.3f}s  "
          f"hit {merged.cache_stats['hit_rate']:.2f}  "
          f"adapter loads {merged.cache_stats['misses']}")
    for i, m in enumerate(per_node):
        print(f"  node {i}: {m.completed():3d} reqs  "
              f"p99 TTFT {m.p99_ttft():7.3f}s  "
              f"hit {m.cache_stats['hit_rate']:.2f}  "
              f"bypassed {m.sched_stats['bypassed']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rps", type=float, default=12.0)
    ap.add_argument("--engine", action="store_true")
    ap.add_argument("--cluster", action="store_true")
    ap.add_argument("--n-engines", type=int, default=2)
    args = ap.parse_args()
    if args.cluster:
        run_engine_cluster(args.n_engines)
    elif args.engine:
        run_engine()
    else:
        run_sim(args.rps)
